"""Roofline analysis over the dry-run artifacts (spec §ROOFLINE ANALYSIS).

Per (arch x shape x mesh) record:
    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)    [s, per step]
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)
All three are derived from the loop-aware HLO accounting (hlo_analysis.py)
of the compiled SPMD module; HLO numbers are already per-device, so the
per-chip terms divide by the peak rates only. Byte terms use the
bf16-equivalent counts (the CPU backend f32-promotes bf16; DESIGN.md §4).

MODEL_FLOPS = 6 N D (dense train) / 6 N_active D (MoE), 2 N D for inference
prefill and 2 N D_step for decode; the MODEL/HLO ratio flags remat and
dispatch waste.

Usage:  PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def model_flops_per_device(arch: str, shape_name: str, devices: int) -> float:
    """Useful (algorithmic) matmul FLOPs per device per step."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        # LoRA train: fwd (2ND) + remat fwd (2ND) + activation-grad bwd (2ND)
        total = 6.0 * n_active * tokens
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: ONE token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / devices


def analyze_record(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    dev = rec["devices"]
    flops = rec["flops_per_device"]
    mem_bytes = rec.get("bytes_per_device_bf16eq", rec["bytes_per_device"])
    coll_bytes = rec.get("collective_bytes_bf16eq", rec["collective_bytes"])

    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = mem_bytes / HBM_BW
    t_collective = coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], dev)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "devices": dev,
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_collective,
        "dominant": dominant,
        "model_flops_per_device": mf,
        "useful_flops_ratio": mf / flops if flops else 0.0,
        "step_bound_s": max(terms.values()),
        "mfu_upper_bound": (mf / PEAK_FLOPS_BF16) / max(terms.values())
        if max(terms.values()) > 0
        else 0.0,
    }


def suggest(row: dict) -> str:
    """One sentence on what would move the dominant term down."""
    d = row["dominant"]
    if d == "collective":
        return ("reduce TP-activation all-reduces: sequence-parallel "
                "(reduce-scatter+all-gather) or a narrower model axis")
    if d == "memory":
        return ("raise arithmetic intensity: larger per-step tile/batch, "
                "fuse elementwise chains, or cast f32 paths to bf16")
    return ("compute-bound: shave redundant FLOPs (remat policy, capacity "
            "factor) or accept — near roofline")


def load_dir(path: str):
    recs = []
    for f in sorted(os.listdir(path)):
        if f.endswith(".json"):
            recs.append(json.load(open(os.path.join(path, f))))
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16", help="mesh filter (16x16 | 2x16x16 | all)")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()

    rows = []
    skipped = []
    for rec in load_dir(args.dir):
        if args.mesh != "all" and rec.get("mesh") != args.mesh:
            continue
        r = analyze_record(rec)
        if r is None:
            skipped.append((rec["arch"], rec["shape"], rec.get("reason", rec.get("error", ""))))
            continue
        r["suggestion"] = suggest(r)
        rows.append(r)

    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} {'useful':>7s} {'MFU_ub':>7s}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(
            f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} {r['dominant']:>10s} "
            f"{r['useful_flops_ratio']:7.3f} {r['mfu_upper_bound']:7.3f}"
        )
    for a, s, why in skipped:
        print(f"{a:22s} {s:12s} SKIPPED: {why}")

    if args.json_out:
        os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"\nwrote {args.json_out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
