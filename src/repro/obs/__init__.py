"""Device-resident flight recorder.

The capture path lives INSIDE the jitted ``lax.scan``s of the three
engines (``fast_sim`` pool, ``fleet`` contention, ``selector``/``engine``
selection) as extra stacked scan outputs — no host callbacks on the hot
path. Everything rides behind a static ``collect=`` flag: with
``collect=False`` (the default everywhere) the traced program is the exact
program shipped before this package existed (bitwise pin, enforced by
tests/test_telemetry.py and the forced-4-device subprocess parity tests).

Host side:

* :mod:`repro.obs.frame` — the ``TelemetryFrame`` view over the ``tel_*``
  keys the engines emit (telemetry travels as flat dict keys so the
  scatter-merge / shard_map / reorder plumbing needs no special cases);
* :mod:`repro.obs.ledger` — folds frames into structured, JSON-serializable
  metric reports (cost decomposition reconciled against reported
  utilities, preemption counts, fleet starvation incidence, selector
  convergence curves);
* :mod:`repro.obs.report` — renders a ledger as a textual dashboard.
"""
from repro.obs.frame import (
    FALLBACK_KEYS,
    FLEET_KEYS,
    SLOT_KEYS,
    TEL_PREFIX,
    TelemetryFrame,
    frame_from_out,
    has_telemetry,
)
from repro.obs.ledger import (
    SCHEMA_VERSION,
    cost_reconciliation,
    fallback_events,
    fleet_ledger,
    grid_ledger,
    pool_ledger,
    selection_ledger,
)
from repro.obs.report import render

__all__ = [
    "TEL_PREFIX",
    "SLOT_KEYS",
    "FLEET_KEYS",
    "FALLBACK_KEYS",
    "fallback_events",
    "TelemetryFrame",
    "frame_from_out",
    "has_telemetry",
    "SCHEMA_VERSION",
    "cost_reconciliation",
    "pool_ledger",
    "fleet_ledger",
    "selection_ledger",
    "grid_ledger",
    "render",
]
