"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-v01 family] — dense GQA, no biases."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b",
        arch_type="dense",
        num_layers=64,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        d_ff=33792,
        vocab_size=256000,
        rope_theta=75_000_000.0,
        norm_type="layernorm",
        mlp_act="silu",
        tie_embeddings=True,
        source="hf:CohereForAI/c4ai-command-r-v01",
    )


def smoke_config() -> ModelConfig:
    return config().reduced()
