"""Online Policy Selection (Algorithm 2): Exponentiated Gradient over the
policy pool, full-information (every candidate's utility is evaluated per
job — cheap thanks to the vmapped simulator).

Guarantee (Theorem 2): with eta = sqrt(2 ln M / K) and utilities normalized
to [0,1], regret vs the best fixed policy is <= sqrt(2 K ln M).
benchmarks/theorem2.py verifies the bound empirically; test_selector.py
asserts it for adversarial utility streams.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class SelectorState:
    weights: np.ndarray               # (M,) simplex
    eta: float
    k: int = 0
    cum_expected: float = 0.0         # sum_k E_{w_k}[u_k]
    cum_utils: Optional[np.ndarray] = None  # (M,) per-policy cumulative
    weight_history: List[np.ndarray] = field(default_factory=list)


def init_selector(n_policies: int, horizon: int, eta: Optional[float] = None,
                  track_history: bool = False) -> SelectorState:
    eta = float(np.sqrt(2.0 * np.log(n_policies) / max(horizon, 1))) if eta is None else eta
    st = SelectorState(
        weights=np.full(n_policies, 1.0 / n_policies),
        eta=eta,
        cum_utils=np.zeros(n_policies),
    )
    if track_history:
        st.weight_history.append(st.weights.copy())
    return st


def select(state: SelectorState, rng: np.random.Generator) -> int:
    """Sample the policy to run for the incoming job (Line 6)."""
    return int(rng.choice(len(state.weights), p=state.weights))


def update(state: SelectorState, utilities: np.ndarray,
           track_history: bool = False) -> SelectorState:
    """EG / multiplicative-weights update (Lines 7-11). ``utilities`` must be
    normalized to [0, 1] (see repro.core.job.normalize_utility)."""
    u = np.clip(np.asarray(utilities, float), 0.0, 1.0)
    assert u.shape == state.weights.shape
    state.cum_expected += float(np.dot(state.weights, u))
    state.cum_utils += u
    logits = np.log(np.maximum(state.weights, 1e-300)) + state.eta * u
    logits -= logits.max()
    w = np.exp(logits)
    state.weights = w / w.sum()
    state.k += 1
    if track_history:
        state.weight_history.append(state.weights.copy())
    return state


def regret(state: SelectorState) -> float:
    """max_m sum_k u_k^m - sum_k E_{w_k}[u_k]  (cumulative, Theorem 2 LHS)."""
    return float(state.cum_utils.max() - state.cum_expected)


def regret_bound(n_policies: int, k: int) -> float:
    return float(np.sqrt(2.0 * k * np.log(n_policies)))


def best_policy(state: SelectorState) -> int:
    return int(np.argmax(state.weights))
