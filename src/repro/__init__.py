"""repro: deadline-aware online scheduling for LLM fine-tuning on spot
markets (CS.DC'25 reproduction) — a multi-pod JAX training/inference
framework with the paper's scheduler as a first-class layer.

Packages: core (the paper), models, kernels (Pallas TPU), configs, data,
optim, checkpoint, train, serve, launch. See README.md / DESIGN.md.
"""

__version__ = "1.0.0"
