"""Rotary position embeddings, including Qwen2-VL M-RoPE.

M-RoPE splits the rotary frequency dimensions into (temporal, height, width)
sections, each rotated by its own position stream. For text tokens all three
streams carry the same position, which makes M-RoPE coincide with standard
RoPE — the property ``test_rope.py`` checks.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    # x: (..., head_dim); rotate-half convention
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(
    x: jnp.ndarray,  # (B, S, n_heads, head_dim)
    positions: jnp.ndarray,  # (B, S) int32
    theta: float,
) -> jnp.ndarray:
    if theta <= 0:  # arch without RoPE (e.g. hubert: positional info in frontend)
        return x
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_m_rope(
    x: jnp.ndarray,  # (B, S, n_heads, head_dim)
    positions: jnp.ndarray,  # (B, S, 3) int32 -- (t, h, w) streams
    theta: float,
    sections: Tuple[int, int, int],
) -> jnp.ndarray:
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    # angle per stream: (B, S, 3, half)
    ang_all = positions[..., None].astype(jnp.float32) * freqs
    # pick section s for frequency indices in that section
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )  # (half,)
    ang = ang_all[:, :, sec_id, jnp.arange(half)]  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def default_positions(batch: int, seq: int, offset=0) -> jnp.ndarray:
    return jnp.arange(seq, dtype=jnp.int32)[None, :] + offset


def default_m_positions(batch: int, seq: int, offset=0) -> jnp.ndarray:
    p = default_positions(batch, seq, offset)
    p = jnp.broadcast_to(p, (batch, seq))
    return jnp.stack([p, p, p], axis=-1)
