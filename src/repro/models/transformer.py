"""Composable model assembly: init / forward / prefill / decode for every
assigned architecture family (dense, moe, ssm, hybrid, vlm, audio).

Layers are *scanned* (stacked params, lax.scan) to keep HLO size independent
of depth — essential for compiling 80-layer models on the 512-device dry-run.
Hybrid (zamba2) scans super-blocks: ``hybrid_period`` Mamba2 layers + one
*shared* transformer block whose weights are closed over (weight-tied), each
application carrying its own KV cache slot.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import blocks as blk
from repro.models import ssm as ssm_lib
from repro.models.common import init_norm, apply_norm, normal_param
from repro.models.rope import default_m_positions, default_positions
from repro.sharding import Param, is_param, shard, split_params


def model_dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Param stacking for scanned layers
# ---------------------------------------------------------------------------

def stack_param_trees(trees):
    def _stack(*ps):
        vals = jnp.stack([p.value for p in ps])
        return Param(vals, ("layers",) + tuple(ps[0].axes))

    return jax.tree.map(_stack, *trees, is_leaf=is_param)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(rng, cfg):
    """Returns a tree with Param leaves (value + logical axes)."""
    dt = model_dtype(cfg)
    keys = jax.random.split(rng, cfg.num_layers + 4)
    p = {}
    if not cfg.embed_inputs:
        # vocab dim deliberately NOT sharded: gathers from a vocab-sharded
        # table trigger involuntary replication in SPMD (dry-run warning);
        # the table is small once d_model is FSDP-sharded.
        p["embed"] = normal_param(
            keys[-1], (cfg.vocab_size, cfg.d_model), (None, "fsdp"), dt, stddev=0.02
        )
    p["final_norm"] = init_norm(cfg, dt)
    if not cfg.tie_embeddings:
        p["head"] = normal_param(
            keys[-2], (cfg.d_model, cfg.vocab_size), ("fsdp", "vocab"), dt, stddev=0.02
        )
    elif cfg.embed_inputs:
        # tied embeddings impossible without an input table; emit a head
        p["head"] = normal_param(
            keys[-2], (cfg.d_model, cfg.vocab_size), ("fsdp", "vocab"), dt, stddev=0.02
        )

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        layers = [
            blk.init_transformer_block(keys[i], cfg, dt) for i in range(cfg.num_layers)
        ]
        p["layers"] = stack_param_trees(layers)
    elif cfg.arch_type == "ssm":
        layers = [blk.init_mamba_block(keys[i], cfg, dt) for i in range(cfg.num_layers)]
        p["layers"] = stack_param_trees(layers)
    elif cfg.arch_type == "hybrid":
        per = cfg.hybrid_period
        ns = cfg.num_layers // per
        supers = []
        for si in range(ns):
            inner = [
                blk.init_mamba_block(keys[si * per + j], cfg, dt) for j in range(per)
            ]
            supers.append(stack_param_trees(inner))
        p["layers"] = stack_param_trees(supers)
        p["shared"] = blk.init_transformer_block(keys[-3], cfg, dt, use_moe=False)
    else:
        raise ValueError(cfg.arch_type)
    return p


def init_model(rng, cfg):
    """Convenience: (param values, logical axes) trees."""
    return split_params(init_params(rng, cfg))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(cfg, params, batch):
    dt = model_dtype(cfg)
    if cfg.embed_inputs:
        h = batch["embeds"].astype(dt)
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    return shard(h, "batch", "seq", "embed")


def unembed(cfg, params, h):
    if "head" in params:
        logits = jnp.einsum("bsd,dv->bsv", h, params["head"])
    else:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    logits = shard(logits, "batch", "seq", "vocab")
    return logits.astype(jnp.float32)


def _positions(cfg, batch, seq: int, offset=0):
    if "positions" in batch:
        return batch["positions"]
    b = (batch.get("tokens") if "tokens" in batch else batch["embeds"]).shape[0]
    if cfg.m_rope:
        return default_m_positions(b, seq, offset)
    return jnp.broadcast_to(default_positions(b, seq, offset), (b, seq))


# ---------------------------------------------------------------------------
# Forward (train / full sequence)
# ---------------------------------------------------------------------------

def forward(cfg, params, batch, remat: str = "none"):
    """-> (logits (B,S,V) f32, aux_loss scalar)."""
    h = embed_inputs(cfg, params, batch)
    seq = h.shape[1]
    positions = _positions(cfg, batch, seq)

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        fn = functools.partial(blk.transformer_block_full, cfg, positions=positions)
        if remat != "none":
            fn = jax.checkpoint(fn)

        def body(carry, lp):
            hh, aux = carry
            hh, a = fn(lp, hh)
            return (hh, aux + a), None

        (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["layers"])
    elif cfg.arch_type == "ssm":
        fn = functools.partial(blk.mamba_block_full, cfg)
        if remat != "none":
            fn = jax.checkpoint(fn)

        def body(carry, lp):
            return fn(lp, carry), None

        h, _ = jax.lax.scan(body, h, params["layers"])
        aux = jnp.zeros((), jnp.float32)
    elif cfg.arch_type == "hybrid":
        shared = params["shared"]
        mfn = functools.partial(blk.mamba_block_full, cfg)
        sfn = functools.partial(blk.transformer_block_full, cfg, positions=positions)
        if remat != "none":
            mfn = jax.checkpoint(mfn)
            sfn = jax.checkpoint(sfn)

        def super_body(carry, mp):
            hh, aux = carry

            def inner(h2, lp):
                return mfn(lp, h2), None

            hh, _ = jax.lax.scan(inner, hh, mp)
            hh, a = sfn(shared, hh)
            return (hh, aux + a), None

        (h, aux), _ = jax.lax.scan(
            super_body, (h, jnp.zeros((), jnp.float32)), params["layers"]
        )
    else:
        raise ValueError(cfg.arch_type)

    h = apply_norm(cfg, params["final_norm"], h)
    return unembed(cfg, params, h), aux


# ---------------------------------------------------------------------------
# KV / state cache
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    dt = model_dtype(cfg)
    c = {"index": jnp.zeros((), jnp.int32)}
    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        c["kv"] = attn.init_kv_cache(cfg, batch, max_len, dt, cfg.num_layers)
    elif cfg.arch_type == "ssm":
        one = ssm_lib.init_mamba_cache(cfg, batch, dt)
        c["mamba"] = jax.tree.map(
            lambda x: jnp.zeros((cfg.num_layers,) + x.shape, x.dtype), one
        )
    elif cfg.arch_type == "hybrid":
        per = cfg.hybrid_period
        ns = cfg.num_layers // per
        one = ssm_lib.init_mamba_cache(cfg, batch, dt)
        c["mamba"] = jax.tree.map(
            lambda x: jnp.zeros((ns, per) + x.shape, x.dtype), one
        )
        c["kv"] = attn.init_kv_cache(cfg, batch, max_len, dt, ns)
    return c


def cache_axes(cfg):
    """Logical axes tree matching init_cache structure (string leaves, see
    repro.sharding.axes_to_str — keeps the tree mappable against values)."""
    from repro.sharding import axes_to_str as a2s

    c = {"index": a2s(())}
    kv_ax = a2s(("layers", "batch", "kv_seq", "kv_heads", None))
    m_ax = {
        "conv": a2s(("layers", "batch", None, "tensor")),
        "ssd": a2s(("layers", "batch", "ssm_heads", None, None)),
    }
    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        c["kv"] = {"k": kv_ax, "v": kv_ax}
    elif cfg.arch_type == "ssm":
        c["mamba"] = m_ax
    elif cfg.arch_type == "hybrid":
        c["mamba"] = {
            "conv": a2s(("layers", "layers", "batch", None, "tensor")),
            "ssd": a2s(("layers", "layers", "batch", "ssm_heads", None, None)),
        }
        c["kv"] = {"k": kv_ax, "v": kv_ax}
    return c


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(cfg, params, batch, max_len: int):
    """Full-prefix pass building the cache. -> (last-token logits (B,1,V), cache)."""
    assert cfg.supports_decode, "encoder-only arch has no prefill/decode"
    h = embed_inputs(cfg, params, batch)
    bsz, seq = h.shape[0], h.shape[1]
    positions = _positions(cfg, batch, seq)
    cache = init_cache(cfg, bsz, max_len)
    cache["index"] = jnp.asarray(seq, jnp.int32)

    if cfg.arch_type in ("dense", "moe", "vlm"):
        def body(carry, xs):
            hh = carry
            lp, ck, cv = xs
            hh, _, (k, v) = blk.transformer_block_full(
                cfg, lp, hh, positions, want_cache=True
            )
            nk, nv = attn.write_prefill(cfg, ck, cv, k, v)
            return hh, (nk, nv)

        h, (nk, nv) = jax.lax.scan(
            body, h, (params["layers"], cache["kv"]["k"], cache["kv"]["v"])
        )
        cache["kv"] = {"k": nk, "v": nv}
    elif cfg.arch_type == "ssm":
        def body(carry, lp):
            hh, mc = blk.mamba_block_full(cfg, lp, carry, return_cache=True)
            return hh, mc

        h, mc = jax.lax.scan(body, h, params["layers"])
        cache["mamba"] = mc
    elif cfg.arch_type == "hybrid":
        shared = params["shared"]

        def super_body(carry, xs):
            hh = carry
            mp, ck, cv = xs

            def inner(h2, lp):
                h2, mc = blk.mamba_block_full(cfg, lp, h2, return_cache=True)
                return h2, mc

            hh, mcs = jax.lax.scan(inner, hh, mp)
            hh, _, (k, v) = blk.transformer_block_full(
                cfg, shared, hh, positions, want_cache=True
            )
            nk, nv = attn.write_prefill(cfg, ck, cv, k, v)
            return hh, (mcs, nk, nv)

        h, (mcs, nk, nv) = jax.lax.scan(
            super_body, h, (params["layers"], cache["kv"]["k"], cache["kv"]["v"])
        )
        cache["mamba"] = mcs
        cache["kv"] = {"k": nk, "v": nv}
    else:
        raise ValueError(cfg.arch_type)

    h = apply_norm(cfg, params["final_norm"], h[:, -1:])
    return unembed(cfg, params, h), cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def decode_step(cfg, params, batch, cache):
    """One-token step. batch: tokens (B,1) or embeds (B,1,d). -> (logits, cache)."""
    assert cfg.supports_decode
    h = embed_inputs(cfg, params, batch)
    index = cache["index"]
    bsz = h.shape[0]
    if "positions" in batch:
        positions = batch["positions"]
    elif cfg.m_rope:
        positions = jnp.broadcast_to(index[None, None, None], (bsz, 1, 3)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(index[None, None], (bsz, 1)).astype(jnp.int32)

    new_cache = dict(cache)
    if cfg.arch_type in ("dense", "moe", "vlm"):
        def body(carry, xs):
            hh = carry
            lp, ck, cv = xs
            hh, nk, nv = blk.transformer_block_decode(
                cfg, lp, hh, ck, cv, index, positions
            )
            return hh, (nk, nv)

        h, (nk, nv) = jax.lax.scan(
            body, h, (params["layers"], cache["kv"]["k"], cache["kv"]["v"])
        )
        new_cache["kv"] = {"k": nk, "v": nv}
    elif cfg.arch_type == "ssm":
        def body(carry, xs):
            lp, mc = xs
            hh, nmc = blk.mamba_block_decode(cfg, lp, carry, mc)
            return hh, nmc

        h, nmc = jax.lax.scan(body, h, (params["layers"], cache["mamba"]))
        new_cache["mamba"] = nmc
    elif cfg.arch_type == "hybrid":
        shared = params["shared"]

        def super_body(carry, xs):
            hh = carry
            mp, mc, ck, cv = xs

            def inner(h2, xs2):
                lp, c2 = xs2
                h2, nc2 = blk.mamba_block_decode(cfg, lp, h2, c2)
                return h2, nc2

            hh, nmc = jax.lax.scan(inner, hh, (mp, mc))
            hh, nk, nv = blk.transformer_block_decode(
                cfg, shared, hh, ck, cv, index, positions
            )
            return hh, (nmc, nk, nv)

        h, (nmc, nk, nv) = jax.lax.scan(
            super_body,
            h,
            (params["layers"], cache["mamba"], cache["kv"]["k"], cache["kv"]["v"]),
        )
        new_cache["mamba"] = nmc
        new_cache["kv"] = {"k": nk, "v": nv}
    else:
        raise ValueError(cfg.arch_type)

    new_cache["index"] = index + 1
    h = apply_norm(cfg, params["final_norm"], h)
    return unembed(cfg, params, h), new_cache
