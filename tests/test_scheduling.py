"""Paper-core tests: value functions, window solver, offline OPT, policies,
simulator semantics."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.job import (
    expected_progress,
    normalization_bounds,
    normalize_utility,
    tilde_value,
    value_fn,
)
from repro.core.market import constant_trace, from_arrays, vast_like_trace
from repro.core.offline_opt import solve_offline
from repro.core.policies import AHANP, AHANPParams, AHAP, AHAPParams, MSU, ODOnly, UP, Obs
from repro.core.predictor import PerfectPredictor
from repro.core.simulator import simulate
from repro.core.throughput import mu_factor, throughput
from repro.core.window_opt import brute_force_window, solve_window_numpy

JOB = JobConfig(workload=80, deadline=10, n_min=1, n_max=12, value=120.0)
TPUT = ThroughputConfig(mu1=0.9, mu2=0.95)


# ---------------------------------------------------------------------------
# Eq. 4 / Eq. 9
# ---------------------------------------------------------------------------

def test_value_fn_piecewise():
    j = JobConfig(deadline=10, gamma=2.0, value=100.0)
    assert float(value_fn(j, 5)) == 100.0
    assert float(value_fn(j, 10)) == 100.0
    assert abs(float(value_fn(j, 15)) - 50.0) < 1e-5  # halfway to gamma*d
    assert float(value_fn(j, 20)) == 0.0
    assert float(value_fn(j, 99)) == 0.0


def test_tilde_value_properties():
    zs = np.linspace(0, JOB.workload, 200)
    tv = np.array([float(tilde_value(JOB, TPUT, z)) for z in zs])
    assert np.all(np.diff(tv) >= -1e-6)                 # nondecreasing
    assert abs(tv[-1] - JOB.value) < 1e-5               # Ṽ(L) = v
    # NOT concave: slope increases once completion crosses gamma*d
    slopes = np.diff(tv)
    assert slopes.max() > slopes[0] + 1e-6


def test_expected_progress():
    assert float(expected_progress(JOB, 5)) == pytest.approx(40.0)


def test_normalization():
    lo, hi = normalization_bounds(JOB)
    assert lo < 0 < hi
    assert float(normalize_utility(JOB, hi)) == 1.0
    assert float(normalize_utility(JOB, lo)) == 0.0
    assert 0.0 <= float(normalize_utility(JOB, 3.3)) <= 1.0


def test_throughput_and_mu():
    t = ThroughputConfig(alpha=2.0, beta=0.5, mu1=0.8, mu2=0.9)
    assert float(throughput(t, 0)) == 0.0
    assert float(throughput(t, 3)) == pytest.approx(6.5)
    assert float(mu_factor(t, 2, 5)) == pytest.approx(0.8)
    assert float(mu_factor(t, 5, 2)) == pytest.approx(0.9)
    assert float(mu_factor(t, 5, 5)) == 1.0
    assert float(mu_factor(t, 0, 0)) == 1.0


# ---------------------------------------------------------------------------
# Window solver (Eq. 10) — exactness vs brute force
# ---------------------------------------------------------------------------

def test_window_solver_exact_random():
    rng = np.random.default_rng(7)
    for _ in range(60):
        nmin = int(rng.integers(1, 4))
        job = JobConfig(
            workload=float(rng.uniform(10, 30)), deadline=5, n_min=nmin,
            n_max=int(rng.integers(nmin, 8)), value=float(rng.uniform(8, 25)),
            gamma=float(rng.uniform(1.2, 2.5)),
        )
        w1 = int(rng.integers(1, 5))
        prices = rng.uniform(0.2, 1.2, w1).round(2)
        avail = rng.integers(0, 9, w1)
        z0 = float(rng.uniform(0, job.workload * 1.1))
        std = int(rng.integers(0, w1 + 1))
        no, ns, obj = solve_window_numpy(job, TPUT, z0, std, prices, avail, 1.0)
        bu, _ = brute_force_window(job, TPUT, z0, std, prices, avail, 1.0)
        z = z0 + (no + ns).sum()
        cost = float((ns * prices).sum() + no.sum())
        u = float(tilde_value(job, TPUT, z)) - cost
        assert u >= bu - 1e-3, (u, bu)
        assert np.all(ns <= avail)
        assert np.all(no + ns <= job.n_max)


def test_window_solver_respects_deadline_cutoff():
    job = JobConfig(workload=100, deadline=5, n_min=1, n_max=4, value=50.0)
    prices = np.array([0.1, 0.1, 0.1])
    avail = np.array([4, 4, 4])
    no, ns, _ = solve_window_numpy(job, TPUT, 0.0, 1, prices, avail, 1.0)
    assert (no[1:] + ns[1:]).sum() == 0  # slots past the deadline unused


# ---------------------------------------------------------------------------
# Offline OPT
# ---------------------------------------------------------------------------

def test_offline_opt_dominates_all_policies():
    for seed in range(3):
        tr = vast_like_trace(seed=seed, days=1).window(0, JOB.deadline)
        opt = solve_offline(JOB, TPUT, tr)
        pred = PerfectPredictor(tr).matrix(5)
        for pol in [AHAP(AHAPParams(3, 1, 0.7)), AHANP(AHANPParams(0.7)),
                    ODOnly(), MSU(), UP()]:
            r = simulate(pol, JOB, TPUT, tr,
                         pred if pol.name == "ahap" else None)
            assert opt.utility >= r.utility - 0.35, (seed, pol.name, opt.utility, r.utility)


def test_offline_opt_prefers_cheap_slots():
    prices = np.array([1.0, 1.0, 0.1, 0.1, 1.0])
    avail = np.array([12, 12, 12, 12, 12])
    job = JobConfig(workload=16, deadline=5, n_min=1, n_max=12, value=60.0)
    tr = from_arrays(prices, avail)
    opt = solve_offline(job, ThroughputConfig(), tr)
    # the bulk of work should land on the 0.1-priced slots
    assert opt.plan_total[2] + opt.plan_total[3] >= 0.7 * opt.plan_total.sum()


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

def test_od_only_meets_deadline_when_feasible():
    tr = constant_trace(0.5, 0, 20)  # no spot at all
    r = simulate(ODOnly(), JOB, TPUT, tr)
    assert r.completed_by_deadline
    assert r.n_spot.sum() == 0


def test_msu_prefers_spot_then_panics():
    prices = np.full(10, 0.4)
    avail = np.array([6, 6, 6, 0, 0, 0, 0, 0, 0, 0])
    tr = from_arrays(prices, avail)
    r = simulate(MSU(), JOB, TPUT, tr)
    assert r.n_spot[:3].sum() > 0
    assert r.n_od[:2].sum() == 0          # no panic early
    assert r.n_od[4:].sum() > 0           # on-demand after spot vanishes
    # MSU's panic rule ignores reconfiguration losses (mu), so it can slip
    # just past the deadline — exactly the paper's criticism of MSU (Fig. 5)
    assert r.z_ddl > 0.99 * JOB.workload
    assert r.completion_time <= JOB.deadline + 0.1


def test_up_tracks_reference_line():
    tr = constant_trace(0.5, 12, 20)
    r = simulate(UP(), JOB, TPUT, tr)
    assert r.completed_by_deadline
    # near-uniform allocation: 80 work over 10 slots -> ~8/slot
    used = r.n_total[r.n_total > 0]
    assert used.max() <= 10 and used.min() >= 7


def test_ahap_uses_cheap_spot_with_perfect_prediction():
    prices = np.array([1.2, 1.2, 0.2, 0.2, 0.2, 0.2, 1.2, 1.2, 1.2, 1.2])
    avail = np.full(10, 12)
    tr = from_arrays(prices, avail)
    pred = PerfectPredictor(tr).matrix(5)
    r = simulate(AHAP(AHAPParams(5, 1, 0.7)), JOB, TPUT, tr, pred)
    # the cheap slots are saturated (CHC's Ṽ is myopic past the window, so
    # some expensive early work is bought too — faithful Alg. 1 behavior)
    assert np.all(r.n_total[2:6] == JOB.n_max), list(r.n_total)
    assert r.n_spot[2:6].sum() == r.n_total[2:6].sum()  # cheap slots all-spot
    assert r.utility > simulate(ODOnly(), JOB, TPUT, tr).utility
    assert r.utility > simulate(UP(), JOB, TPUT, tr).utility


def test_ahanp_case_table():
    pol = AHANP(AHANPParams(0.7))
    pol.reset(JOB, TPUT)
    # behind schedule -> doubles (with floor n_min)
    pol._prev_avail = 4
    n_o, n_s = pol.decide(Obs(t=4, price=0.5, avail=4, z_prev=10.0, n_prev=3))
    assert n_o + n_s == 6
    # ahead + availability crash -> halve
    pol._prev_avail = 8
    n_o, n_s = pol.decide(Obs(t=4, price=0.5, avail=3, z_prev=60.0, n_prev=8))
    assert n_o + n_s == 4
    # ahead + no spot -> idle
    pol._prev_avail = 8
    n_o, n_s = pol.decide(Obs(t=4, price=0.5, avail=0, z_prev=60.0, n_prev=8))
    assert n_o + n_s == 0
    # ahead + cheap & rising spot -> grab it
    pol._prev_avail = 4
    n_o, n_s = pol.decide(Obs(t=4, price=0.3, avail=9, z_prev=60.0, n_prev=4))
    assert n_s == 9 and n_o == 0


# ---------------------------------------------------------------------------
# Simulator semantics
# ---------------------------------------------------------------------------

def test_simulator_budget_identity_and_feasibility():
    for seed in range(4):
        tr = vast_like_trace(seed=seed, days=1).window(0, 10)
        pred = PerfectPredictor(tr).matrix(5)
        for pol in [AHAP(AHAPParams(2, 2, 0.5)), AHANP(AHANPParams(0.5)), MSU(), UP()]:
            r = simulate(pol, JOB, TPUT, tr, pred if pol.name == "ahap" else None)
            assert abs(r.utility - (r.value - r.cost)) < 1e-6
            assert np.all(r.n_spot <= tr.avail[: len(r.n_spot)])
            assert np.all(r.n_total <= JOB.n_max)
            active = r.n_total > 0
            assert np.all(r.n_total[active] >= JOB.n_min)
            assert r.value <= JOB.value + 1e-9
            assert r.z_ddl <= JOB.workload + 1e-6


def test_termination_config_cost():
    """Idle policy: all value comes from the termination configuration."""

    class Idle(ODOnly):
        def decide(self, obs):
            return 0, 0

    job = JobConfig(workload=24, deadline=4, n_min=1, n_max=12, value=100.0, gamma=3.0)
    tr = constant_trace(0.5, 4, 10)
    r = simulate(Idle(), job, TPUT, tr)
    # termination: 24 work / 12 = 2 extra slots, cost 24, value V(d+2)
    assert r.completion_time == pytest.approx(6.0)
    assert r.cost == pytest.approx(24.0)
    expected_value = 100.0 * (1 - 2.0 / (2.0 * 4))
    assert r.value == pytest.approx(expected_value)
