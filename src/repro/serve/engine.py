"""Minimal batched serving engine: prefill + greedy/temperature decode.

Used by the decode-shape dry-runs (via repro.train.step factories) and the
serving example. Requests are batched to a fixed width; the KV cache is the
ring-buffer/state cache from the model zoo, so SWA and SSM archs serve long
contexts in O(window)/O(1) memory.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf


@dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0    # 0 = greedy


class ServingEngine:
    def __init__(self, cfg, params, max_len: int = 2048, seed: int = 0):
        assert cfg.supports_decode, "encoder-only arch cannot serve decode"
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.rng = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, b: tf.prefill(cfg, p, b, max_len=max_len)
        )
        self._decode = jax.jit(lambda p, c, b: tf.decode_step(cfg, p, b, c))

    def generate_batch(self, requests: List[Request]) -> List[np.ndarray]:
        """Decodes a batch of equal-length prompts in lockstep.

        Production serving would bucket requests by prompt length (padding
        without pad-attention-masking is incorrect); the bucket width is a
        deployment knob, not model logic, so the engine just asserts it."""
        cfg = self.cfg
        bsz = len(requests)
        plen = len(requests[0].prompt)
        assert all(len(r.prompt) == plen for r in requests), (
            "batch requests must be length-bucketed"
        )
        prompts = np.stack([r.prompt for r in requests]).astype(np.int32)
        batch = {"tokens": jnp.asarray(prompts)}
        logits, cache = self._prefill(self.params, batch)

        max_new = max(r.max_new_tokens for r in requests)
        outs = [[] for _ in range(bsz)]
        tok = self._sample(logits[:, -1], requests)
        for step in range(max_new):
            for i in range(bsz):
                if step < requests[i].max_new_tokens:
                    outs[i].append(int(tok[i]))
            logits, cache = self._decode(self.params, cache, {"tokens": tok[:, None]})
            tok = self._sample(logits[:, -1], requests)
        return [np.asarray(o, np.int32) for o in outs]

    def _sample(self, logits: jnp.ndarray, requests: List[Request]) -> jnp.ndarray:
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        temps = jnp.asarray([r.temperature for r in requests], jnp.float32)
        if float(temps.max()) == 0.0:
            return greedy
        self.rng, k = jax.random.split(self.rng)
        sampled = jax.random.categorical(
            k, logits / jnp.maximum(temps[:, None], 1e-3)
        ).astype(jnp.int32)
        return jnp.where(temps > 0, sampled, greedy)
