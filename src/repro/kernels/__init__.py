"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
validated in interpret mode against the pure-jnp oracles in ref.py; ops.py
holds the jit'd public wrappers.
"""
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.window_dp import window_dp
