import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization (see spec — dry-run only; tests/benches see
# the real single CPU device because they never import this module).
# REPRO_DRYRUN_DEVICES (used by the subprocess mini-dryrun test) may shrink
# the placeholder device count; the production default stays 512.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (  # noqa: E402
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    TrainConfig,
    get_config,
    shape_applicable,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import batch_axes, input_specs  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.sharding import split_params, tree_shardings, use_sharding  # noqa: E402
from repro.train.step import (  # noqa: E402
    init_opt_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.utils.partition import is_lora_path, partition_by_path  # noqa: E402

from repro.launch.hlo_analysis import analyze as analyze_hlo  # noqa: E402


def build_step(cfg, shape, mesh, microbatches=None, rules=None):
    """Returns (jitted_fn, example_args_as_SDS) for the shape's mode.

    ``microbatches`` / ``rules`` override the defaults for §Perf hillclimb
    experiments (launch/perf.py)."""
    rng = jax.random.PRNGKey(0)
    abs_params = jax.eval_shape(lambda: tf.init_params(rng, cfg))
    values, axes = split_params(abs_params)
    p_shard = tree_shardings(values, axes, mesh, rules)
    batch_spec, cache_spec = input_specs(cfg, shape)
    b_shard = tree_shardings(batch_spec, batch_axes(batch_spec), mesh, rules)
    repl = NamedSharding(mesh, P())

    if shape.mode == "train":
        # microbatch so each accumulation step carries 1 sequence per device:
        # keeps the 80-layer scan residuals inside v5e HBM (DESIGN.md §5)
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        batch_shards = axis_sizes.get("data", 1) * axis_sizes.get("pod", 1)
        mb = microbatches or max(1, shape.global_batch // batch_shards)
        tcfg = TrainConfig(remat="full", seq_len=shape.seq_len,
                           global_batch=shape.global_batch, microbatches=mb)
        step = make_train_step(cfg, tcfg)
        opt_spec = jax.eval_shape(functools.partial(init_opt_state), values)
        lora_shards, _ = partition_by_path(p_shard, is_lora_path)
        opt_shard = type(opt_spec)(step=repl, m=list(lora_shards), v=list(lora_shards))
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, opt_shard, b_shard),
            out_shardings=(p_shard, opt_shard, None),
        )
        return jitted, (values, opt_spec, batch_spec)

    if shape.mode == "prefill":
        if cfg.encoder_only:
            # encoder inference over the full window: no cache to build
            from repro.models import transformer as _tf

            pstep = lambda p, b: _tf.forward(cfg, p, b)[0]
        else:
            pstep = make_prefill_step(cfg, max_len=shape.seq_len)
        jitted = jax.jit(pstep, in_shardings=(p_shard, b_shard), out_shardings=None)
        return jitted, (values, batch_spec)

    if shape.mode == "decode":
        dstep = make_decode_step(cfg)
        c_shard = tree_shardings(cache_spec, tf.cache_axes(cfg), mesh, rules)
        jitted = jax.jit(
            dstep,
            in_shardings=(p_shard, c_shard, b_shard),
            out_shardings=(None, c_shard),
        )
        return jitted, (values, cache_spec, batch_spec)

    raise ValueError(shape.mode)


_SMOKE_SHAPES = {
    "train_4k": ("train_4k", 128, 8, "train"),
    "prefill_32k": ("prefill_32k", 256, 4, "prefill"),
    "decode_32k": ("decode_32k", 256, 8, "decode"),
    "long_500k": ("long_500k", 512, 1, "decode"),
}


def run_one(arch: str, shape_name: str, multi_pod: bool, verbose=True,
            smoke: bool = False, hlo_dir: str = "", microbatches=None,
            rules=None, variant: str = "", cfg_overrides=None):
    if smoke:
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeConfig

        cfg = get_smoke_config(arch)
        shape = ShapeConfig(*_SMOKE_SHAPES[shape_name])
    else:
        cfg = get_config(arch)
        shape = INPUT_SHAPES[shape_name]
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    ok, reason = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if smoke:
        mesh_name = "2x2x2" if multi_pod else "2x2"
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mode": shape.mode, "status": "skipped", "reason": reason,
    }
    if not ok:
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: SKIP ({reason})")
        return rec

    t0 = time.time()
    if smoke:
        mesh = jax.make_mesh(
            (2, 2, 2) if multi_pod else (2, 2),
            ("pod", "data", "model") if multi_pod else ("data", "model"),
        )
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    if variant:
        rec["variant"] = variant
    with use_sharding(mesh, rules):
        jitted, args = build_step(cfg, shape, mesh, microbatches=microbatches,
                                  rules=rules)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax-version drift: list of per-device dicts
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    hlo = analyze_hlo(hlo_text)
    n_dev = mesh.devices.size
    if hlo_dir:
        import zstandard

        os.makedirs(hlo_dir, exist_ok=True)
        fname = f"{arch}_{shape_name}_{mesh_name}.hlo.zst".replace("/", "-")
        with open(os.path.join(hlo_dir, fname), "wb") as f:
            f.write(zstandard.ZstdCompressor(level=6).compress(hlo_text.encode()))

    rec.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        devices=int(n_dev),
        # loop-aware accounting (per device); raw cost_analysis kept as cross-check
        flops_per_device=float(hlo["dot_flops"]),
        bytes_per_device=float(hlo["traffic_bytes"]),
        xla_cost_flops=float(cost.get("flops", -1.0)),
        xla_cost_bytes=float(cost.get("bytes accessed", -1.0)),
        collectives=hlo["collectives"],
        collective_bytes=float(hlo["collective_bytes_total"]),
        collective_bytes_bf16eq=float(hlo["collective_bytes_bf16eq"]),
        bytes_per_device_bf16eq=float(hlo["traffic_bytes_bf16eq"]),
        while_trips=hlo["while_trips"],
        unknown_trip_whiles=hlo["unknown_trip_whiles"],
        memory={
            k: int(getattr(mem, k))
            for k in (
                "temp_size_in_bytes",
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        },
    )
    if verbose:
        gb = rec["memory"].get("argument_size_in_bytes", 0) / 2**30
        tmp = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
        print(
            f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
            f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
            f"args={gb:.2f}GiB/dev temp={tmp:.2f}GiB/dev "
            f"flops/dev={rec['flops_per_device']:.3e} "
            f"coll={rec['collective_bytes']/2**20:.1f}MiB/dev "
            f"trips={rec['while_trips']}"
        )
        print(f"[dryrun]   memory_analysis: {rec['memory']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs + tiny mesh (subprocess tests)")
    ap.add_argument("--hlo-dir", default="",
                    help="also save zstd-compressed compiled HLO per combo")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_one(arch, shape, mp, smoke=args.smoke,
                                  hlo_dir=args.hlo_dir)
                except Exception as e:  # a failure here is a bug in the system
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "FAILED", "error": repr(e)[:2000],
                    }
                    n_fail += 1
                    print(f"[dryrun] {arch} x {shape} FAILED: {e!r}")
                fname = f"{arch}_{shape}_{rec['mesh']}.json".replace("/", "-")
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(rec, f, indent=2)
    print(f"[dryrun] done, failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
