"""benchmarks/run.py CLI: --only resolution must error on unknown names
instead of silently skipping typos (a misspelled ``--only pool_sim,felt_sim``
used to drop the fleet bench without a word)."""
import sys

import pytest

from benchmarks.run import MODULES, main, select_modules


def test_select_modules_empty_selects_all():
    selected, unknown = select_modules("")
    assert selected == MODULES
    assert unknown == []


def test_select_modules_prefixes():
    selected, unknown = select_modules("pool_sim,scenario_grid")
    assert selected == ["pool_sim_bench", "scenario_grid"]
    assert unknown == []
    # prefix semantics: fig1 matches fig10_adaptation too? no — fig1 is a
    # prefix of both fig1_throughput and fig10_adaptation, and both match
    selected, _ = select_modules("fig1")
    assert selected == ["fig1_throughput", "fig10_adaptation"]


def test_select_modules_reports_unknown():
    selected, unknown = select_modules("pool_sim,felt_sim")
    assert selected == ["pool_sim_bench"]
    assert unknown == ["felt_sim"]


def test_main_errors_on_unknown_name(monkeypatch):
    """The CLI refuses a typo'd --only up front (before importing or
    running any benchmark module) and names the offender."""
    monkeypatch.setattr(
        sys, "argv", ["benchmarks.run", "--only", "pool_sim,felt_sim"]
    )
    with pytest.raises(SystemExit) as exc_info:
        main()
    assert "felt_sim" in str(exc_info.value)
    assert "pool_sim_bench" in str(exc_info.value)  # lists known modules
