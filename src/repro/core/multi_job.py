"""Multi-job scheduling — the paper's stated extension (Sec. III-A: "our
framework can be readily extended to handle multiple jobs").

Jobs arrive over time and COMPETE for the same finite spot pool; each job
runs its own policy instance (chosen by the per-job EG selector state), and
a simple priority mechanism arbitrates the shared capacity:

  * spot supply is allocated in order of *deadline slack* (least-slack
    first): jobs closest to violating their SLO get spot first — the
    textbook EDF-style rule adapted to elastic allocations;
  * on-demand is unlimited (cloud semantics), so contention only reshapes
    the cheap-capacity split.

The scheduler keeps the single-job policy semantics intact: every policy
sees a *virtual* market whose availability is the residual supply after
higher-priority jobs took their share. Utilities therefore remain
comparable with single-job simulation, and Theorem 2 applies per job
unchanged (the pool's utility estimates are computed on each job's
realized residual market).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.job import value_fn
from repro.core.market import Trace
from repro.core.policies import BasePolicy, Obs


@dataclass
class ActiveJob:
    job_id: int
    job: JobConfig
    policy: BasePolicy
    arrival: int
    pred: Optional[np.ndarray] = None      # (T, h+1, 2) absolute-time forecasts
    z: float = 0.0
    n_prev: int = 0
    cost: float = 0.0
    t_complete: Optional[float] = None
    alloc_spot: List[int] = field(default_factory=list)
    alloc_od: List[int] = field(default_factory=list)

    def slack(self, t: int, tput: ThroughputConfig) -> float:
        """Slots to spare if finished at N^max from now on (can be < 0)."""
        remaining = max(self.job.workload - self.z, 0.0)
        h_max = tput.alpha * self.job.n_max + tput.beta
        need = remaining / h_max
        deadline_abs = self.arrival + self.job.deadline
        return (deadline_abs - t) - need

    @property
    def local_t(self) -> int:
        return -1  # set per step by the scheduler


@dataclass
class JobResult:
    job_id: int
    utility: float
    value: float
    cost: float
    completion_time: float
    completed_by_deadline: bool


class MultiJobScheduler:
    """Slot-synchronous scheduler over a shared market trace."""

    def __init__(self, tput: ThroughputConfig, trace: Trace):
        self.tput = tput
        self.trace = trace
        self.active: List[ActiveJob] = []
        self.done: List[JobResult] = []
        self._next_id = 0

    def submit(self, t: int, job: JobConfig, policy: BasePolicy,
               pred: Optional[np.ndarray] = None) -> int:
        policy.reset(job, self.tput)
        aj = ActiveJob(self._next_id, job, policy, arrival=t, pred=pred)
        self.active.append(aj)
        self._next_id += 1
        return aj.job_id

    # ------------------------------------------------------------------
    def step(self, t: int):
        """One market slot: least-slack-first spot arbitration."""
        price = float(self.trace.prices[t])
        supply = int(self.trace.avail[t])
        order = sorted(self.active, key=lambda a: a.slack(t, self.tput))
        for aj in order:
            local_t = t - aj.arrival
            if local_t >= aj.job.deadline:
                continue  # termination config handles it at finalize
            pred = None
            if aj.pred is not None:
                pred = aj.pred[t]
                pred = np.array(pred, copy=True)
                # residual supply for the present slot; forecasts stay global
                pred[0, 1] = min(pred[0, 1], supply)
            obs = Obs(t=local_t, price=price, avail=supply, z_prev=aj.z,
                      n_prev=aj.n_prev, pred=pred)
            n_o, n_s = aj.policy.decide(obs)
            n_s = int(np.clip(n_s, 0, min(supply, aj.job.n_max)))
            n_o = int(np.clip(n_o, 0, aj.job.n_max - n_s))
            n = n_o + n_s
            if 0 < n < aj.job.n_min:
                n_o += aj.job.n_min - n
                n = n_o + n_s
            supply -= n_s

            mu = 1.0 if n == aj.n_prev else (
                self.tput.mu1 if n > aj.n_prev else self.tput.mu2
            )
            if n == 0 and aj.n_prev == 0:
                mu = 1.0
            work = mu * (self.tput.alpha * n + (self.tput.beta if n > 0 else 0.0))
            aj.cost += n_s * price + n_o * aj.job.on_demand_price
            aj.alloc_spot.append(n_s)
            aj.alloc_od.append(n_o)
            if work > 0 and aj.z + work >= aj.job.workload and aj.t_complete is None:
                aj.t_complete = local_t + (aj.job.workload - aj.z) / work
            aj.z = min(aj.z + work, aj.job.workload)
            aj.n_prev = n

        # retire finished / past-deadline jobs
        still = []
        for aj in self.active:
            local_t = t - aj.arrival
            if aj.t_complete is not None:
                self.done.append(self._finalize(aj))
            elif local_t + 1 >= aj.job.deadline:
                self.done.append(self._finalize(aj))
            else:
                still.append(aj)
        self.active = still

    # ------------------------------------------------------------------
    def _finalize(self, aj: ActiveJob) -> JobResult:
        job, tput = aj.job, self.tput
        if aj.t_complete is None:
            h_max = tput.alpha * job.n_max + tput.beta
            dt = (job.workload - aj.z) / h_max
            aj.t_complete = job.deadline + dt
            aj.cost += job.on_demand_price * job.n_max * dt
        value = float(value_fn(job, aj.t_complete))
        return JobResult(
            job_id=aj.job_id, utility=value - aj.cost, value=value,
            cost=aj.cost, completion_time=float(aj.t_complete),
            completed_by_deadline=aj.t_complete <= job.deadline,
        )

    # ------------------------------------------------------------------
    def run(self, t_end: int):
        for t in range(t_end):
            if not self.active:
                continue
            self.step(t)
        for aj in self.active:  # anything left at horizon end
            self.done.append(self._finalize(aj))
        self.active = []
        return self.done
