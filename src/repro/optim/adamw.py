"""AdamW over a pytree (built from scratch — optax is not in this env).

State and updates operate on any pytree; the trainer passes the *LoRA leaf
list* so the base model carries no optimizer state (the paper's N^min memory
argument: base + adapters + optimizer state fit one A100/one v5e shard).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: list
    v: list


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda l: l * scale, tree), norm


def update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.999, eps=1e-8,
           weight_decay=0.0):
    """Returns (new_params, new_state). ``lr`` may be a scalar or traced value."""
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    # three passes keep tree structure handling trivial; XLA CSE dedups under jit
    new_params = jax.tree.map(lambda g, m, v, p: upd(g, m, v, p)[0], grads, state.m, state.v, params)
    new_m = jax.tree.map(lambda g, m, v, p: upd(g, m, v, p)[1], grads, state.m, state.v, params)
    new_v = jax.tree.map(lambda g, m, v, p: upd(g, m, v, p)[2], grads, state.m, state.v, params)
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
