"""Qwen1.5-110B [hf:Qwen/Qwen1.5-0.5B family scaled] — dense GQA with QKV bias."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b",
        arch_type="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=49152,
        vocab_size=152064,
        rope_theta=1_000_000.0,
        qkv_bias=True,
        norm_type="rmsnorm",
        mlp_act="silu",
        source="hf:Qwen/Qwen1.5-0.5B",
    )


def smoke_config() -> ModelConfig:
    return config().reduced()
