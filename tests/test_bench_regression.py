"""Opt-in CI-style perf regression guards for the pool simulator.

The ROADMAP pins the kind-partitioned path at >= 3x the seed monolithic
path, (since the 2-D mesh PR) the sharded path at >= 1x the partitioned
path at Fig. 9/10 scale on multiple devices — the 1000-job sharded-scale
regression (0.63x, retrace-per-call + lane-major scan-boundary transposes)
must not silently return — and (since the selection-engine PR) the
device-resident selection engine at >= 1x the host-loop pipeline it
replaced at the Fig. 9 scale. All guards run a bench config through
``benchmarks/run.py --json`` (the same entry point CI would use) and fail
if their row drops below the bar; the multi-device guard forces 4 host
devices in its subprocess (the forcing flag is forbidden in the main test
process by conftest).

Timing is meaningless under tier-1's parallel/contended conditions, so the
tests are opt-in:

    RUN_BENCH_REGRESSION=1 PYTHONPATH=src python -m pytest -q \
        tests/test_bench_regression.py

Knobs: POOL_SIM_JOBS / POOL_SIM_REPEAT / POOL_SIM_SCALE_JOBS /
POOL_SIM_SCALE_REPEAT / POOL_SIM_MESH / SEL_E2E_JOBS / SEL_E2E_REPEAT /
REGION_E2E_JOBS / REGION_E2E_REPEAT / FLEET_SIM_JOBS / FLEET_SIM_REPEAT
shrink or reshape the workloads (the
guards set small defaults for themselves below; the scenario-grid winner
pins force their own SCENARIO_GRID_* config so the pinned map always
refers to one fixed workload).

Since the fleet PR the guard set also covers the multi-job contention
engine: core.fleet at the 1000-job scale must be no slower than the
MultiJobScheduler host loop AND must reproduce every per-job utility the
numpy oracle computes (fleet_sim_utility_match == 1.0). Since the
scenario-grid PR it also pins the per-regime winner map of a 16-regime
shrunken grid — behavioral, not timing, so the pins are exact. Since the
chaos PR it pins the prediction-failure fallback's value under the forced
storm regime: fallback-on must beat fallback-off on the AHAP lanes and the
trigger/recovery accounting must reconcile.
"""
import json
import os
import subprocess
import sys
import tempfile

import pytest

MIN_SPEEDUP = 3.0
# sharded must be no slower than partitioned at scale; == 1.0 is "no slower"
MIN_SCALE_RATIO = 1.0
# the selection engine must be no slower than the host-loop pipeline it
# replaced at the Fig. 9 scale (prep + simulate + select, end to end)
MIN_ENGINE_RATIO = 1.0

pytestmark = pytest.mark.skipif(
    os.environ.get("RUN_BENCH_REGRESSION", "") != "1",
    reason="perf guard is opt-in: set RUN_BENCH_REGRESSION=1",
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_pool_bench(defaults: dict, force: dict = {},
                    only: str = "pool_sim") -> dict:
    """Drive ``benchmarks.run --only <only> --json`` in a subprocess and
    return the parsed payload. ``defaults`` yield to caller env (workload
    knobs); ``force`` always wins (the device-forcing XLA flag)."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT]
        + env.get("PYTHONPATH", "").split(os.pathsep)
    ).rstrip(os.pathsep)
    for k, v in defaults.items():
        env.setdefault(k, v)
    env.update(force)
    with tempfile.TemporaryDirectory() as td:
        out_json = os.path.join(td, "bench.json")
        # keep the tracked BENCH_pool_sim.json artifact out of reach of the
        # guard's shrunken config
        env["POOL_SIM_JSON"] = os.path.join(td, "pool_sim.json")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run",
             "--only", only, "--json", out_json],
            capture_output=True, text=True, env=env, cwd=ROOT, timeout=1800,
        )
        assert proc.returncode == 0, (
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
        )
        with open(out_json) as f:
            payload = json.load(f)
    assert payload["backend"] == "cpu"
    return payload


def test_partitioned_speedup_at_least_3x_seed():
    # small-but-representative workload; scale rows off to keep this quick
    payload = _run_pool_bench({
        "POOL_SIM_JOBS": "4",
        "POOL_SIM_REPEAT": "3",
        "POOL_SIM_SCALE_REPEAT": "0",
    })
    rows = {r["name"]: r for r in payload["rows"]}
    assert "pool_sim_partitioned_speedup" in rows, sorted(rows)
    speedup = rows["pool_sim_partitioned_speedup"]["derived"]
    assert speedup >= MIN_SPEEDUP, (
        f"partitioned path regressed: {speedup:.2f}x < {MIN_SPEEDUP}x seed\n"
        f"rows: { {n: r['derived'] for n, r in rows.items()} }"
    )
    # the sharded row must be present (single-device fallback included) —
    # it is the row successive PRs track for multi-device scaling
    assert "pool_sim_sharded" in rows, sorted(rows)


def test_sharded_scale_not_slower_than_partitioned_4dev():
    """The 0.63x guard: on multiple devices the sharded path must be no
    slower than single-device partitioned at Fig. 9/10 job counts. Forces 4
    host devices in the bench subprocess (the bench itself runs unchanged);
    the ratio row compares the two paths measured back-to-back in the same
    process, so host-level noise largely cancels."""
    # POOL_SIM_SCALE_REPEAT=0 / POOL_SIM_SCALE_JOBS=0 skip the scale rows
    # elsewhere, but this guard is meaningless without them — force both
    # positive (caller values above zero still shrink the workload)
    def _positive(knob: str, fallback: str) -> str:
        val = os.environ.get(knob, fallback)
        return val if int(val) > 0 else fallback

    payload = _run_pool_bench(
        defaults={
            "POOL_SIM_JOBS": "4",
            "POOL_SIM_REPEAT": "2",
        },
        force={
            "POOL_SIM_SCALE_JOBS": _positive("POOL_SIM_SCALE_JOBS", "1000"),
            "POOL_SIM_SCALE_REPEAT": _positive("POOL_SIM_SCALE_REPEAT", "2"),
            "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"
                          ).strip(),
        },
    )
    assert payload["devices"] == 4, payload["devices"]
    rows = {r["name"]: r for r in payload["rows"]}
    assert "pool_sim_sharded_scale_vs_partitioned" in rows, sorted(rows)
    ratio = rows["pool_sim_sharded_scale_vs_partitioned"]["derived"]
    assert ratio >= MIN_SCALE_RATIO, (
        f"sharded scale path regressed: {ratio:.2f}x < {MIN_SCALE_RATIO}x "
        f"partitioned at {payload['workload']['scale_jobs']} jobs\n"
        f"rows: { {n: r['derived'] for n, r in rows.items()} }"
    )


def test_selection_engine_not_slower_than_host_loop():
    """The engine guard: at the Fig. 9 scale (1000 jobs x 124-lane pool) the
    device-resident selection engine (prep + simulate + select) must be no
    slower than the per-job host-loop pipeline it replaced — per-job
    NoisyPredictor constructions, per-job normalize_utility calls and the
    K-iteration numpy selector loop must never quietly come back.
    SEL_E2E_JOBS in the caller env shrinks the workload for local runs."""
    payload = _run_pool_bench(
        defaults={
            "SEL_E2E_JOBS": "1000",
            "SEL_E2E_REPEAT": "1",
        },
        only="selection_e2e",
    )
    rows = {r["name"]: r for r in payload["rows"]}
    assert "selection_e2e_engine_vs_loop" in rows, sorted(rows)
    ratio = rows["selection_e2e_engine_vs_loop"]["derived"]
    assert ratio >= MIN_ENGINE_RATIO, (
        f"selection engine regressed: {ratio:.2f}x < {MIN_ENGINE_RATIO}x the "
        f"host-loop pipeline\n"
        f"rows: { {n: r['derived'] for n, r in rows.items()} }"
    )
    # both pipelines must land on the same winning policy
    assert rows["selection_e2e_same_winner"]["derived"] == 1.0


def test_region_engine_not_slower_than_host_loop():
    """The regional-engine guard (region e2e PR): at the Fig. 9/10 scale
    regionalized (1000 jobs x 36 region lanes x 3 regions) the streamed
    regional engine — chunked ``prepare_noisy_inputs_regions`` prep
    double-buffered against the sharded region simulation and the fused
    EG scan — must be no slower than the per-(job, region)
    RegionalPredictor host-loop pipeline it replaced, and must land on
    the same winning lane (the two draw bitwise-identical forecasts, so
    same_winner is deterministic). REGION_E2E_JOBS in the caller env
    shrinks the workload for local runs."""
    payload = _run_pool_bench(
        defaults={
            "REGION_E2E_JOBS": "1000",
            "REGION_E2E_REPEAT": "1",
        },
        only="region_e2e",
    )
    rows = {r["name"]: r for r in payload["rows"]}
    assert "region_e2e_engine_vs_loop" in rows, sorted(rows)
    ratio = rows["region_e2e_engine_vs_loop"]["derived"]
    assert ratio >= MIN_ENGINE_RATIO, (
        f"regional engine regressed: {ratio:.2f}x < {MIN_ENGINE_RATIO}x the "
        f"host-loop pipeline\n"
        f"rows: { {n: r['derived'] for n, r in rows.items()} }"
    )
    assert rows["region_e2e_same_winner"]["derived"] == 1.0


# Per-regime winner pins for the scenario grid's forced shrunken config
# (2 avail x 1 sigma x 2 tight x 2 mu x 2 noise = 16 regimes, 8 jobs each,
# full 124-lane pool). The derived column of each winner row is the lane
# INDEX in paper_pool() + rand_deadline_pool() + baseline_specs() order;
# the names are recorded here for the reader. The map is the measured form
# of "the selector adapts": scarce/cheap-restart regimes flip to MSU or
# short-window AHAP lanes, abundant regimes keep the Fig. 9 winner
# ahap(w=5,v=1,s=0.3). Utilities are bitwise-deterministic under tier-1
# conditions (CPU, x64 off; sharded == single-device is pinned), so a
# changed cell means a real behavior change, not noise.
SCENARIO_WINNER_PINS = {
    "a3.5_s0.5_t0.8_m0.9_n0": 122,      # msu
    "a3.5_s0.5_t0.8_m0.9_n0.3": 122,    # msu
    "a3.5_s0.5_t1.15_m0.9_n0": 77,      # ahap(w=5,v=2,s=0.3)
    "a3.5_s0.5_t1.15_m0.9_n0.3": 42,    # ahap(w=4,v=1,s=0.3)
    "a9_s0.5_t0.8_m0.9_n0": 70,         # ahap(w=5,v=1,s=0.3)
    "a9_s0.5_t0.8_m0.9_n0.3": 70,       # ahap(w=5,v=1,s=0.3)
    "a9_s0.5_t1.15_m0.9_n0": 70,        # ahap(w=5,v=1,s=0.3)
    "a9_s0.5_t1.15_m0.9_n0.3": 70,      # ahap(w=5,v=1,s=0.3)
    "a3.5_s0.5_t0.8_m0.7_n0": 28,       # ahap(w=3,v=2,s=0.3)
    "a3.5_s0.5_t0.8_m0.7_n0.3": 70,     # ahap(w=5,v=1,s=0.3)
    "a3.5_s0.5_t1.15_m0.7_n0": 28,      # ahap(w=3,v=2,s=0.3)
    "a3.5_s0.5_t1.15_m0.7_n0.3": 11,    # ahap(w=2,v=1,s=0.7)
    "a9_s0.5_t0.8_m0.7_n0": 21,         # ahap(w=3,v=1,s=0.3)
    "a9_s0.5_t0.8_m0.7_n0.3": 21,       # ahap(w=3,v=1,s=0.3)
    "a9_s0.5_t1.15_m0.7_n0": 5,         # ahap(w=1,v=1,s=0.8)
    "a9_s0.5_t1.15_m0.7_n0.3": 4,       # ahap(w=1,v=1,s=0.7)
}


def test_scenario_grid_winner_pins():
    """The scenario-grid guard: a future PR that silently flips a winner
    map cell must fail here. Drives the bench with a forced 16-regime
    config (the workload knobs always win over caller env so the pins
    mean one fixed workload) and compares every per-regime winner row
    against the recorded map."""
    payload = _run_pool_bench(
        defaults={},
        force={
            "SCENARIO_GRID_JOBS": "8",
            "SCENARIO_GRID_AVAIL": "3.5,9.0",
            "SCENARIO_GRID_SIGMA": "0.5",
            "SCENARIO_GRID_TIGHT": "0.8,1.15",
            "SCENARIO_GRID_MU": "0.9:0.95,0.7:0.85",
            "SCENARIO_GRID_NOISE": "0.0,0.3",
            "SCENARIO_GRID_REPEAT": "1",
        },
        only="scenario_grid",
    )
    rows = {r["name"]: r for r in payload["rows"]}
    assert rows["scenario_grid_regimes"]["derived"] == len(
        SCENARIO_WINNER_PINS
    )
    mismatches = {}
    for key, want in SCENARIO_WINNER_PINS.items():
        row = rows.get(f"scenario_grid_winner__{key}")
        assert row is not None, (key, sorted(rows))
        if int(row["derived"]) != want:
            mismatches[key] = (want, int(row["derived"]))
    assert not mismatches, (
        "scenario-grid winner map changed (regime: expected_idx -> got_idx):"
        f" {mismatches}\n(lane indices are paper_pool + rand_deadline +"
        " baselines order; see benchmarks/scenario_grid.py)"
    )
    # adaptivity itself is part of the pin: several distinct winners
    assert rows["scenario_grid_winner_diversity"]["derived"] >= 5.0


def test_fleet_engine_not_slower_than_host_loop_4dev():
    """The fleet guard (multi-job contention PR): at the 1000-job fleet
    scale, the device-resident contention engine must be no slower than the
    per-job-python-policy MultiJobScheduler host loop, on 4 forced host
    devices — and the two must agree on EVERY per-job utility within the
    repo's python-vs-f32-device tolerance (the window DP's deterministic
    near-tie resolution makes exact agreement achievable; a drop below 1.0
    means compilation-dependent argmax flips are back).
    FLEET_SIM_JOBS in the caller env shrinks the workload for local runs."""
    payload = _run_pool_bench(
        defaults={
            "FLEET_SIM_JOBS": "1000",
            "FLEET_SIM_REPEAT": "1",
        },
        force={
            "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"
                          ).strip(),
        },
        only="fleet_sim",
    )
    assert payload["devices"] == 4, payload["devices"]
    rows = {r["name"]: r for r in payload["rows"]}
    assert "fleet_sim_engine_vs_loop" in rows, sorted(rows)
    ratio = rows["fleet_sim_engine_vs_loop"]["derived"]
    assert ratio >= MIN_ENGINE_RATIO, (
        f"fleet engine regressed: {ratio:.2f}x < {MIN_ENGINE_RATIO}x the "
        f"MultiJobScheduler host loop\n"
        f"rows: { {n: r['derived'] for n, r in rows.items()} }"
    )
    assert rows["fleet_sim_utility_match"]["derived"] == 1.0, (
        "per-job utility parity with the numpy oracle broke:\n"
        f"rows: { {n: r['derived'] for n, r in rows.items()} }"
    )


def test_chaos_fallback_beats_pure_ahap_under_storms():
    """The chaos guard (robustness PR): under the forced preemption-storm +
    stale-predictor regime of benchmarks/chaos_sweep.py, the AHAP lanes
    with the online prediction-failure fallback armed must beat the same
    lanes running pure AHAP on the bad forecasts (chaos_gain > 0), the
    fallback must never fire in the clean intensity-0 case, and the
    trigger/recovery accounting must reconcile. Behavioral, not timing —
    the utilities are bitwise-deterministic under tier-1 conditions.
    The workload knobs always win over caller env so the pin refers to one
    fixed regime (CHAOS_JOBS shrunken from the bench's 64 for speed; the
    gain sign is stable across job counts for this seed set)."""
    payload = _run_pool_bench(
        defaults={},
        force={
            "CHAOS_JOBS": "16",
            "CHAOS_REPEAT": "1",
            "CHAOS_INTENSITY": "0,2",
            "CHAOS_THRESHOLD": "0.5",
            "CHAOS_STORM_LEN": "4",
            "CHAOS_SPIKE": "2.5",
            "CHAOS_LAM": "0.5",
        },
        only="chaos_sweep",
    )
    rows = {r["name"]: r for r in payload["rows"]}
    assert "chaos_gain__s2" in rows, sorted(rows)
    gain = rows["chaos_gain__s2"]["derived"]
    assert gain > 0.0, (
        f"fallback-on no longer beats fallback-off under the forced storm "
        f"regime: gain {gain:.3f} <= 0\n"
        f"rows: { {n: r['derived'] for n, r in rows.items()} }"
    )
    # clean market: the monitor must never fire (threshold discipline)
    assert rows["chaos_triggers__s0"]["derived"] == 0.0
    assert rows["chaos_fallback_frac__s0"]["derived"] == 0.0
    # storms: it fires, and every trigger is matched by a recovery or is
    # still open at the end of the window
    assert rows["chaos_triggers__s2"]["derived"] > 0.0
    assert rows["chaos_events_reconciled__s2"]["derived"] == 1.0
    assert rows["chaos_events_reconciled__s0"]["derived"] == 1.0
