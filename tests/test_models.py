"""Per-architecture smoke tests (spec deliverable f): REDUCED variant of each
family runs one forward + one train step on CPU; output shapes + finiteness.
Plus prefill/decode parity — the core serving invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, TrainConfig, get_smoke_config
from repro.models import decode_step, forward, init_model, prefill
from repro.models.transformer import init_params
from repro.train.step import init_opt_state, make_train_step
from repro.utils.partition import is_lora_path, partition_by_path

B, S = 2, 64


def _batch(cfg, rng, batch=B, seq=S, targets=True):
    out = {}
    if cfg.embed_inputs:
        out["embeds"] = jax.random.normal(rng, (batch, seq, cfg.d_model), jnp.float32) * 0.1
        if targets:
            out["targets"] = jax.random.randint(rng, (batch, seq), 0, cfg.vocab_size)
    else:
        out["tokens"] = jax.random.randint(rng, (batch, seq), 0, cfg.vocab_size)
    if cfg.encoder_only and targets:
        out["loss_mask"] = jax.random.bernoulli(rng, 0.2, (batch, seq))
    return out


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_finite(arch, rng):
    cfg = get_smoke_config(arch)
    params, _ = init_model(rng, cfg)
    batch = _batch(cfg, rng, targets=False)
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step(arch, rng):
    cfg = get_smoke_config(arch)
    params, _ = init_model(rng, cfg)
    tcfg = TrainConfig(total_steps=10, lr=1e-3)
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = init_opt_state(params)
    batch = _batch(cfg, rng)
    p2, opt2, m = step(params, opt, batch)
    assert bool(jnp.isfinite(m.loss)) and float(m.loss) > 0
    assert bool(jnp.isfinite(m.grad_norm))
    # LoRA-only training: base frozen, adapters move
    l0, _ = partition_by_path(params, is_lora_path)
    l2, _ = partition_by_path(p2, is_lora_path)
    b0, _ = partition_by_path(params, lambda p: not is_lora_path(p))
    b2, _ = partition_by_path(p2, lambda p: not is_lora_path(p))
    assert any(bool(jnp.any(a != b)) for a, b in zip(l0, l2))
    assert all(bool(jnp.all(a == b)) for a, b in zip(b0, b2))


@pytest.mark.parametrize(
    "arch",
    ["olmo-1b", "granite-20b", "mixtral-8x7b", "mamba2-370m", "zamba2-2.7b", "qwen2-vl-7b"],
)
def test_prefill_decode_parity(arch, rng):
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # capacity-based MoE drops differ between prefill groups (S tokens)
        # and decode groups (1 token); ample capacity removes drops so the
        # parity check tests the cache machinery, not drop noise
        import dataclasses

        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    params, _ = init_model(rng, cfg)
    full = _batch(cfg, rng, seq=S, targets=False)
    key = "embeds" if cfg.embed_inputs else "tokens"
    pre = {key: full[key][:, : S - 4]}
    logits_full, _ = forward(cfg, params, full)
    lg, cache = prefill(cfg, params, pre, max_len=S)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(logits_full[:, S - 5]), atol=2e-4, rtol=2e-3
    )
    for i in range(S - 4, S):
        stepin = {key: full[key][:, i : i + 1]}
        lg, cache = decode_step(cfg, params, stepin, cache)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(logits_full[:, -1]), atol=2e-4, rtol=2e-3
    )


def test_tiny_model_learns(rng):
    """End-to-end learning signal: loss strictly decreases on repeated batch."""
    cfg = get_smoke_config("olmo-1b")
    params, _ = init_model(rng, cfg)
    tcfg = TrainConfig(total_steps=40, lr=5e-3, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = init_opt_state(params)
    batch = _batch(cfg, rng)
    first = last = None
    for i in range(30):
        params, opt, m = step(params, opt, batch)
        if i == 0:
            first = float(m.loss)
        last = float(m.loss)
    assert last < first - 0.05, (first, last)


def test_sliding_window_limits_context(rng):
    """With SWA, tokens beyond the window cannot influence the output."""
    cfg = get_smoke_config("mixtral-8x7b")
    assert cfg.sliding_window == 64
    cfg = cfg.reduced(sliding_window=16, num_layers=1)
    params, _ = init_model(rng, cfg)
    toks = jax.random.randint(rng, (1, 48), 0, cfg.vocab_size)
    l1, _ = forward(cfg, params, {"tokens": toks})
    toks2 = toks.at[:, :16].set((toks[:, :16] + 7) % cfg.vocab_size)
    l2, _ = forward(cfg, params, {"tokens": toks2})
    # last position attends only to the final 16 tokens -> unchanged
    np.testing.assert_allclose(
        np.asarray(l1[:, -1]), np.asarray(l2[:, -1]), atol=1e-4, rtol=1e-4
    )
    assert bool(jnp.any(jnp.abs(l1[:, 8] - l2[:, 8]) > 1e-3))  # early pos changed
