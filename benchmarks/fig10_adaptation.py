"""Fig. 10: policy-weight dynamics under changing prediction quality.

Four phases (paper): Fixed-Mag+Uniform 10% -> Fixed-Mag+Heavy-Tail 30% ->
Fixed-Mag+Uniform 50% -> 200% noise. The selector re-converges to a new
policy each phase; the weight-history heatmap data is saved to
experiments/fig10_weights.npz.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import PAPER_TPUT, job_stream, timed
from benchmarks.fig9_convergence import _utilities_matrix
from repro.core.policy_pool import paper_pool
from repro.core.selector import init_selector, update

PHASES = [
    ("fixed_uniform", 0.1, 500),
    ("fixed_heavytail", 0.3, 500),
    ("fixed_uniform", 0.5, 500),
    ("fixed_uniform", 2.0, 600),
]


def run() -> list:
    pool = paper_pool()
    M = len(pool)
    K = sum(p[2] for p in PHASES)
    st = init_selector(M, K, track_history=True)
    phase_winners = []
    t0 = 0.0
    for i, (kind, level, n) in enumerate(PHASES):
        (u, un), us = timed(_utilities_matrix, pool, kind, level, n, seed=31 + i)
        t0 += us
        for k in range(n):
            st = update(st, un[k], track_history=True)
        phase_winners.append(int(np.argmax(st.weights)))

    os.makedirs("experiments", exist_ok=True)
    hist = np.stack(st.weight_history)  # (K+1, M)
    np.savez_compressed(
        "experiments/fig10_weights.npz",
        weights=hist.astype(np.float32),
        phase_bounds=np.cumsum([p[2] for p in PHASES]),
        winners=np.array(phase_winners),
        pool_names=np.array([p.name for p in pool]),
    )
    rows = [("fig10_total_jobs", t0, K)]
    for i, w in enumerate(phase_winners):
        rows.append((f"fig10_phase{i}_winner_idx", 0.0, w))
        rows.append((f"fig10_phase{i}_winner_is_ahanp", 0.0, float(pool[w].kind == 1)))
    rows.append(("fig10_distinct_phase_winners", 0.0, float(len(set(phase_winners)))))
    # heavy noise should push weight toward non-predictive AHANP policies
    ahanp_mass_end = float(
        hist[-1, [i for i, p in enumerate(pool) if p.kind == 1]].sum()
    )
    rows.append(("fig10_final_ahanp_weight_mass", 0.0, ahanp_mass_end))
    return rows
