"""Pallas window-DP kernel validation: the fused min-plus DP (interpret mode
executes the real kernel body on CPU) is pinned against the XLA solver paths,
the pure-jnp oracle, and brute force on randomized windows."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp_compat import given, settings, st
from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.window_opt import (
    _solve_xla_batch,
    _unit_cost_table,
    brute_force_window,
    solve_window,
    solve_window_batch,
)
from repro.kernels.ref import window_dp_ref
from repro.kernels.window_dp import window_dp

TPUT = ThroughputConfig(mu1=0.9, mu2=0.95)

job_st = st.builds(
    JobConfig,
    workload=st.floats(5.0, 150.0),
    deadline=st.integers(2, 12),
    n_min=st.integers(1, 3),
    n_max=st.integers(4, 16),
    value=st.floats(10.0, 300.0),
    gamma=st.floats(1.1, 3.0),
)


def _random_window(rng, job, w1):
    prices = rng.uniform(0.05, 1.5, w1).astype(np.float32)
    avail = rng.integers(0, 17, w1).astype(np.int32)
    z0 = float(rng.uniform(0, job.workload))
    std = int(rng.integers(0, w1 + 1))
    return prices, avail, z0, std


def _solve(job, prices, avail, z0, std, backend, table_n=16):
    n_o, n_s, obj = solve_window(
        job, TPUT, jnp.float32(z0), jnp.int32(std), prices, avail,
        job.on_demand_price, table_n=table_n, backend=backend,
    )
    return np.asarray(n_o), np.asarray(n_s), float(obj)


# ---------------------------------------------------------------------------
# kernel == XLA solver (exact: same candidates, same tie-breaking)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), w1=st.integers(1, 6), job=job_st)
def test_window_dp_kernel_matches_xla_solver(seed, w1, job):
    rng = np.random.default_rng(seed)
    prices, avail, z0, std = _random_window(rng, job, w1)
    ref = _solve(job, prices, avail, z0, std, "xla")
    seed_ref = _solve(job, prices, avail, z0, std, "xla-gather")
    pallas = _solve(job, prices, avail, z0, std, "pallas-interpret")
    for got in (seed_ref, pallas):
        np.testing.assert_array_equal(ref[0], got[0])
        np.testing.assert_array_equal(ref[1], got[1])
        assert abs(ref[2] - got[2]) < 1e-5 * (1 + abs(ref[2]))


# ---------------------------------------------------------------------------
# kernel == pure-jnp oracle on raw batched DP inputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,w1,tn", [(1, 6, 16), (8, 6, 16), (13, 3, 5), (40, 1, 4)])
def test_window_dp_kernel_matches_oracle_batched(b, w1, tn):
    rng = np.random.default_rng(b * 131 + w1)
    kw, u1 = tn + 1, w1 * tn + 1
    slot_cost = rng.uniform(0.0, 3.0, (b, w1, kw)).astype(np.float32)
    # price out a random subset of (slot, k) entries like the real table does
    slot_cost = np.where(rng.random((b, w1, kw)) < 0.3, 1.0e9, slot_cost)
    slot_cost[:, :, 0] = 0.0  # buying nothing is always free
    gain = np.cumsum(rng.uniform(0.0, 2.0, (b, u1)), axis=1).astype(np.float32)
    n_tot, obj = window_dp(jnp.asarray(slot_cost), jnp.asarray(gain),
                           interpret=True)
    n_ref, o_ref = window_dp_ref(jnp.asarray(slot_cost), jnp.asarray(gain))
    np.testing.assert_array_equal(np.asarray(n_tot), np.asarray(n_ref))
    np.testing.assert_allclose(np.asarray(obj), np.asarray(o_ref), rtol=1e-6)


def test_window_dp_kernel_under_vmap():
    """The pool simulator calls the kernel per-lane under vmap — the batching
    rule must agree with explicit batching."""
    rng = np.random.default_rng(3)
    b, w1, tn = 6, 4, 8
    slot_cost = rng.uniform(0.0, 3.0, (b, w1, tn + 1)).astype(np.float32)
    slot_cost[:, :, 0] = 0.0
    gain = np.cumsum(rng.uniform(0.0, 2.0, (b, w1 * tn + 1)), axis=1).astype(np.float32)
    direct = window_dp(jnp.asarray(slot_cost), jnp.asarray(gain), interpret=True)
    vmapped = jax.vmap(
        lambda c, g: window_dp(c[None], g[None], interpret=True)
    )(jnp.asarray(slot_cost), jnp.asarray(gain))
    np.testing.assert_array_equal(np.asarray(direct[0]), np.asarray(vmapped[0][:, 0]))
    np.testing.assert_allclose(np.asarray(direct[1]), np.asarray(vmapped[1][:, 0]),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# batched solve (one call per scan slot) == per-lane vmap path
# ---------------------------------------------------------------------------

def _random_lane_batch(rng, job, b, w1):
    prices = rng.uniform(0.05, 1.5, (b, w1)).astype(np.float32)
    avail = rng.integers(0, 17, (b, w1)).astype(np.int32)
    z0 = rng.uniform(0, job.workload, b).astype(np.float32)
    std = rng.integers(0, w1 + 1, b).astype(np.int32)
    return prices, avail, z0, std


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), w1=st.integers(1, 6),
       b=st.integers(1, 9), job=job_st)
def test_solve_window_batch_matches_vmap(seed, w1, b, job):
    """The in-scan batched DP (one (B, w1, tn+1) call — what the pool
    simulator issues per slot) must be BITWISE-equal per lane to vmapping
    the scalar solver, on the XLA and Pallas-interpret backends."""
    rng = np.random.default_rng(seed)
    prices, avail, z0, std = _random_lane_batch(rng, job, b, w1)
    vo, vs, vobj = jax.vmap(
        lambda z, s, p, a: solve_window(
            job, TPUT, z, s, p, a, job.on_demand_price, table_n=16,
            backend="xla",
        )
    )(z0, std, prices, avail)
    for backend in ("xla", "pallas-interpret"):
        bo, bs, bobj = solve_window_batch(
            job, TPUT, z0, std, prices, avail, job.on_demand_price,
            table_n=16, backend=backend,
        )
        np.testing.assert_array_equal(np.asarray(bo), np.asarray(vo), err_msg=backend)
        np.testing.assert_array_equal(np.asarray(bs), np.asarray(vs), err_msg=backend)
        np.testing.assert_allclose(
            np.asarray(bobj), np.asarray(vobj), rtol=1e-6, err_msg=backend
        )


def test_solve_xla_batch_matches_oracle():
    """The lane-batched shifted-slice DP against the pure-jnp scan oracle on
    raw batched tables (same randomized pricing-out as the kernel test)."""
    for b, w1, tn in [(1, 6, 16), (7, 4, 8), (24, 2, 5)]:
        rng = np.random.default_rng(b * 17 + w1)
        kw, u1 = tn + 1, w1 * tn + 1
        slot_cost = rng.uniform(0.0, 3.0, (b, w1, kw)).astype(np.float32)
        slot_cost = np.where(rng.random((b, w1, kw)) < 0.3, 1.0e9, slot_cost)
        slot_cost[:, :, 0] = 0.0
        gain = np.cumsum(rng.uniform(0.0, 2.0, (b, u1)), axis=1).astype(np.float32)
        n_tot, obj = _solve_xla_batch(
            jnp.asarray(slot_cost), jnp.asarray(gain), tn
        )
        n_ref, o_ref = window_dp_ref(jnp.asarray(slot_cost), jnp.asarray(gain))
        np.testing.assert_array_equal(np.asarray(n_tot), np.asarray(n_ref))
        np.testing.assert_allclose(np.asarray(obj), np.asarray(o_ref), rtol=1e-6)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), w1=st.integers(1, 3), b=st.integers(2, 4))
def test_solve_window_batch_matches_brute_force(seed, w1, b):
    """Every lane of a batched solve must achieve the brute-force objective
    (alpha = 1, beta = 0: the achieved plan utility is exact)."""
    from repro.core.job import tilde_value

    rng = np.random.default_rng(seed)
    job = JobConfig(
        workload=float(rng.uniform(5, 40)), deadline=int(rng.integers(2, 8)),
        n_min=1, n_max=int(rng.integers(2, 5)),
        value=float(rng.uniform(10, 100)), gamma=float(rng.uniform(1.2, 2.5)),
    )
    prices, avail, z0, std = _random_lane_batch(rng, job, b, w1)
    n_o, n_s, obj = solve_window_batch(
        job, TPUT, z0, std, prices, avail, job.on_demand_price,
        table_n=job.n_max, backend="xla",
    )
    n_o, n_s = np.asarray(n_o), np.asarray(n_s)
    for i in range(b):
        bf_obj, bf_plan = brute_force_window(
            job, TPUT, float(z0[i]), int(std[i]), prices[i], avail[i],
            job.on_demand_price,
        )
        z = float(z0[i]) + float((n_o[i] + n_s[i]).sum())
        cost = float((n_s[i] * prices[i]).sum()
                     + n_o[i].sum() * job.on_demand_price)
        u = float(tilde_value(job, TPUT, z)) - cost
        tol = 1e-3 * (1 + abs(bf_obj))
        assert abs(u - bf_obj) < tol, (i, u, bf_obj, bf_plan)
        assert abs(float(obj[i]) - bf_obj) < tol, (i, float(obj[i]), bf_obj)


# ---------------------------------------------------------------------------
# kernel == brute force (small windows, exact objective)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), w1=st.integers(1, 3))
def test_window_dp_kernel_matches_brute_force(seed, w1):
    rng = np.random.default_rng(seed)
    job = JobConfig(
        workload=float(rng.uniform(5, 40)), deadline=int(rng.integers(2, 8)),
        n_min=1, n_max=int(rng.integers(2, 5)),
        value=float(rng.uniform(10, 100)), gamma=float(rng.uniform(1.2, 2.5)),
    )
    prices, avail, z0, std = _random_window(rng, job, w1)
    n_o, n_s, obj = _solve(job, prices, avail, z0, std, "pallas-interpret",
                           table_n=job.n_max)
    bf_obj, bf_plan = brute_force_window(
        job, TPUT, z0, std, prices, avail, job.on_demand_price
    )
    # plans may tie; the achieved objective must be exact (alpha = 1, beta = 0)
    from repro.core.job import tilde_value

    z = z0 + float((n_o + n_s).sum())
    cost = float((n_s * prices).sum() + n_o.sum() * job.on_demand_price)
    u = float(tilde_value(job, TPUT, z)) - cost
    tol = 1e-3 * (1 + abs(bf_obj))
    assert abs(u - bf_obj) < tol, (u, obj, bf_obj, bf_plan)
    assert abs(obj - bf_obj) < tol, (obj, bf_obj)


# ---------------------------------------------------------------------------
# cost-table scaffolding sanity (shared by every backend)
# ---------------------------------------------------------------------------

def test_unit_cost_table_feasibility_pricing():
    job = JobConfig(workload=80, deadline=10, n_min=2, n_max=4, value=120.0)
    prices = jnp.asarray([0.5, 2.0, 0.3], jnp.float32)   # slot 1 above p_o
    avail = jnp.asarray([3, 5, 0], jnp.int32)
    slot_cost, spot_units, gain = _unit_cost_table(
        job, TPUT, 0.0, 2, prices, avail, 1.0, tn=4
    )
    slot_cost = np.asarray(slot_cost)
    assert np.all(slot_cost[:, 0] == 0.0)                 # k=0 free everywhere
    assert np.all(slot_cost[:, 1] >= 1.0e8)               # k=1 < n_min infeasible
    assert np.asarray(spot_units).tolist() == [3, 0, 0]   # pricey / past-deadline
    assert slot_cost[2, 2] >= 1.0e8                       # slot 2 beyond horizon
    # slot 0: 2 spot at 0.5 then od; slot 1: all od (price > p_o)
    assert abs(slot_cost[0, 3] - (3 * 0.5)) < 1e-6
    assert abs(slot_cost[1, 2] - 2.0) < 1e-6
    g = np.asarray(gain)
    assert g.shape == (3 * 4 + 1,) and np.all(np.diff(g) >= -1e-5)
