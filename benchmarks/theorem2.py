"""Theorem 2: selector regret <= sqrt(2 K ln M) — measured regret/bound vs K."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.core.selector import init_selector, regret, regret_bound, update


def _run_k(M: int, K: int, seed: int) -> float:
    rng = np.random.default_rng(seed)
    st = init_selector(M, K)
    means = rng.uniform(0.2, 0.8, M)
    for _ in range(K):
        st = update(st, np.clip(rng.normal(means, 0.15), 0, 1))
    return regret(st) / regret_bound(M, K)


def run() -> list:
    rows = []
    worst = 0.0
    for K in (50, 200, 800, 3200):
        ratios, us = timed(
            lambda: [_run_k(112, K, s) for s in range(5)]
        )
        r = float(np.max(ratios))
        worst = max(worst, r)
        rows.append((f"theorem2_regret_over_bound_K{K}", us, r))
    rows.append(("theorem2_bound_holds", 0.0, float(worst <= 1.0)))
    return rows
