"""Fig. 4: the 5-policy toy comparison (workload 20, deadline 5, p^o = 1).

The paper's exact availability row is not recoverable from the figure text,
so we use a reconstructed instance that reproduces the QUALITATIVE result:
  * On-Demand Only  — completes, most expensive (cost 20)
  * Spot-First      — cheapest but INCOMPLETE (misses workload)
  * Progress-Track  — completes, mid cost
  * Perfect-Pred.   — completes at the lowest completing cost
  * Imperfect-Pred. (constant forecast of 6 spot instances) — completes,
    costlier than perfect (prediction error has a price)
Reconfiguration overhead is ignored (mu = 1), as in the paper's example.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.market import from_arrays
from repro.core.offline_opt import solve_offline
from repro.core.policies import AHAP, AHAPParams, MSU, ODOnly, UP
from repro.core.predictor import PerfectPredictor
from repro.core.simulator import simulate

JOB = JobConfig(workload=20, deadline=5, n_min=1, n_max=8, value=100.0,
                gamma=2.0, on_demand_price=1.0)
TPUT = ThroughputConfig(alpha=1.0, beta=0.0, mu1=1.0, mu2=1.0)

PRICES = np.array([0.5, 0.7, 0.3, 0.5, 0.3])
AVAIL = np.array([6, 2, 6, 0, 2])  # sums to 16 < 20: spot-first cannot finish


class SpotFirst(MSU):
    """Pure maximal-spot with NO on-demand fallback (the figure's policy 2)."""

    def decide(self, obs):
        n_s = min(obs.avail, self.job.n_max)
        if obs.z_prev >= self.job.workload:
            return 0, 0
        return 0, n_s


def run() -> list:
    tr = from_arrays(PRICES, AVAIL)
    pred = PerfectPredictor(tr).matrix(5)
    const = pred.copy()
    const[..., 1] = 6.0  # "constant forecast of 6 available spot instances"

    rows = []
    results = {}
    for name, pol, pm in [
        ("od_only", ODOnly(), None),
        ("spot_first", SpotFirst(), None),
        ("progress_track", UP(), None),
        ("perfect_pred", AHAP(AHAPParams(5, 1, 0.9)), pred),
        ("imperfect_pred", AHAP(AHAPParams(5, 1, 0.9)), const),
    ]:
        (r, us) = timed(simulate, pol, JOB, TPUT, tr, pm)
        # in-window cost (what the figure's table shows) + full cost incl.
        # the termination configuration for incomplete jobs
        in_cost = float((r.n_spot * PRICES[: len(r.n_spot)]).sum() + r.n_od.sum())
        results[name] = (r, in_cost)
        rows.append((f"fig4_{name}_cost_in_window", us, in_cost))
        rows.append((f"fig4_{name}_cost_total", us, r.cost))
        rows.append((f"fig4_{name}_workload_by_d", us, r.z_ddl))
        rows.append((f"fig4_{name}_utility", us, r.utility))

    opt = solve_offline(JOB, TPUT, tr)
    rows.append(("fig4_offline_opt_cost", 0.0, opt.cost))

    # qualitative ordering (paper's message), as 1/0 derived flags:
    #   spot-first misses workload in-window; perfect completes at the lowest
    #   total cost; imperfect prediction costs more than perfect (in utility);
    #   on-demand-only is the most expensive completing strategy
    u = {k: v[0].utility for k, v in results.items()}
    ok = (
        (results["od_only"][0].z_ddl >= JOB.workload - 1e-6)
        and (results["spot_first"][0].z_ddl < JOB.workload)
        and (u["perfect_pred"] >= max(u.values()) - 1e-9)
        and (u["imperfect_pred"] <= u["perfect_pred"] + 1e-9)
        and (results["od_only"][0].cost >= max(v[0].cost for v in results.values()) - 1e-9)
        and abs(results["perfect_pred"][0].cost - opt.cost) < 0.75
    )
    rows.append(("fig4_qualitative_ordering_ok", 0.0, float(ok)))
    return rows
