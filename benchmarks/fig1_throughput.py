"""Fig. 1: training throughput vs number of instances.

The paper measured near-linear LoRA fine-tuning scaling on A100s. Without a
cluster we measure the per-microbatch step time of the reduced model on CPU
and project cluster throughput(n) = n * (microbatch samples / step time) *
mu_eff — then fit H(n) = alpha*n + beta and report the linearity (R^2).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Row, timed
from repro.configs import TrainConfig, get_smoke_config
from repro.data import ShardedLMLoader
from repro.models import init_model
from repro.train.step import init_opt_state, make_train_step


def run() -> list:
    cfg = get_smoke_config("llama2-7b")
    tcfg = TrainConfig(seq_len=64, global_batch=4, total_steps=100)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, tcfg))
    loader = ShardedLMLoader(cfg.vocab_size, 4, 64)
    b = loader.batch_at(0)
    params, opt, _ = step(params, opt, b)  # compile
    _, us = timed(lambda: jax.block_until_ready(step(params, opt, b)), repeat=3)

    samples_per_step = tcfg.global_batch
    ns = np.arange(1, 9)
    tput = ns * samples_per_step / (us / 1e6)  # ideal linear scaling
    # paper-style efficiency droop at high n (NCCL overheads): 1.5%/instance
    tput_meas = tput * (1.0 - 0.015 * (ns - 1))
    A = np.stack([ns, np.ones_like(ns)], axis=1).astype(float)
    coef, res, *_ = np.linalg.lstsq(A, tput_meas, rcond=None)
    ss_tot = np.var(tput_meas) * len(ns)
    r2 = 1.0 - (res[0] / ss_tot if len(res) else 0.0)
    return [
        ("fig1_step_time_1inst", us, tput[0]),
        ("fig1_linear_fit_alpha", us, coef[0]),
        ("fig1_linear_fit_r2", us, r2),
    ]
