"""Hypothesis property tests on system invariants."""
import numpy as np
from _hyp_compat import given, settings, st

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.job import normalize_utility, tilde_value, value_fn
from repro.core.market import from_arrays
from repro.core.policies import AHANP, AHANPParams, AHAP, AHAPParams, MSU, ODOnly, UP
from repro.core.predictor import NoisyPredictor, PerfectPredictor
from repro.core.simulator import simulate
from repro.core.throughput import mu_factor
from repro.core.window_opt import solve_window_numpy

job_st = st.builds(
    JobConfig,
    workload=st.floats(5.0, 150.0),
    deadline=st.integers(2, 12),
    n_min=st.integers(1, 3),
    n_max=st.integers(4, 16),
    value=st.floats(10.0, 300.0),
    gamma=st.floats(1.1, 3.0),
)

tput_st = st.builds(
    ThroughputConfig,
    alpha=st.floats(0.5, 2.0),
    beta=st.just(0.0),
    mu1=st.floats(0.5, 1.0),
    mu2=st.floats(0.5, 1.0),
)


@settings(max_examples=40, deadline=None)
@given(job=job_st, tput=tput_st, seed=st.integers(0, 10_000),
       kind=st.integers(0, 4))
def test_simulation_invariants(job, tput, seed, kind):
    if tput.mu1 > tput.mu2:
        tput = ThroughputConfig(tput.alpha, tput.beta, tput.mu2, tput.mu1)
    rng = np.random.default_rng(seed)
    d = job.deadline
    prices = rng.uniform(0.05, 1.5, d)
    avail = rng.integers(0, 17, d)
    tr = from_arrays(prices, avail)
    pol = [AHAP(AHAPParams(3, 2, 0.7)), AHANP(AHANPParams(0.5)), ODOnly(), MSU(), UP()][kind]
    pred = PerfectPredictor(tr).matrix(5) if kind == 0 else None
    r = simulate(pol, job, tput, tr, pred)

    # (5b)-(5e): feasibility at every slot
    assert np.all(r.n_spot <= avail[: len(r.n_spot)])
    assert np.all(r.n_spot >= 0) and np.all(r.n_od >= 0)
    assert np.all(r.n_total <= job.n_max)
    active = r.n_total > 0
    assert np.all(r.n_total[active] >= job.n_min)
    # accounting identities (f32 slack on value comparisons)
    tol = 1e-4 * (1 + job.value)
    assert abs(r.utility - (r.value - r.cost)) < 1e-5
    assert 0.0 <= r.value <= job.value + tol
    assert r.cost >= -1e-9
    assert 0.0 <= r.z_ddl <= job.workload + 1e-5
    assert r.completion_time <= job.gamma * job.deadline + job.workload  # finite
    # normalized utility in [0, 1]
    u = float(normalize_utility(job, r.utility))
    assert 0.0 <= u <= 1.0
    # completing by the deadline <=> full value
    if r.completed_by_deadline:
        assert abs(r.value - job.value) < tol


@settings(max_examples=40, deadline=None)
@given(job=job_st, z=st.floats(0.0, 200.0))
def test_tilde_value_bounds(job, z):
    tput = ThroughputConfig()
    tv = float(tilde_value(job, tput, z))
    assert tv <= job.value + 1e-4 * (1 + job.value)
    # worst case: finish everything post-deadline at full od burn (f32 slack)
    worst = -job.on_demand_price * job.n_max * (job.workload / (tput.alpha * job.n_max))
    assert tv >= worst - 1e-3 * (1 + abs(worst))


@settings(max_examples=30, deadline=None)
@given(job=job_st, t1=st.floats(0, 50), t2=st.floats(0, 50))
def test_value_fn_monotone_nonincreasing(job, t1, t2):
    lo, hi = min(t1, t2), max(t1, t2)
    assert float(value_fn(job, lo)) >= float(value_fn(job, hi)) - 1e-6


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 99999), w1=st.integers(1, 6),
    std=st.integers(0, 6), job=job_st,
)
def test_window_solver_feasibility(seed, w1, std, job):
    rng = np.random.default_rng(seed)
    prices = rng.uniform(0.05, 1.5, w1)
    avail = rng.integers(0, 17, w1)
    n_o, n_s, obj = solve_window_numpy(
        job, ThroughputConfig(), rng.uniform(0, job.workload), std,
        prices, avail, job.on_demand_price,
    )
    tot = n_o + n_s
    assert np.all(n_s <= avail)
    assert np.all(tot <= job.n_max)
    assert np.all((tot == 0) | (tot >= job.n_min))
    assert np.all(tot[min(std, w1):] == 0)  # nothing scheduled past deadline
    assert np.isfinite(obj)


@settings(max_examples=20, deadline=None)
@given(a=st.integers(0, 16), b=st.integers(0, 16),
       mu1=st.floats(0.1, 1.0), mu2=st.floats(0.1, 1.0))
def test_mu_factor_range(a, b, mu1, mu2):
    lo, hi = min(mu1, mu2), max(mu1, mu2)
    t = ThroughputConfig(mu1=lo, mu2=hi)
    m = float(mu_factor(t, a, b))
    assert m == 1.0 or lo - 1e-5 <= m <= hi + 1e-5  # f32 slack
    if a == b:
        assert m == 1.0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 9999), level=st.floats(0.0, 0.5))
def test_noise_matrix_valid(seed, level):
    from repro.core.market import vast_like_trace

    tr = vast_like_trace(seed=seed % 7, days=1)
    M = NoisyPredictor(tr, "magdep_uniform", level, seed=seed).matrix(4)
    assert np.all(M[..., 0] > 0)
    assert np.all(M[..., 1] >= 0) and np.all(M[..., 1] <= 16)
    np.testing.assert_allclose(M[:, 0, 0], tr.prices, atol=1e-9)


# ---------------------------------------------------------------------------
# Fleet waterfall under grid-style random market regimes (core/fleet.py)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 99999), supply=st.integers(0, 40),
       extra=st.integers(0, 25), j=st.integers(1, 12))
def test_waterfall_feasible_and_monotone_in_supply(seed, supply, extra, j):
    """The per-slot supply-grant law: grants are within [0, demand], total
    granted units equal min(total demand, supply) — and are monotone
    non-decreasing in supply, elementwise (grant_i = clip(S - (cum - d_i),
    0, d_i) only grows with S; the sort order is supply-independent)."""
    import jax.numpy as jnp

    from repro.core.fleet import _waterfall

    rng = np.random.default_rng(seed)
    demand = rng.integers(0, 10, j)
    slack = rng.integers(0, 4, j).astype(np.float32)  # coarse: forces ties
    ids = rng.permutation(j).astype(np.int32)
    args = (jnp.asarray(demand, jnp.int32), jnp.asarray(slack),
            jnp.asarray(ids))
    g_lo = np.asarray(_waterfall(*args, supply))
    g_hi = np.asarray(_waterfall(*args, supply + extra))
    for g in (g_lo, g_hi):
        assert np.all(g >= 0) and np.all(g <= demand)
    assert g_lo.sum() == min(demand.sum(), supply)
    assert g_hi.sum() == min(demand.sum(), supply + extra)
    assert np.all(g_hi >= g_lo)  # elementwise monotone in supply


# two fixed kind mixes (with and without AHAP lanes) keep the fleet scan at
# two compiled programs across all hypothesis examples
_FLEET_MIXES = ((0, 0, 1, 3, 4, 5), (1, 2, 3, 4, 5, 5))


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 9999), avail_mean=st.floats(1.0, 12.0),
       price_sigma=st.floats(0.05, 0.6),
       mix=st.sampled_from(_FLEET_MIXES))
def test_fleet_invariants_under_random_regimes(seed, avail_mean,
                                               price_sigma, mix):
    """Fleet-engine invariants under a grid-style random market regime
    (availability level x price volatility, scenario-grid axes): granted
    spot never exceeds the slot supply, jobs outside their live window
    (pre-arrival / past-deadline) and completed ('done') jobs never
    receive grants."""
    from benchmarks.common import PAPER_TPUT
    from repro.core import fleet
    from repro.core.fast_sim import JobArrays
    from repro.core.market import vast_like_trace

    J, T = len(mix), 16
    tr = vast_like_trace(seed=seed % 64, days=T / 48, mean_price=0.7,
                         price_sigma=price_sigma, avail_mean=avail_mean,
                         avail_season_amp=3.0)
    rng = np.random.default_rng(seed)
    jobs = JobArrays(
        workload=rng.uniform(10, 60, J).astype(np.float32),
        deadline=rng.integers(4, 10, J).astype(np.int32),
        n_min=rng.integers(1, 3, J).astype(np.int32),
        n_max=rng.integers(4, 10, J).astype(np.int32),
        value=np.full(J, 120.0, np.float32),
        gamma=np.full(J, 2.0, np.float32),
        p_o=np.full(J, 1.0, np.float32),
    )
    arrivals = rng.integers(0, 8, J)
    rows = {"kind": np.asarray(mix), "omega": np.full(J, 3),
            "v": np.full(J, 1), "sigma": np.full(J, 0.7),
            "rho": np.full(J, 1.0), "cfrac": np.full(J, -1.0)}
    out = fleet.simulate_fleet(rows, jobs, arrivals, PAPER_TPUT,
                               tr.prices, tr.avail)
    ns = np.asarray(out["n_spot"])
    no = np.asarray(out["n_od"])
    assert np.all(ns >= 0) and np.all(no >= 0)
    # grants never exceed the slot supply, summed over the fleet
    assert np.all(ns.sum(axis=0) <= tr.avail)
    # no grants outside each job's live window (local clock t - arrival)
    lt = np.arange(T)[None, :] - arrivals[:, None]
    live = (lt >= 0) & (lt < np.asarray(jobs.deadline)[:, None])
    assert np.all(ns[~live] == 0) and np.all(no[~live] == 0)
    # done jobs never receive grants: once a job completes (local
    # completion time ct), every later local slot allocates nothing
    ct = np.asarray(out["completion_time"])
    completed = np.asarray(out["completed"])
    for j in np.flatnonzero(completed):
        done = lt[j] >= np.ceil(ct[j] - 1e-6)
        assert np.all(ns[j][done] == 0) and np.all(no[j][done] == 0)
