"""Two more framework surfaces in one script:

1. batched SERVING of a fine-tuned checkpoint (prefill + greedy decode with
   the ring-buffer KV cache engine), and
2. MULTI-JOB scheduling — several fine-tuning jobs with different deadlines
   competing for the same spot pool (least-slack-first arbitration, the
   paper's stated Sec. III-A extension).

    PYTHONPATH=src python examples/serve_and_multijob.py
"""
import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.market import vast_like_trace
from repro.core.multi_job import MultiJobScheduler
from repro.core.policies import AHAP, AHAPParams, UP
from repro.core.predictor import ARIMAPredictor
from repro.models import init_model
from repro.serve import Request, ServingEngine

# --- 1. serving -----------------------------------------------------------
cfg = get_smoke_config("mixtral-8x7b")  # MoE + sliding-window attention
params, _ = init_model(jax.random.PRNGKey(0), cfg)
engine = ServingEngine(cfg, params, max_len=128)
rng = np.random.default_rng(0)
prompts = rng.integers(0, cfg.vocab_size, (4, 12))
reqs = [Request(prompt=p, max_new_tokens=8) for p in prompts]
outs = engine.generate_batch(reqs)
print("serving (mixtral smoke, batch=4, SWA ring cache):")
for i, o in enumerate(outs):
    print(f"  req{i}: prompt[:4]={list(prompts[i][:4])} -> generated {list(o)}")

# --- 2. multi-job scheduling ----------------------------------------------
tput = ThroughputConfig(mu1=0.9, mu2=0.95)
market = vast_like_trace(seed=9, days=3, mean_price=0.7, price_sigma=0.5,
                         avail_mean=6.0, avail_season_amp=3.0)
pred = ARIMAPredictor(market).matrix(5)
sched = MultiJobScheduler(tput, market)

jobs = [
    (0, JobConfig(workload=60, deadline=8, n_min=1, n_max=12, value=100.0), "tight"),
    (0, JobConfig(workload=40, deadline=14, n_min=1, n_max=10, value=80.0), "loose"),
    (3, JobConfig(workload=50, deadline=10, n_min=1, n_max=12, value=90.0), "late-arrival"),
]
names = {}
for arr, job, tag in jobs:
    jid = sched.submit(arr, job, AHAP(AHAPParams(3, 1, 0.7)), pred=pred)
    names[jid] = tag

results = sched.run(30)
print("\nmulti-job (shared spot pool, least-slack-first):")
print(f"{'job':>14s} {'utility':>8s} {'cost':>7s} {'T':>6s} {'on-time':>7s}")
for r in sorted(results, key=lambda r: r.job_id):
    print(f"{names[r.job_id]:>14s} {r.utility:8.2f} {r.cost:7.2f} "
          f"{r.completion_time:6.2f} {str(r.completed_by_deadline):>7s}")
