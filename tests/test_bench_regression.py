"""Opt-in CI-style perf regression guard for the pool simulator.

The ROADMAP pins the kind-partitioned path at >= 3x the seed monolithic
path; this test runs a small ``pool_sim_bench`` config through
``benchmarks/run.py --json`` (the same entry point CI would use) and fails
if the speedup drops below the bar.

Timing is meaningless under tier-1's parallel/contended conditions, so the
test is opt-in:

    RUN_BENCH_REGRESSION=1 PYTHONPATH=src python -m pytest -q \
        tests/test_bench_regression.py

Knobs: POOL_SIM_JOBS / POOL_SIM_REPEAT / POOL_SIM_SCALE_JOBS /
POOL_SIM_SCALE_REPEAT shrink the workload (the guard sets small defaults
for itself below).
"""
import json
import os
import subprocess
import sys
import tempfile

import pytest

MIN_SPEEDUP = 3.0

pytestmark = pytest.mark.skipif(
    os.environ.get("RUN_BENCH_REGRESSION", "") != "1",
    reason="perf guard is opt-in: set RUN_BENCH_REGRESSION=1",
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_partitioned_speedup_at_least_3x_seed():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT]
        + env.get("PYTHONPATH", "").split(os.pathsep)
    ).rstrip(os.pathsep)
    # small-but-representative workload; scale rows off to keep this quick
    env.setdefault("POOL_SIM_JOBS", "4")
    env.setdefault("POOL_SIM_REPEAT", "3")
    env.setdefault("POOL_SIM_SCALE_REPEAT", "0")
    with tempfile.TemporaryDirectory() as td:
        out_json = os.path.join(td, "bench.json")
        # keep the tracked BENCH_pool_sim.json artifact out of reach of the
        # guard's shrunken config
        env["POOL_SIM_JSON"] = os.path.join(td, "pool_sim.json")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run",
             "--only", "pool_sim", "--json", out_json],
            capture_output=True, text=True, env=env, cwd=ROOT, timeout=1800,
        )
        assert proc.returncode == 0, (
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
        )
        with open(out_json) as f:
            payload = json.load(f)

    assert payload["backend"] == "cpu"
    rows = {r["name"]: r for r in payload["rows"]}
    assert "pool_sim_partitioned_speedup" in rows, sorted(rows)
    speedup = rows["pool_sim_partitioned_speedup"]["derived"]
    assert speedup >= MIN_SPEEDUP, (
        f"partitioned path regressed: {speedup:.2f}x < {MIN_SPEEDUP}x seed\n"
        f"rows: { {n: r['derived'] for n, r in rows.items()} }"
    )
    # the sharded row must be present (single-device fallback included) —
    # it is the row successive PRs track for multi-device scaling
    assert "pool_sim_sharded" in rows, sorted(rows)
