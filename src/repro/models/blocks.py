"""Decoder blocks: pre-norm transformer (dense/MoE) and Mamba2 residual blocks,
with full-sequence, prefill and decode variants.

All block functions are written to be scanned over stacked layer params
(`transformer.py`), so each returns pytrees with static structure.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.common import apply_norm, init_norm
from repro.models.mlp import apply_mlp, init_mlp
from repro.sharding import shard


# ---------------------------------------------------------------------------
# Transformer block (attention + MLP/MoE)
# ---------------------------------------------------------------------------

def init_transformer_block(key, cfg, dtype, use_moe: Optional[bool] = None) -> dict:
    if use_moe is None:
        use_moe = cfg.arch_type == "moe"
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": init_norm(cfg, dtype),
        "attn": attn.init_attention(k1, cfg, dtype),
        "mlp_norm": init_norm(cfg, dtype),
    }
    if use_moe:
        p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg, dtype)
    return p


def transformer_block_full(cfg, p, h, positions, want_cache: bool = False):
    """Full-sequence (train / forward / prefill).

    Returns (h, aux_loss) or, when ``want_cache``, (h, aux_loss, (k, v)).
    """
    x = apply_norm(cfg, p["attn_norm"], h)
    q, k, v = attn.qkv_project(cfg, p["attn"], x, positions)
    if cfg.m_rope:
        q_pos = positions[..., 0][0]  # (S,) temporal stream for masking
    else:
        q_pos = positions[0]
    out = attn.attend(
        q, k, v, q_pos, q_pos, causal=cfg.causal, window=cfg.sliding_window
    )
    h = h + attn.out_project(cfg, p["attn"], out)
    h = shard(h, "batch", "seq", "embed")

    x = apply_norm(cfg, p["mlp_norm"], h)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        y, aux = moe_lib.apply_moe(cfg, p["moe"], x)
    else:
        y = apply_mlp(cfg, p["mlp"], x)
    h = h + y
    h = shard(h, "batch", "seq", "embed")
    if want_cache:
        return h, aux, (k, v)
    return h, aux


def transformer_block_decode(cfg, p, h1, cache_k, cache_v, index, positions):
    """One-token decode. h1:(B,1,d). Returns (h1, new_k, new_v)."""
    x = apply_norm(cfg, p["attn_norm"], h1)
    q, k, v = attn.qkv_project(cfg, p["attn"], x, positions)
    cache_k, cache_v = attn.write_decode(cache_k, cache_v, k, v, index)
    out = attn.decode_attend(cfg, q, cache_k, cache_v, index + 1)
    h1 = h1 + attn.out_project(cfg, p["attn"], out)

    x = apply_norm(cfg, p["mlp_norm"], h1)
    if "moe" in p:
        y, _ = moe_lib.apply_moe(cfg, p["moe"], x)
    else:
        y = apply_mlp(cfg, p["mlp"], x)
    return h1 + y, cache_k, cache_v


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_mamba_block(key, cfg, dtype) -> dict:
    return {
        "norm": init_norm(cfg, dtype),
        "mamba": ssm_lib.init_mamba(key, cfg, dtype),
    }


def mamba_block_full(cfg, p, h, return_cache=False):
    x = apply_norm(cfg, p["norm"], h)
    if return_cache:
        y, cache = ssm_lib.apply_mamba(cfg, p["mamba"], x, return_cache=True)
        return h + y, cache
    y = ssm_lib.apply_mamba(cfg, p["mamba"], x)
    h = h + y
    return shard(h, "batch", "seq", "embed")


def mamba_block_decode(cfg, p, h1, cache):
    x = apply_norm(cfg, p["norm"], h1)
    y, new_cache = ssm_lib.apply_mamba_decode(cfg, p["mamba"], x, cache)
    return h1 + y, new_cache
