"""Shared building blocks: initializers, norms, activations."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import Param


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def normal_param(key, shape, axes, dtype, stddev: Optional[float] = None) -> Param:
    if stddev is None:
        stddev = 1.0 / np.sqrt(shape[0])  # fan-in
    v = (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)
    return Param(v, tuple(axes))


def zeros_param(shape, axes, dtype) -> Param:
    return Param(jnp.zeros(shape, dtype), tuple(axes))


def ones_param(shape, axes, dtype) -> Param:
    return Param(jnp.ones(shape, dtype), tuple(axes))


def const_param(value, axes) -> Param:
    return Param(jnp.asarray(value), tuple(axes))


# ---------------------------------------------------------------------------
# Norms (computed in f32, cast back)
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: Optional[jnp.ndarray], eps: float) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    return y.astype(dtype)


def layernorm(
    x: jnp.ndarray,
    scale: Optional[jnp.ndarray],
    bias: Optional[jnp.ndarray],
    eps: float,
) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def init_norm(cfg, dtype) -> dict:
    """Norm params per config.norm_type. layernorm_np (OLMo) has no params."""
    d = cfg.d_model
    if cfg.norm_type == "rmsnorm":
        return {"scale": ones_param((d,), (None,), dtype)}
    if cfg.norm_type == "layernorm":
        return {
            "scale": ones_param((d,), (None,), dtype),
            "bias": zeros_param((d,), (None,), dtype),
        }
    if cfg.norm_type == "layernorm_np":
        return {}
    raise ValueError(cfg.norm_type)


def apply_norm(cfg, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm_type == "rmsnorm":
        return rmsnorm(x, params["scale"], cfg.norm_eps)
    if cfg.norm_type == "layernorm":
        return layernorm(x, params["scale"], params["bias"], cfg.norm_eps)
    if cfg.norm_type == "layernorm_np":
        return layernorm(x, None, None, cfg.norm_eps)
    raise ValueError(cfg.norm_type)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def softplus(x):
    return jax.nn.softplus(x)
