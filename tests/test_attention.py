"""Attention unit tests: blockwise==plain, ring cache, GQA, RoPE/M-RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models.rope import apply_m_rope, apply_rope, default_m_positions


def _qkv(rng, b=2, s=256, h=4, kv=2, d=32):
    ks = jax.random.split(rng, 3)
    return (
        jax.random.normal(ks[0], (b, s, h, d)),
        jax.random.normal(ks[1], (b, s, kv, d)),
        jax.random.normal(ks[2], (b, s, kv, d)),
    )


@pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 96)])
def test_blockwise_matches_plain(rng, causal, window):
    q, k, v = _qkv(rng, s=512)
    pos = jnp.arange(512)
    out_plain = attn._plain_attn(q, k, v, pos, pos, causal, window)
    out_block = attn._blockwise_attn(q, k, v, pos, pos, causal, window)
    np.testing.assert_allclose(
        np.asarray(out_block), np.asarray(out_plain), atol=2e-5, rtol=2e-5
    )


def test_ring_positions():
    # after 10 tokens in a width-4 ring: slots hold positions 8,9,6,7
    got = np.asarray(attn.ring_positions(4, jnp.int32(10)))
    np.testing.assert_array_equal(got, [8, 9, 6, 7])
    got = np.asarray(attn.ring_positions(4, jnp.int32(2)))
    np.testing.assert_array_equal(got, [0, 1, -1, -1])
    got = np.asarray(attn.ring_positions(4, jnp.int32(0)))
    np.testing.assert_array_equal(got, [-1, -1, -1, -1])


def test_ring_decode_matches_full_window(rng):
    """Decode through a ring cache == windowed attention over full history."""
    b, h, kv, d, w = 1, 2, 2, 16, 8
    steps = 20

    class C:  # minimal cfg stand-in
        sliding_window = w
        num_kv_heads = kv
        head_dim = d

    ks = jax.random.split(rng, steps * 3).reshape(steps, 3, -1)
    ck = jnp.zeros((b, w, kv, d))
    cv = jnp.zeros((b, w, kv, d))
    khist, vhist = [], []
    for t in range(steps):
        q1 = jax.random.normal(jax.random.PRNGKey(t * 3), (b, 1, h, d))
        k1 = jax.random.normal(jax.random.PRNGKey(t * 3 + 1), (b, 1, kv, d))
        v1 = jax.random.normal(jax.random.PRNGKey(t * 3 + 2), (b, 1, kv, d))
        khist.append(k1)
        vhist.append(v1)
        ck, cv = attn.write_decode(ck, cv, k1, v1, jnp.int32(t))
        out_ring = attn.decode_attend(C, q1, ck, cv, jnp.int32(t + 1))
        kfull = jnp.concatenate(khist, axis=1)
        vfull = jnp.concatenate(vhist, axis=1)
        pos = jnp.arange(t + 1)
        ref = attn._plain_attn(q1, kfull, vfull, jnp.array([t]), pos, True, w)
        np.testing.assert_allclose(
            np.asarray(out_ring), np.asarray(ref), atol=2e-5, rtol=2e-5
        )


def test_write_prefill_ring_layout(rng):
    b, kv, d, w, s = 1, 1, 4, 8, 20
    k = jnp.arange(s, dtype=jnp.float32).reshape(1, s, 1, 1) * jnp.ones((b, s, kv, d))
    ck = jnp.zeros((b, w, kv, d))
    nk, _ = attn.write_prefill(type("C", (), {"sliding_window": w})(), ck, ck, k, k)
    slot_pos = np.asarray(attn.ring_positions(w, jnp.int32(s)))
    for j, p in enumerate(slot_pos):
        assert float(nk[0, j, 0, 0]) == float(p)


def test_gqa_equals_repeated_mha(rng):
    q, k, v = _qkv(rng, s=64, h=4, kv=2)
    pos = jnp.arange(64)
    out = attn._plain_attn(q, k, v, pos, pos, True, None)
    k2 = jnp.repeat(k, 2, axis=2)
    v2 = jnp.repeat(v, 2, axis=2)
    out2 = attn._plain_attn(q, k2, v2, pos, pos, True, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=2e-5, rtol=2e-5)


def test_mrope_equals_rope_for_text(rng):
    x = jax.random.normal(rng, (2, 32, 4, 64))
    pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    r1 = apply_rope(x, pos, 10000.0)
    r2 = apply_m_rope(x, default_m_positions(2, 32), 10000.0, (8, 12, 12))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)


def test_rope_relative_shift_invariance(rng):
    """Attention logits depend only on relative positions under RoPE."""
    q = jax.random.normal(rng, (1, 8, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(9), (1, 8, 1, 64))
    p0 = jnp.arange(8)[None]
    s0 = jnp.einsum(
        "bqhd,bkhd->bqk", apply_rope(q, p0, 1e4), apply_rope(k, p0, 1e4)
    )
    p1 = p0 + 100
    s1 = jnp.einsum(
        "bqhd,bkhd->bqk", apply_rope(q, p1, 1e4), apply_rope(k, p1, 1e4)
    )
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=2e-3, rtol=1e-3)
