"""Structured, seeded fault injection over market traces and forecasts.

The chaos layer is a set of PURE batched transforms applied on the host,
before the data reaches the jitted engines — the engines never learn a
fault happened, which is the point: market faults (preemption storms,
regional blackouts, price spikes) mutate what the market actually *does*,
while the forecast stack keeps saying what the predictor *believed* —
except for its observed-present column (``pred[..., 0, :]``), which
:func:`inject` re-syncs to the faulted market because the present slot is
always observed, never predicted. Predictor faults (``pred_outage`` /
``pred_stale``) instead corrupt the forecast rows ``j >= 1`` directly and
leave the market alone.

Every transform is shape-agnostic over the trailing time axis — ``(T,)``
single traces, ``(K, T)`` per-job window batches
(``engine.prepare_noisy_inputs`` output, ``data.synthetic.
market_regime_batch`` rows), and ``(..., R, T)`` regional tensors for
blackouts — and is the identity outside its window; an empty schedule is
a bitwise no-op (pinned by tests/test_chaos.py hypothesis properties,
along with avail >= 0 / prices >= 0 invariants).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import numpy as np

# market faults hit (prices, avail); forecast faults hit the pred stack
MARKET_KINDS = ("preempt_storm", "blackout", "price_spike")
FORECAST_KINDS = ("pred_outage", "pred_stale")
FAULT_KINDS = MARKET_KINDS + FORECAST_KINDS


@dataclass(frozen=True)
class FaultSpec:
    """One fault window.

    ``kind``       one of :data:`FAULT_KINDS`:

                   - ``preempt_storm`` — availability forced to 0
                   - ``blackout`` — availability forced to 0 in region
                     ``region`` (axis -2 of a regional tensor; ``region <
                     0`` blacks out every region, same as a storm)
                   - ``price_spike`` — prices multiplied by ``magnitude``
                   - ``pred_outage`` — forecast rows ``j >= 1`` zeroed
                     (the predictor went dark; the observed present stays)
                   - ``pred_stale`` — forecast rows ``j >= 1`` frozen at
                     the last pre-window forecast matrix (the predictor
                     stopped refreshing)

    ``start``      first faulted slot (absolute index on the time axis)
    ``length``     window length in slots (clipped at the trace end)
    ``magnitude``  price multiplier for ``price_spike`` (ignored otherwise)
    ``region``     region index for ``blackout`` (ignored otherwise)
    """
    kind: str
    start: int
    length: int
    magnitude: float = 1.0
    region: int = -1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.start < 0 or self.length < 0:
            raise ValueError(
                f"fault window start/length must be >= 0, got "
                f"start={self.start} length={self.length}")
        if self.magnitude < 0:
            raise ValueError(f"magnitude must be >= 0, got {self.magnitude}")


def window_mask(n_slots: int, spec: FaultSpec) -> np.ndarray:
    """(T,) bool mask of the slots inside ``spec``'s window."""
    idx = np.arange(n_slots)
    return (idx >= spec.start) & (idx < spec.start + spec.length)


def inject_market(prices, avail, faults: Sequence[FaultSpec]):
    """Apply the market faults in ``faults`` (others are skipped) to
    ``prices``/``avail`` with a shared trailing time axis. Returns new
    arrays (inputs untouched); dtypes are preserved, so integer
    availability stays integer."""
    prices = np.array(prices, copy=True)
    avail = np.array(avail, copy=True)
    if prices.shape[-1] != avail.shape[-1]:
        raise ValueError(
            f"prices/avail time axes disagree: {prices.shape} vs {avail.shape}")
    n_slots = prices.shape[-1]
    for f in faults:
        if f.kind not in MARKET_KINDS:
            continue
        m = window_mask(n_slots, f)
        if not m.any():
            continue
        if f.kind == "price_spike":
            prices[..., m] = (prices[..., m] * f.magnitude).astype(
                prices.dtype, copy=False)
        elif f.kind == "preempt_storm" or f.region < 0:
            avail[..., m] = 0
        else:  # regional blackout
            if avail.ndim < 2:
                raise ValueError(
                    "blackout with region >= 0 needs a (..., R, T) "
                    f"availability tensor, got shape {avail.shape}")
            avail[..., f.region, m] = 0
    return prices, avail


def inject_forecasts(preds, faults: Sequence[FaultSpec]):
    """Apply the predictor faults in ``faults`` (others are skipped) to a
    ``(..., T, h+1, 2)`` forecast stack. Only the future rows ``j >= 1``
    are touched — row 0 is the observed present, which no predictor outage
    can take away. Returns a new array."""
    preds = np.array(preds, copy=True)
    if preds.ndim < 3:
        raise ValueError(
            f"forecast stack must be (..., T, h+1, 2), got shape {preds.shape}")
    n_slots, h1 = preds.shape[-3], preds.shape[-2]
    future = np.arange(h1) >= 1                      # (h+1,)
    for f in faults:
        if f.kind not in FORECAST_KINDS:
            continue
        m = window_mask(n_slots, f)
        if not m.any():
            continue
        sel = (m[:, None] & future[None, :])[..., None]  # (T, h+1, 1)
        if f.kind == "pred_outage":
            repl = np.zeros((), preds.dtype)
        else:  # pred_stale: replay the last matrix issued before the window
            t_freeze = max(min(f.start, n_slots) - 1, 0)
            repl = preds[..., t_freeze, None, :, :]       # (..., 1, h+1, 2)
        preds = np.where(sel, repl, preds).astype(preds.dtype, copy=False)
    return preds


def sync_present(preds, prices, avail):
    """Re-sync the observed-present column of a forecast stack to a
    (possibly faulted) market: ``pred[..., 0, 0] = prices``,
    ``pred[..., 0, 1] = avail``. Returns a new array."""
    preds = np.array(preds, copy=True)
    preds[..., 0, 0] = prices
    preds[..., 0, 1] = avail
    return preds


def inject(prices, avail, preds, faults: Sequence[FaultSpec]):
    """The one-call composition: market faults, then the present-column
    re-sync (the present is always observed), then the predictor faults.
    Future forecast rows are NOT re-synced to market faults — that is the
    chaos scenario: the market broke and the predictor did not see it
    coming. ``preds=None`` skips the forecast leg. Returns
    ``(prices, avail, preds)`` as new arrays."""
    p, a = inject_market(prices, avail, faults)
    if preds is None:
        return p, a, None
    return p, a, inject_forecasts(sync_present(preds, p, a), faults)


# ---------------------------------------------------------------------------
# Seeded schedules
# ---------------------------------------------------------------------------

def storm_schedule(seed: int, n_slots: int, *, n_storms: int = 2,
                   storm_len: int = 3, spike_mag: float = 1.0,
                   pred_fault: str = "stale") -> Tuple[FaultSpec, ...]:
    """Seeded preemption-storm schedule: ``n_storms`` bursts, one per
    equal segment of the horizon (so storms never overlap), each forcing
    availability to zero for ``storm_len`` slots. ``spike_mag != 1``
    additionally spikes prices over the same windows; ``pred_fault``
    (``"stale"`` / ``"outage"`` / ``None``) aligns a predictor fault with
    each storm — the forced regime of the chaos bench. Deterministic for a
    given (seed, n_slots, knobs)."""
    if pred_fault not in ("stale", "outage", None):
        raise ValueError(f"pred_fault must be 'stale'/'outage'/None, "
                         f"got {pred_fault!r}")
    rng = np.random.default_rng(seed)
    faults = []
    if n_storms <= 0:
        return ()
    seg = max(n_slots // n_storms, 1)
    for i in range(n_storms):
        lo = min(i * seg, n_slots - 1)
        hi = max(min((i + 1) * seg, n_slots) - storm_len, lo)
        start = int(rng.integers(lo, hi + 1))
        faults.append(FaultSpec("preempt_storm", start, storm_len))
        if spike_mag != 1.0:
            faults.append(
                FaultSpec("price_spike", start, storm_len, magnitude=spike_mag))
        if pred_fault is not None:
            faults.append(FaultSpec(f"pred_{pred_fault}", start, storm_len))
    return tuple(faults)


def blackout_schedule(seed: int, n_slots: int, n_regions: int, *,
                      n_events: int = 1,
                      length: int = 4) -> Tuple[FaultSpec, ...]:
    """Seeded regional-blackout schedule for ``simulate_pool_regions*``
    markets: ``n_events`` windows, each zeroing one seeded region's
    availability for ``length`` slots."""
    rng = np.random.default_rng(seed)
    faults = []
    for _ in range(n_events):
        start = int(rng.integers(0, max(n_slots - length, 0) + 1))
        region = int(rng.integers(0, n_regions))
        faults.append(FaultSpec("blackout", start, length, region=region))
    return tuple(faults)
