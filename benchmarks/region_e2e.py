"""End-to-end region-aware selection throughput: the regional engine vs
the host-loop pipeline it replaced.

The workload is the Fig. 9/10 convergence setting regionalized at paper
scale (1000 jobs x the 36-lane region pool x 16 slots x 3 phase-shifted
regions, fixed-magnitude uniform 10% noise). Two pipelines produce the
same selection decision:

  engine   core.engine.simulate_and_select in regional mode — a
           ``prepare_noisy_inputs_regions`` closure streams each job
           chunk's (K, R, d) market tensors + (K, R, d, W1MAX, 2)
           forecast stack (double-buffered: chunk k+1's host prep
           overlaps chunk k's async-dispatched device work), the
           simulate leg is ``simulate_pool_regions_sharded``, and the
           fused normalize + EG lax.scan keeps the (K, M) utility matrix
           device-resident end to end.
  loop     the pre-engine pipeline: per-job ``RegionalPredictor`` /
           ``NoisyPredictor`` constructions (one python predictor per
           (job, region)), the same region simulation, then per-job
           ``normalize_utility`` calls and a K-iteration numpy
           ``selector.update`` loop.

Both pipelines draw identical forecasts (the engine's numpy prep is
bitwise-equal to the per-job constructions, seed convention
``seeds[k] * 1009 + r``), so ``region_e2e_same_winner`` is a
deterministic 1.0, not a statistical one. The headline
``region_e2e_engine_vs_loop`` row is loop-seconds over engine-seconds
(>= 1.0 means the engine pays for itself); the opt-in regression guard
(tests/test_bench_regression.py, RUN_BENCH_REGRESSION=1) pins both at
the 1000-job scale. The prep / simulate / select split is recorded via
StageTimer, plus ``region_e2e_prep_numpy`` vs ``region_e2e_prep_jax``
rows comparing the host-numpy forecast stack against the jitted
batched-PRNG device path (``prep_backend="jax"``). Rows are folded into
BENCH_pool_sim.json (region_e2e rows replaced in place, the rest
untouched).

Env knobs: REGION_E2E_JOBS (default 1000), REGION_E2E_REPEAT (default
2), REGION_E2E_CHUNK (default 256 — the engine's streamed job-chunk
size); POOL_SIM_MESH picks the pool mesh for the sharded region
simulation (single device falls back bitwise to the unsharded path);
POOL_SIM_JSON redirects the JSON artifact.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import (
    PAPER_TPUT,
    StageTimer,
    job_stream_arrays,
    merge_bench_rows,
)
from benchmarks.pool_sim_bench import _JSON_PATH

N_JOBS = int(os.environ.get("REGION_E2E_JOBS", "1000"))
REPEAT = int(os.environ.get("REGION_E2E_REPEAT", "2"))
CHUNK = int(os.environ.get("REGION_E2E_CHUNK", "256"))
N_REGIONS = 3
DEADLINE = 16          # 8 hours of 30-min slots: spans half a phase offset
DELTA_MIG = 1
KIND, LEVEL, SEED = "fixed_uniform", 0.1, 7


def _market():
    from repro.core.region_market import vast_like_regions

    # region_sim's scarce regime, on a trace long enough that 1000 random
    # job windows land all over the diurnal cycle
    return vast_like_regions(
        N_REGIONS, seed=13, days=8,
        phase_hours=(0.0, 8.0, 16.0),
        mean_price=0.7, price_sigma=0.5,
        avail_mean=5.5, avail_season_amp=3.0,
        delta_mig=DELTA_MIG,
    )


def _workload():
    rng = np.random.default_rng(SEED)
    market = _market()
    jobs = job_stream_arrays(rng, N_JOBS, DEADLINE)
    t0s = rng.integers(0, len(market) - DEADLINE - 1, size=N_JOBS)
    seeds = SEED * 100003 + np.arange(N_JOBS)
    return market, jobs, t0s, seeds


def _timeit(fn, repeat: int = REPEAT):
    """(warm-up result, seconds per call at steady state)."""
    out = fn()
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return out, (time.perf_counter() - t0) / repeat


def _loop_pipeline(market, jobs_cfg, t0s, seeds, arrs, n_pol: int):
    """The pre-engine regional host pipeline, end to end (returns the final
    numpy SelectorState). One python predictor per (job, region) — the
    construction cost the batched prep deletes — then the same region
    simulation and the per-job numpy EG loop."""
    from repro.core import fast_sim, selector
    from repro.core.job import normalize_utility
    from repro.core.predictor import NoisyPredictor, RegionalPredictor

    prices, avail, preds = [], [], []
    for t0, s in zip(t0s, seeds):
        w = market.window(int(t0), DEADLINE + 1)
        prices.append(w.prices[:, :DEADLINE])
        avail.append(w.avail[:, :DEADLINE])
        rp = RegionalPredictor(
            w, lambda tr, r, s=s: NoisyPredictor(
                tr, KIND, LEVEL, seed=int(s) * 1009 + r)
        )
        preds.append(rp.matrix(fast_sim.W1MAX - 1)[:, :DEADLINE])
    out = fast_sim.simulate_pool_regions(
        arrs, fast_sim.stack_jobs(jobs_cfg), PAPER_TPUT,
        np.stack(prices).astype(np.float32),
        np.stack(avail).astype(np.int64),
        np.stack(preds).astype(np.float32),
        delta_mig=DELTA_MIG,
    )
    u = np.asarray(out["utility"])
    st = selector.init_selector(n_pol, len(jobs_cfg))
    for k in range(len(jobs_cfg)):
        st = selector.update(
            st, np.asarray(normalize_utility(jobs_cfg[k], u[k]))
        )
    return st


def _update_bench_json(rows, extra):
    """Fold the region_e2e rows into BENCH_pool_sim.json without disturbing
    the other modules' rows (shared merge in benchmarks.common)."""
    merge_bench_rows(_JSON_PATH, "region_e2e", "region_e2e", rows, extra)


def run():
    from repro.core import engine, fast_sim, selector
    from repro.core.policy_pool import region_pool, specs_to_arrays
    from repro.launch.mesh import make_pool_mesh, parse_pool_mesh_shape

    pool = region_pool()
    arrs = specs_to_arrays(pool)
    n_pol = len(pool)
    mesh = make_pool_mesh(
        shape=parse_pool_mesh_shape(os.environ.get("POOL_SIM_MESH", ""))
    )
    market, jobs, t0s, seeds = _workload()
    jobs_cfg = fast_sim.unstack_jobs(jobs)
    units = DEADLINE * n_pol * N_JOBS * N_REGIONS

    prep = lambda backend, lo=0, hi=N_JOBS: engine.prepare_noisy_inputs_regions(
        market, t0s[lo:hi], DEADLINE, KIND, LEVEL, seeds[lo:hi],
        prep_backend=backend,
    )
    engine_run = lambda: engine.simulate_and_select(
        arrs, jobs, PAPER_TPUT, None, None, None,
        mesh=mesh, delta_mig=DELTA_MIG, job_chunk=CHUNK,
        prep=lambda lo, hi: prep("numpy", lo, hi),
    )

    # --- stage split: one full engine pass, prep/simulate/select timed ---
    # separately (NOT double-buffered — the split shows what overlap hides;
    # the total row below is the double-buffered streamed engine)
    st = StageTimer()
    with st.stage("prep"):
        prices, avail, preds = prep("numpy")
    sim = lambda: fast_sim.simulate_pool_regions_sharded(
        arrs, jobs, PAPER_TPUT, prices, avail, preds,
        delta_mig=DELTA_MIG, mesh=mesh,
    )
    with st.stage("simulate", block_on=lambda: sim()["utility"]):
        u_dev = sim()["utility"]
    with st.stage("select", block_on=lambda: engine.select_from_utilities(
            jobs, u_dev, selector.eg_init(n_pol, N_JOBS))[0].weights):
        pass

    res, total_secs = _timeit(engine_run)
    _, prep_np_secs = _timeit(lambda: prep("numpy"))
    _, prep_jax_secs = _timeit(
        lambda: jax.block_until_ready(prep("jax")[2])
    )

    # --- the replaced host-loop pipeline, same draws, measured whole ---
    st_loop, loop_secs = _timeit(
        lambda: _loop_pipeline(market, jobs_cfg, t0s, seeds, arrs, n_pol),
        repeat=1,
    )

    rows = st.rows("region_e2e")
    rows += [
        ("region_e2e_total", total_secs * 1e6, units / total_secs),
        ("region_e2e_loop", loop_secs * 1e6, units / loop_secs),
        ("region_e2e_prep_numpy", prep_np_secs * 1e6, units / prep_np_secs),
        ("region_e2e_prep_jax", prep_jax_secs * 1e6, units / prep_jax_secs),
    ]
    ratio = loop_secs / total_secs
    rows.append(("region_e2e_engine_vs_loop", 0.0, ratio))
    # identical forecast draws + the shared EG update rule: both pipelines
    # must land on the same winning lane (f32 vs f64 EG)
    same = float(res.best_policy() == selector.best_policy(st_loop))
    rows.append(("region_e2e_same_winner", 0.0, same))

    _update_bench_json(rows, {
        "workload": {
            "jobs": N_JOBS, "slots": DEADLINE, "regions": N_REGIONS,
            "policies": n_pol, "delta_mig": DELTA_MIG,
            "job_chunk": CHUNK, "noise": f"{KIND}@{LEVEL:g}",
            "pool": "region_pool(36)",
        },
        "pool_mesh": "x".join(map(str, mesh.devices.shape)),
        "engine_vs_loop": ratio,
        "prep_jax_vs_numpy": prep_np_secs / prep_jax_secs,
        "winner": pool[res.best_policy()].name,
    })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
