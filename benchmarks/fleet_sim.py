"""Fleet-scale multi-job contention throughput: the device engine vs the
host ``MultiJobScheduler`` loop it replaces.

The workload is the Sec. III-A traffic shape at cluster scale: N jobs
(default 1000) arriving within half a deadline of each other — so the
whole fleet is live CONCURRENTLY — and contending for one shared
paper-market spot pool. Per-job policies are drawn from EG selector
weights learned by a pilot ``engine.simulate_and_select`` run (the
select -> admit loop), so the policy mix is whatever the selector actually
converged to, not a hand-picked split. All jobs share one JobConfig
(arrivals differ): the host comparator's AHAP lanes then hit a single
cached window-DP jit entry, which is the FAIR host baseline — distinct
configs would measure recompilation, not scheduling.

Two implementations produce the same per-job utilities:

  engine   core.fleet.simulate_fleet_sharded — one lax.scan over market
           slots, job axis batched (and sharded over the pool mesh),
           least-slack waterfall as sort + cumsum clip.
  loop     core.multi_job.MultiJobScheduler — the numpy oracle: one
           python policy object per job, sorted residual allocation per
           slot.

Headline rows: ``fleet_sim_engine_vs_loop`` (loop-seconds over
engine-seconds; >= 1.0 means the engine pays for itself) and
``fleet_sim_utility_match`` (fraction of jobs whose oracle and engine
utilities agree within 1e-2 — the repo's python-vs-f32-device tolerance).
The opt-in guard (tests/test_bench_regression.py, RUN_BENCH_REGRESSION=1)
pins both at the 1000-job scale. Rows fold into BENCH_pool_sim.json.

Env knobs: FLEET_SIM_JOBS (default 1000), FLEET_SIM_REPEAT (default 2);
POOL_SIM_MESH picks the engine's mesh; POOL_SIM_JSON redirects the JSON.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import (
    PAPER_JOB,
    PAPER_TPUT,
    job_stream_arrays,
    merge_bench_rows,
    paper_market,
)
from benchmarks.pool_sim_bench import _JSON_PATH

N_JOBS = int(os.environ.get("FLEET_SIM_JOBS", "1000"))
REPEAT = int(os.environ.get("FLEET_SIM_REPEAT", "2"))
DEADLINE = PAPER_JOB.deadline
ARRIVAL_SPAN = DEADLINE // 2          # < deadline: every job live at once
HORIZON = ARRIVAL_SPAN + DEADLINE
PILOT_JOBS = 128                      # EG pilot that learns the weights
KIND, LEVEL, SEED = "fixed_uniform", 0.1, 13
UTIL_ATOL = 1e-2                      # python-f64 vs device-f32 tolerance


def _timeit(fn, repeat: int = REPEAT):
    """(warm-up result, steady-state seconds per call)."""
    out = fn()
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return out, (time.perf_counter() - t0) / repeat


def _workload(arrs, n_pol: int, mesh):
    """Shared market window, arrivals, and EG-admitted policy rows."""
    from repro.core import engine, fast_sim

    rng = np.random.default_rng(SEED)
    trace = paper_market(seed=29, days=3).window(0, HORIZON + 1)
    from repro.core.predictor import NoisyPredictor

    pred = NoisyPredictor(trace, KIND, LEVEL, seed=SEED).matrix(
        fast_sim.W1MAX - 1
    )[:HORIZON].astype(np.float32)
    prices = trace.prices[:HORIZON].astype(np.float32)
    avail = trace.avail[:HORIZON].astype(np.int64)
    arrivals = rng.integers(0, ARRIVAL_SPAN, size=N_JOBS)

    # pilot selection: learn EG weights on a small job stream, then admit
    # the whole fleet from them (SelectionResult.admission_rows)
    pilot_trace = paper_market(seed=31, days=40)
    pilot_jobs = job_stream_arrays(rng, PILOT_JOBS, DEADLINE)
    t0s = rng.integers(0, len(pilot_trace) - DEADLINE - 1, size=PILOT_JOBS)
    seeds = SEED * 100003 + np.arange(PILOT_JOBS)
    res = engine.simulate_and_select(
        arrs, pilot_jobs, PAPER_TPUT,
        *engine.prepare_noisy_inputs(
            pilot_trace, t0s, DEADLINE, KIND, LEVEL, seeds
        ),
        mesh=mesh,
    )
    rows, idx = res.admission_rows(arrs, N_JOBS, rng=rng)
    return trace, prices, avail, pred, arrivals, rows, idx


def _loop_fleet(pool, idx, jobs_cfg, arrivals, trace, pred):
    """The numpy oracle, end to end: fresh python policy objects per run
    (submit resets them), utilities in submission order."""
    from repro.core.multi_job import MultiJobScheduler

    sched = MultiJobScheduler(PAPER_TPUT, trace)
    for i in range(N_JOBS):
        sched.submit(int(arrivals[i]), jobs_cfg, pool[int(idx[i])].build(),
                     pred=pred)
    res = {r.job_id: r for r in sched.run(HORIZON)}
    return np.array([res[i].utility for i in range(N_JOBS)])


def run():
    from repro.core import fast_sim, fleet
    from repro.core.policy_pool import (
        baseline_specs,
        paper_pool,
        rand_deadline_pool,
        specs_to_arrays,
    )
    from repro.launch.mesh import make_pool_mesh, parse_pool_mesh_shape

    pool = paper_pool() + rand_deadline_pool() + baseline_specs()
    arrs = specs_to_arrays(pool)
    mesh = make_pool_mesh(
        shape=parse_pool_mesh_shape(os.environ.get("POOL_SIM_MESH", ""))
    )
    trace, prices, avail, pred, arrivals, rows, idx = _workload(
        arrs, len(pool), mesh
    )
    jobs = fast_sim.stack_jobs([PAPER_JOB] * N_JOBS)

    engine_fn = lambda: jax.block_until_ready(
        fleet.simulate_fleet_sharded(
            rows, jobs, arrivals, PAPER_TPUT, prices, avail, pred, mesh=mesh
        )["utility"]
    )
    u_dev, secs_engine = _timeit(engine_fn)

    u_loop, secs_loop = _timeit(
        lambda: _loop_fleet(pool, idx, PAPER_JOB, arrivals, trace, pred)
    )

    diff = np.abs(np.asarray(u_dev) - u_loop)
    match = float(np.mean(diff <= UTIL_ATOL))
    ratio = secs_loop / secs_engine
    # peak concurrency: arrivals span < deadline, so at slot ARRIVAL_SPAN
    # every still-running job is live together
    peak = int(max(
        np.sum((arrivals <= t) & (t < arrivals + DEADLINE))
        for t in range(HORIZON)
    ))

    rows_out = [
        ("fleet_sim_engine", secs_engine * 1e6, N_JOBS / secs_engine),
        ("fleet_sim_loop", secs_loop * 1e6, N_JOBS / secs_loop),
        ("fleet_sim_engine_vs_loop", 0.0, ratio),
        ("fleet_sim_utility_match", 0.0, match),
        ("fleet_sim_peak_concurrency", 0.0, float(peak)),
    ]
    kinds, counts = np.unique(
        np.asarray(rows["kind"]), return_counts=True
    )
    merge_bench_rows(_JSON_PATH, "fleet_sim", "fleet", rows_out, {
        "workload": {
            "jobs": N_JOBS, "slots": HORIZON, "arrival_span": ARRIVAL_SPAN,
            "policies": len(pool), "pilot_jobs": PILOT_JOBS,
            "noise": f"{KIND}@{LEVEL:g}",
        },
        "pool_mesh": "x".join(map(str, mesh.devices.shape)),
        "engine_vs_loop": ratio,
        "utility_match": match,
        "max_abs_utility_diff": float(diff.max()),
        "admitted_kinds": {int(k): int(c) for k, c in zip(kinds, counts)},
    })
    return rows_out


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
