"""Pallas kernel for the CHC window min-plus (tropical) DP (Eq. 10).

Fuses the whole inner solve of ``window_opt.solve_window`` — per-slot
candidate evaluation, argmin choice tracking, objective argmax and the
backtrack — into one kernel, batched over policy x job lanes. The DP state
C (min cost of buying u units so far) lives in a VMEM scratch padded on the
left with tn BIG entries so the candidate C[u-k] + cost[tau, k] is a
*statically shifted slice* per k (no gathers; k and tau loops are unrolled —
w1 <= 6 and tn <= 16 in the paper's pools, so at most ~102 VPU ops over
(LANE_BLOCK, U+1) tiles). The backtrack resolves the per-lane dynamic
``choices[tau, u]`` read with a one-hot reduction over the unit axis, which
vectorizes where a gather would serialize.

Lanes ride the sublane dimension, units the lane dimension: (LB, U+1) tiles
with LB = 8 (f32 sublane tile). The grid iterates lane blocks; ``jax.vmap``
composes on top (the policy-pool simulator calls this per-lane under vmap,
which batches into an extra grid dimension).

Oracle: repro.kernels.ref.window_dp_ref (scan-based min-plus DP); pinned in
tests/test_window_dp_kernel.py against solve_window and brute_force_window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_BIG = 1.0e9

LANE_BLOCK = 8  # f32 sublane tile


def _kernel(cost_ref, gain_ref, ntot_ref, obj_ref, cpad_ref, choice_ref):
    lb, w1, kw = cost_ref.shape           # (LB, w1, tn+1)
    u1 = gain_ref.shape[1]                # U + 1
    u_iota = jax.lax.broadcasted_iota(jnp.int32, (lb, u1), 1)

    # ---- forward min-plus DP over slots ----
    cpad_ref[:, :kw] = jnp.full((lb, kw), _BIG, jnp.float32)
    cpad_ref[:, kw:] = jnp.where(u_iota == 0, 0.0, _BIG)
    for tau in range(w1):
        row = cost_ref[:, tau, :]         # (LB, tn+1)
        best = cpad_ref[:, kw:] + row[:, 0:1]
        bestk = jnp.zeros((lb, u1), jnp.int32)
        for k in range(1, kw):
            # C[u-k] is the padded buffer shifted k to the right
            cand = cpad_ref[:, kw - k : kw - k + u1] + row[:, k : k + 1]
            take = cand < best            # keep smallest k on ties (= argmin)
            best = jnp.where(take, cand, best)
            bestk = jnp.where(take, k, bestk)
        choice_ref[tau] = bestk
        cpad_ref[:, kw:] = best

    # ---- objective argmax over prefix length u ----
    C = cpad_ref[:, kw:]
    obj = jnp.where(C < _BIG / 2, gain_ref[:, :] - C, -jnp.inf)
    obj_ref[:, 0] = jnp.max(obj, axis=1)
    u_cur = jnp.argmax(obj, axis=1).astype(jnp.int32)  # (LB,)

    # ---- backtrack: one-hot select of choices[tau, u_cur] per lane ----
    for tau in range(w1 - 1, -1, -1):
        hit = u_iota == u_cur[:, None]
        k = jnp.sum(jnp.where(hit, choice_ref[tau], 0), axis=1)
        ntot_ref[:, tau] = k
        u_cur = u_cur - k


@functools.partial(jax.jit, static_argnames=("interpret", "block_lanes"))
def window_dp(slot_cost, gain, *, interpret: bool = False,
              block_lanes: int = LANE_BLOCK):
    """Solve B independent CHC window DPs in one fused kernel.

    Args:
      slot_cost: (B, w1, tn+1) f32 — slot_cost[b, tau, k] = cheapest cost of
        buying k units in slot tau for lane b (infeasible k priced at BIG).
      gain: (B, U+1) f32 — Ṽ(z0 + alpha * u) per lane, U = w1 * tn.
      interpret: run through the Pallas interpreter (CPU path).

    Returns:
      n_tot: (B, w1) i32 — optimal units per slot.
      obj:   (B,)    f32 — optimal objective value.
    """
    b, w1, kw = slot_cost.shape
    u1 = gain.shape[1]
    assert u1 == w1 * (kw - 1) + 1, (slot_cost.shape, gain.shape)

    lb = min(block_lanes, b)
    pad = (-b) % lb
    if pad:
        slot_cost = jnp.pad(slot_cost, ((0, pad), (0, 0), (0, 0)))
        gain = jnp.pad(gain, ((0, pad), (0, 0)))
    bp = b + pad

    n_tot, obj = pl.pallas_call(
        _kernel,
        grid=(bp // lb,),
        in_specs=[
            pl.BlockSpec((lb, w1, kw), lambda i: (i, 0, 0)),
            pl.BlockSpec((lb, u1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((lb, w1), lambda i: (i, 0)),
            pl.BlockSpec((lb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, w1), jnp.int32),
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((lb, kw + u1), jnp.float32),   # padded DP state
            pltpu.VMEM((w1, lb, u1), jnp.int32),      # argmin choices
        ],
        interpret=interpret,
    )(slot_cost, gain)
    return n_tot[:b], obj[:b, 0]
