"""Hypothesis property tests on system invariants."""
import numpy as np
from _hyp_compat import given, settings, st

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.job import normalize_utility, tilde_value, value_fn
from repro.core.market import from_arrays
from repro.core.policies import AHANP, AHANPParams, AHAP, AHAPParams, MSU, ODOnly, UP
from repro.core.predictor import NoisyPredictor, PerfectPredictor
from repro.core.simulator import simulate
from repro.core.throughput import mu_factor
from repro.core.window_opt import solve_window_numpy

job_st = st.builds(
    JobConfig,
    workload=st.floats(5.0, 150.0),
    deadline=st.integers(2, 12),
    n_min=st.integers(1, 3),
    n_max=st.integers(4, 16),
    value=st.floats(10.0, 300.0),
    gamma=st.floats(1.1, 3.0),
)

tput_st = st.builds(
    ThroughputConfig,
    alpha=st.floats(0.5, 2.0),
    beta=st.just(0.0),
    mu1=st.floats(0.5, 1.0),
    mu2=st.floats(0.5, 1.0),
)


@settings(max_examples=40, deadline=None)
@given(job=job_st, tput=tput_st, seed=st.integers(0, 10_000),
       kind=st.integers(0, 4))
def test_simulation_invariants(job, tput, seed, kind):
    if tput.mu1 > tput.mu2:
        tput = ThroughputConfig(tput.alpha, tput.beta, tput.mu2, tput.mu1)
    rng = np.random.default_rng(seed)
    d = job.deadline
    prices = rng.uniform(0.05, 1.5, d)
    avail = rng.integers(0, 17, d)
    tr = from_arrays(prices, avail)
    pol = [AHAP(AHAPParams(3, 2, 0.7)), AHANP(AHANPParams(0.5)), ODOnly(), MSU(), UP()][kind]
    pred = PerfectPredictor(tr).matrix(5) if kind == 0 else None
    r = simulate(pol, job, tput, tr, pred)

    # (5b)-(5e): feasibility at every slot
    assert np.all(r.n_spot <= avail[: len(r.n_spot)])
    assert np.all(r.n_spot >= 0) and np.all(r.n_od >= 0)
    assert np.all(r.n_total <= job.n_max)
    active = r.n_total > 0
    assert np.all(r.n_total[active] >= job.n_min)
    # accounting identities (f32 slack on value comparisons)
    tol = 1e-4 * (1 + job.value)
    assert abs(r.utility - (r.value - r.cost)) < 1e-5
    assert 0.0 <= r.value <= job.value + tol
    assert r.cost >= -1e-9
    assert 0.0 <= r.z_ddl <= job.workload + 1e-5
    assert r.completion_time <= job.gamma * job.deadline + job.workload  # finite
    # normalized utility in [0, 1]
    u = float(normalize_utility(job, r.utility))
    assert 0.0 <= u <= 1.0
    # completing by the deadline <=> full value
    if r.completed_by_deadline:
        assert abs(r.value - job.value) < tol


@settings(max_examples=40, deadline=None)
@given(job=job_st, z=st.floats(0.0, 200.0))
def test_tilde_value_bounds(job, z):
    tput = ThroughputConfig()
    tv = float(tilde_value(job, tput, z))
    assert tv <= job.value + 1e-4 * (1 + job.value)
    # worst case: finish everything post-deadline at full od burn (f32 slack)
    worst = -job.on_demand_price * job.n_max * (job.workload / (tput.alpha * job.n_max))
    assert tv >= worst - 1e-3 * (1 + abs(worst))


@settings(max_examples=30, deadline=None)
@given(job=job_st, t1=st.floats(0, 50), t2=st.floats(0, 50))
def test_value_fn_monotone_nonincreasing(job, t1, t2):
    lo, hi = min(t1, t2), max(t1, t2)
    assert float(value_fn(job, lo)) >= float(value_fn(job, hi)) - 1e-6


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 99999), w1=st.integers(1, 6),
    std=st.integers(0, 6), job=job_st,
)
def test_window_solver_feasibility(seed, w1, std, job):
    rng = np.random.default_rng(seed)
    prices = rng.uniform(0.05, 1.5, w1)
    avail = rng.integers(0, 17, w1)
    n_o, n_s, obj = solve_window_numpy(
        job, ThroughputConfig(), rng.uniform(0, job.workload), std,
        prices, avail, job.on_demand_price,
    )
    tot = n_o + n_s
    assert np.all(n_s <= avail)
    assert np.all(tot <= job.n_max)
    assert np.all((tot == 0) | (tot >= job.n_min))
    assert np.all(tot[min(std, w1):] == 0)  # nothing scheduled past deadline
    assert np.isfinite(obj)


@settings(max_examples=20, deadline=None)
@given(a=st.integers(0, 16), b=st.integers(0, 16),
       mu1=st.floats(0.1, 1.0), mu2=st.floats(0.1, 1.0))
def test_mu_factor_range(a, b, mu1, mu2):
    lo, hi = min(mu1, mu2), max(mu1, mu2)
    t = ThroughputConfig(mu1=lo, mu2=hi)
    m = float(mu_factor(t, a, b))
    assert m == 1.0 or lo - 1e-5 <= m <= hi + 1e-5  # f32 slack
    if a == b:
        assert m == 1.0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 9999), level=st.floats(0.0, 0.5))
def test_noise_matrix_valid(seed, level):
    from repro.core.market import vast_like_trace

    tr = vast_like_trace(seed=seed % 7, days=1)
    M = NoisyPredictor(tr, "magdep_uniform", level, seed=seed).matrix(4)
    assert np.all(M[..., 0] > 0)
    assert np.all(M[..., 1] >= 0) and np.all(M[..., 1] <= 16)
    np.testing.assert_allclose(M[:, 0, 0], tr.prices, atol=1e-9)
