"""Fig. 8: impact of spot price volatility."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_JOB, PAPER_TPUT, mean_utilities, paper_market, timed, windows

N_JOBS = 64


def run() -> list:
    rng = np.random.default_rng(3)
    rows = []
    for sigma in (0.2, 0.5, 0.8):
        trace = paper_market(seed=14, price_sigma=sigma)
        jobs = [PAPER_JOB] * N_JOBS
        trs = windows(trace, N_JOBS, PAPER_JOB.deadline, rng)
        u, us = timed(mean_utilities, jobs, trs, PAPER_TPUT)
        for i, n in enumerate(("ahap", "ahanp", "od", "msu", "up")):
            rows.append((f"fig8_sigma{sigma:g}_{n}_utility", us, u[i]))
    return rows
