"""Online GPU provisioning policies (paper Sec. IV + baselines Sec. VI-A).

Conventions: slots are 0-indexed; at slot t the policy observes the current
spot price/availability (and forecasts, if predictive) plus the job progress
Z_{t-1} accumulated so far, and outputs (n_o, n_s). Expected progress by the
*end* of slot t is Z^exp = L/d * (t+1) (Eq. 6).

AHAP (Alg. 1): Committed-Horizon-Control with prediction window omega,
commitment level v, and spot price threshold sigma. The inner problem
(Eq. 10) is solved exactly by window_opt.solve_window. The final decision
averages the plans committed over the last v steps (the paper's Line 14-15
writes a bare sum but describes — and CHC defines — an average).

AHANP (Alg. 3): reactive fallback on indicators z_hat (progress ratio),
p_hat = p^s/(sigma p^o), n_hat (availability change ratio).

Baselines: OD-Only, MSU (maximal spot utilization), UP (uniform progress,
Wu et al. NSDI'24 [16]).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.window_opt import solve_window_numpy


@dataclass
class Obs:
    t: int
    price: float
    avail: int
    z_prev: float
    n_prev: int
    pred: Optional[np.ndarray] = None  # (horizon+1, 2): [j] = forecast t+j


class BasePolicy:
    name = "base"

    def reset(self, job: JobConfig, tput: ThroughputConfig):
        self.job, self.tput = job, tput

    def decide(self, obs: Obs) -> Tuple[int, int]:  # (n_o, n_s)
        raise NotImplementedError

    def _feasible(self, n_o: int, n_s: int, obs: Obs) -> Tuple[int, int]:
        job = self.job
        n_s = int(min(n_s, obs.avail, job.n_max))
        n_o = int(max(n_o, 0))
        total = n_o + n_s
        if total <= 0:
            return 0, 0
        if total < job.n_min:
            # top up with the cheaper source
            need = job.n_min - total
            if obs.price <= job.on_demand_price and obs.avail - n_s >= need:
                n_s += need
            else:
                n_o += need
        if n_o + n_s > job.n_max:
            over = n_o + n_s - job.n_max
            drop_od = min(over, n_o) if obs.price <= job.on_demand_price else 0
            n_o -= drop_od
            over -= drop_od
            n_s -= over
        return int(n_o), int(n_s)


# ---------------------------------------------------------------------------
# AHAP — Algorithm 1
# ---------------------------------------------------------------------------

@dataclass
class AHAPParams:
    omega: int = 3       # prediction window
    v: int = 1           # commitment level (1 <= v <= omega)
    sigma: float = 0.7   # spot price threshold (fraction of p^o)
    # BEYOND-PAPER (Robust-AHAP): discount factor applied to *predicted*
    # future availability (the present is observed). Over-trusting noisy
    # availability forecasts under-provisions on-demand and slips deadlines;
    # rho < 1 hedges. rho = 1 recovers the paper's AHAP exactly.
    rho: float = 1.0


class AHAP(BasePolicy):
    name = "ahap"

    def __init__(self, params: AHAPParams):
        assert 1 <= params.v <= max(params.omega, 1)
        self.p = params

    def reset(self, job, tput):
        super().reset(job, tput)
        self._plans: List[Tuple[int, np.ndarray, np.ndarray]] = []  # (t0, n_o seq, n_s seq)

    def _threshold_plan(self, obs: Obs, pred: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Ahead of schedule: take all spot priced under sigma*p^o (Lines 5-11)."""
        job, p = self.job, self.p
        w1 = p.omega + 1
        n_s = np.zeros(w1, int)
        for j in range(w1):
            price_j = pred[j, 0]
            avail_j = int(pred[j, 1])
            if price_j <= p.sigma * job.on_demand_price and avail_j >= job.n_min:
                n_s[j] = min(avail_j, job.n_max)
        return np.zeros(w1, int), n_s

    def _discounted(self, obs: Obs, w1: int) -> np.ndarray:
        """Forecast window with Robust-AHAP availability pessimism (rho)."""
        pred = np.array(obs.pred[:w1], copy=True)
        if self.p.rho < 1.0:
            pred[1:, 1] = np.floor(self.p.rho * pred[1:, 1])
        return pred

    def decide(self, obs: Obs) -> Tuple[int, int]:
        job, tput, p = self.job, self.tput, self.p
        assert obs.pred is not None, "AHAP needs forecasts"
        w1 = p.omega + 1
        z_exp_end = job.workload / job.deadline * min(obs.t + 1 + p.omega, job.deadline)
        pred = self._discounted(obs, w1)

        if obs.z_prev >= z_exp_end:  # ahead of schedule through the window
            plan_o, plan_s = self._threshold_plan(obs, pred)
        else:  # behind: CHC window problem (Eq. 10)
            slots_to_deadline = max(job.deadline - obs.t, 0)
            plan_o, plan_s, _ = solve_window_numpy(
                job, tput, obs.z_prev, slots_to_deadline,
                pred[:, 0], pred[:, 1], job.on_demand_price,
            )
        self._plans.append((obs.t, np.asarray(plan_o), np.asarray(plan_s)))
        if len(self._plans) > p.v:
            self._plans = self._plans[-p.v :]

        # committed decision: average the last v plans' entries for slot t
        os_, ss_, cnt = 0.0, 0.0, 0
        for t0, po_, ps_ in self._plans:
            j = obs.t - t0
            if 0 <= j < len(po_):
                os_ += po_[j]
                ss_ += ps_[j]
                cnt += 1
        # round-half-up, computed identically to the jnp fast-sim twin
        # (int(round()) is half-to-even and diverges on f32/f64 boundaries)
        n_o = int(math.floor(os_ / max(cnt, 1) + 0.5))
        n_s = int(math.floor(ss_ / max(cnt, 1) + 0.5))
        n_s = min(n_s, obs.avail)  # Line 15: actual availability caps spot
        if n_o + n_s == 0:
            return 0, 0
        return self._feasible(n_o, n_s, obs)


# ---------------------------------------------------------------------------
# AHANP — Algorithm 3
# ---------------------------------------------------------------------------

@dataclass
class AHANPParams:
    sigma: float = 0.7


class AHANP(BasePolicy):
    name = "ahanp"

    def __init__(self, params: AHANPParams):
        self.p = params

    def reset(self, job, tput):
        super().reset(job, tput)
        self._prev_avail: Optional[int] = None

    def decide(self, obs: Obs) -> Tuple[int, int]:
        job, p = self.job, self.p
        z_exp = job.workload / job.deadline * obs.t  # expected by end of slot t-1
        z_hat = obs.z_prev / z_exp if z_exp > 0 else 1.0
        p_hat = obs.price / (p.sigma * job.on_demand_price)
        prev_av = self._prev_avail if self._prev_avail is not None else obs.avail
        if obs.avail == 0:
            n_hat = 0.0
        elif prev_av == 0:
            n_hat = math.inf
        else:
            n_hat = obs.avail / prev_av
        self._prev_avail = obs.avail

        n_prev = obs.n_prev
        if z_hat >= 1.0:
            if n_hat == 0.0:
                n = 0                                          # case 1: idle
            elif n_hat <= 0.5:
                n = max(int(0.5 * n_prev), job.n_min)          # case 2: shrink
            elif n_hat <= 1.0:
                n = n_prev                                     # case 3: hold
            elif p_hat > 1.0:
                n = n_prev                                     # case 4: hold (pricey)
            else:
                n = max(n_prev, obs.avail)                     # case 5: grab cheap spot
        else:
            n = max(2 * n_prev, job.n_min)                     # cases 6-7: double
        if n <= 0:
            return 0, 0
        n = int(np.clip(n, job.n_min, job.n_max))
        n_s = min(obs.avail, n)  # spot-first split (Lines 6-7)
        return self._feasible(n - n_s, n_s, obs)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

class ODOnly(BasePolicy):
    """Constant on-demand allocation sized to finish exactly at the deadline."""

    name = "od_only"

    def decide(self, obs: Obs) -> Tuple[int, int]:
        job, tput = self.job, self.tput
        remaining = max(job.workload - obs.z_prev, 0.0)
        slots_left = job.deadline - obs.t
        if remaining <= 0 or slots_left <= 0:
            return 0, 0
        need = math.ceil(remaining / slots_left / tput.alpha)
        return self._feasible(int(np.clip(need, job.n_min, job.n_max)), 0, obs)


class MSU(BasePolicy):
    """Maximal Spot Utilization: all spot early; on-demand only once the
    remaining slots at N^max would no longer finish the job."""

    name = "msu"

    def decide(self, obs: Obs) -> Tuple[int, int]:
        job, tput = self.job, self.tput
        remaining = max(job.workload - obs.z_prev, 0.0)
        if remaining <= 0:
            return 0, 0
        n_s = min(obs.avail, job.n_max)
        slots_left = job.deadline - obs.t
        h_max = tput.alpha * job.n_max + tput.beta
        panic = remaining > h_max * max(slots_left - 1, 0)
        n_o = 0
        if panic:
            need = math.ceil(remaining / max(slots_left, 1) / tput.alpha)
            n_o = max(0, min(need, job.n_max) - n_s)
        if n_s + n_o == 0:
            return 0, 0
        return self._feasible(n_o, n_s, obs)


class MSUWeak(MSU):
    """The paper's literal MSU: switches to on-demand only when the remaining
    slots at N^max can no longer finish even with zero margin — mu-blind, so
    reconfiguration losses make it miss deadlines under droughts (this is the
    variant the paper's -54.8% headline punishes; our default MSU adds a
    one-slot safety margin and is much stronger)."""

    name = "msu_weak"

    def decide(self, obs: Obs) -> Tuple[int, int]:
        job, tput = self.job, self.tput
        remaining = max(job.workload - obs.z_prev, 0.0)
        if remaining <= 0:
            return 0, 0
        n_s = min(obs.avail, job.n_max)
        slots_left = job.deadline - obs.t
        h_max = tput.alpha * job.n_max + tput.beta
        panic = remaining > h_max * max(slots_left, 0)
        n_o = 0
        if panic:
            n_o = max(0, job.n_max - n_s)
        if n_s + n_o == 0:
            return 0, 0
        return self._feasible(n_o, n_s, obs)


def rand_commit_frac(q: float) -> float:
    """Inverse CDF of the optimal randomized commitment distribution at
    quantile q (float64; callers cast to f32 so the python policies and the
    JAX fast-sim lanes floor the same bits). The ski-rental-optimal density
    on the normalized deadline is p(x) = e^x/(e-1), so
    F^{-1}(q) = log(1 + q (e - 1))."""
    return float(np.log1p(q * (np.e - 1.0)))


def uniform_commit_frac(q: float) -> float:
    """Uniform-commitment quantile function: F^{-1}(q) = q. The naive
    alternative to the ski-rental-optimal family — each pool member commits
    at a uniformly spread fraction of the deadline. Useful as a control for
    how much the optimal commitment density buys (ROADMAP 'grow the cheap
    lane')."""
    return float(q)


@dataclass
class RandDeadlineParams:
    q: float = 0.5  # quantile of the optimal commitment CDF, in (0, 1)
    # commitment fraction override: None derives the ski-rental-optimal
    # fraction from q via rand_commit_frac; any other quantile family
    # (e.g. uniform_commit_frac) precomputes its fraction and passes it here.
    commit_frac: Optional[float] = None


class RandDeadline(BasePolicy):
    """BEYOND-PAPER (arXiv:2601.14612): randomized commitment-threshold
    strategy. All-spot (MSU-style, no panic logic) before the committed
    slot tau = floor(F^{-1}(q) * d); from tau on, on-demand sized to finish
    exactly at the deadline (OD-Only sizing). The randomization lives in
    the *pool*: each member carries one quantile of the optimal commitment
    distribution, and the selector learns which quantile fits the market.

    The jnp twin is fast_sim._rand_rule — tau is computed with the same f32
    multiply + floor so the two commit on exactly the same slot."""

    name = "rand_deadline"

    def __init__(self, params: RandDeadlineParams):
        assert 0.0 <= params.q <= 1.0, params
        self.p = params
        cf = (rand_commit_frac(params.q) if params.commit_frac is None
              else params.commit_frac)
        self.commit_frac = np.float32(cf)

    def decide(self, obs: Obs) -> Tuple[int, int]:
        job, tput = self.job, self.tput
        remaining = max(job.workload - obs.z_prev, 0.0)
        slots_left = job.deadline - obs.t
        if remaining <= 0 or slots_left <= 0:
            return 0, 0
        tau = float(np.floor(self.commit_frac * np.float32(job.deadline)))
        if obs.t >= tau:  # committed: guarantee the deadline on-demand
            need = math.ceil(remaining / max(slots_left, 1) / tput.alpha)
            n_o, n_s = int(np.clip(need, job.n_min, job.n_max)), 0
        else:  # pre-commitment: ride whatever spot there is
            n_o, n_s = 0, min(obs.avail, job.n_max)
        if n_o + n_s == 0:
            return 0, 0
        return self._feasible(n_o, n_s, obs)


# ---------------------------------------------------------------------------
# Multi-region selection (BEYOND-PAPER, SkyNomad arXiv:2601.06520)
# ---------------------------------------------------------------------------

# Region-selection strategy ids (the ``rsel`` slot of the pool encoding).
RSEL_FIXED, RSEL_PRICE, RSEL_AVAIL, RSEL_PRED = 0, 1, 2, 3
N_RSEL = 4
RSEL_NAMES = {0: "fixed", 1: "greedy_price", 2: "greedy_avail",
              3: "pred_horizon"}

# availability-infeasible regions (avail < N^min) are pushed out of the
# argmin with a large additive penalty rather than masked, so a job stuck
# with *every* region infeasible still has a deterministic (cheapest) pick
RSEL_BIG = np.float32(1e6)

# pred_horizon averages a FIXED-width forecast window so the reference and
# the fast lanes score identically regardless of the predictor's horizon:
# shorter forecasts are edge-padded, longer ones trimmed. Must equal
# fast_sim.W1MAX (asserted there), which pads its prediction inputs the
# same way (prepare_inputs_regions).
RSEL_PRED_WINDOW = 6


@dataclass
class RegionSelectorParams:
    strategy: int = RSEL_PRICE   # one of RSEL_*
    margin: float = 0.0          # hysteresis: switch only if better by this


class RegionSelector:
    """Reference per-slot region chooser — the python twin of the vectorized
    score + hysteresis step inside fast_sim.simulate_pool_regions.

    Scores are LOWER-better, computed in float32 so the f32 fast-sim lanes
    and this reference make identical switch decisions:

      fixed         all-zero (stay wherever the job was placed)
      greedy_price  observed price, +RSEL_BIG where avail < N^min
      greedy_avail  -observed availability
      pred_horizon  mean over the forecast window of predicted price,
                    +RSEL_BIG where predicted avail < N^min

    The first ``step`` places the job at the argmin for free (initial
    placement is not a migration); afterwards a switch to the argmin region
    happens only when its score beats the current region's by more than
    ``margin`` (hysteresis — prevents thrash on noisy scores) and no
    checkpoint transfer is already in flight. A switch starts a migration of
    ``delta_mig`` slots during which the job holds zero instances.
    """

    def __init__(self, params: Optional[RegionSelectorParams] = None):
        self.p = params or RegionSelectorParams()
        assert self.p.strategy in RSEL_NAMES, self.p

    def reset(self, job: JobConfig, delta_mig: int):
        self.job, self.delta_mig = job, int(delta_mig)
        self.cur: Optional[int] = None
        self.mig_left = 0

    def scores(self, prices_t, avail_t, pred_t=None) -> np.ndarray:
        """(R,) float32 scores for one slot. ``pred_t`` is the (R, h+1, 2)
        forecast made this slot (required for pred_horizon)."""
        s, n_min = self.p.strategy, self.job.n_min
        prices_t = np.asarray(prices_t, np.float32)
        avail_t = np.asarray(avail_t)
        if s == RSEL_FIXED:
            return np.zeros(len(prices_t), np.float32)
        if s == RSEL_PRICE:
            dead = (avail_t < n_min).astype(np.float32)
            return (prices_t + RSEL_BIG * dead).astype(np.float32)
        if s == RSEL_AVAIL:
            return -avail_t.astype(np.float32)
        assert pred_t is not None, "pred_horizon needs forecasts"
        pred_t = np.asarray(pred_t, np.float32)[:, :RSEL_PRED_WINDOW]
        if pred_t.shape[1] < RSEL_PRED_WINDOW:  # edge-pad like the fast path
            pad = np.repeat(pred_t[:, -1:],
                            RSEL_PRED_WINDOW - pred_t.shape[1], axis=1)
            pred_t = np.concatenate([pred_t, pad], axis=1)
        dead = (pred_t[..., 1] < np.float32(n_min)).astype(np.float32)
        eff = pred_t[..., 0] + RSEL_BIG * dead          # (R, RSEL_PRED_WINDOW)
        return eff.mean(axis=-1, dtype=np.float32)

    def step(self, sc: np.ndarray):
        """Consume one slot's scores -> (region, migrating, switched)."""
        best = int(np.argmin(sc))
        if self.cur is None:  # initial placement, free
            self.cur = best
            return self.cur, False, False
        switched = (
            best != self.cur
            and self.mig_left == 0
            and bool(np.float32(sc[best]) + np.float32(self.p.margin)
                     < np.float32(sc[self.cur]))
        )
        if switched:
            self.cur = best
            self.mig_left = self.delta_mig
        else:
            self.mig_left = max(self.mig_left - 1, 0)
        return self.cur, self.mig_left > 0, switched


class UP(BasePolicy):
    """Uniform Progress (Wu et al. [16]): track the L/d reference line; spot
    when available, on-demand only when behind and spot is insufficient."""

    name = "up"

    def decide(self, obs: Obs) -> Tuple[int, int]:
        job, tput = self.job, self.tput
        remaining = max(job.workload - obs.z_prev, 0.0)
        if remaining <= 0:
            return 0, 0
        rate = job.workload / job.deadline
        deficit = max(0.0, rate * obs.t - obs.z_prev)
        need = math.ceil((rate + deficit) / tput.alpha)
        need = int(np.clip(need, job.n_min, job.n_max))
        n_s = min(obs.avail, need)
        n_o = need - n_s if deficit > 0 else 0
        if n_s + n_o == 0 and deficit > 0:
            n_o = need
        if n_s + n_o == 0:
            return 0, 0
        return self._feasible(n_o, n_s, obs)
