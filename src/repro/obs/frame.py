"""TelemetryFrame: the typed host-side view over the ``tel_*`` series.

The engines emit telemetry as FLAT ``tel_*`` keys in their result dicts —
one extra stacked scan output per series, same leading axes as the
``n_od``/``n_spot`` histories ((P, T) per-lane, (J, P, T) pool-of-jobs,
(J, T) fleet) — so every piece of existing result plumbing
(``fast_sim._scatter_merge``, shard_map out_specs, padding drops, the
fleet's submission-order reorder) carries them with zero special cases.
This module assembles the flat keys into one NamedTuple on the host.

Per-slot semantics (all sampled AFTER the slot executed):

==============  ============================================================
``spot_cost``   f32, ``n_spot * price`` on active slots (0 otherwise)
``od_cost``     f32, ``n_od * p_o`` on active slots
``progress``    f32, cumulative work ``z`` at the end of the slot
``active``      bool, the slot executed (live and not yet complete)
``reconfig_up``   bool, allocation grew vs the previous slot (pays mu1)
``reconfig_down`` bool, allocation shrank vs the previous slot (pays mu2)
``preempted``   bool, shrink forced by supply: the slot's available spot
                (fleet: the waterfall grant) fell below last slot's
                allocation — the spot-market preemption event GFS-style
                predictive management keys on
==============  ============================================================

Fleet runs add the waterfall series (``None`` for pool runs):

==============  ============================================================
``demand``      i32, spot demand at full supply (pre-waterfall)
``grant``       i32, spot actually granted by the waterfall
``slack``       f32, the least-slack-first key (0 where not live)
``rank``        i32, position in the demanders-only grant order
                (-1 when the job demanded nothing that slot)
``starved``     bool, live, demanded, and granted strictly less
==============  ============================================================

Runs with the prediction-failure monitor armed (``fallback=`` a
``repro.chaos.FallbackConfig``) add two more series (``None`` otherwise;
cheap lanes, which carry no monitor, report all-zero rows):

==================  ========================================================
``fallback_active`` bool, the lane ran the prediction-free AHANP rule this
                    slot (its forecast-error EWMA exceeded the threshold)
``pred_err``        f32, that realized-forecast-error EWMA after the slot
==================  ========================================================

Region runs (``simulate_pool_regions[_sharded]`` with ``collect=True``)
add the migration series (``None`` for single-region runs):

==============  ============================================================
``region``      i32, the region occupied this slot (post region-selector
                step — matches the ``region`` result leaf exactly)
``migrated``    bool, a cross-region switch was *committed* this slot (the
                checkpoint transfer starts; the lane holds zero instances
                for the next ``delta_mig`` slots). Slot sums equal the
                ``migrations`` result leaf — ``obs.ledger.
                migration_reconciliation`` checks that invariant.
==============  ============================================================
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

TEL_PREFIX = "tel_"

# slot series every engine emits (fast_sim._slot_telemetry order)
SLOT_KEYS = ("tel_spot_cost", "tel_od_cost", "tel_progress", "tel_active",
             "tel_up", "tel_down", "tel_preempt")
# waterfall series only the fleet engine emits
FLEET_KEYS = ("tel_demand", "tel_grant", "tel_slack", "tel_rank",
              "tel_starved")
# prediction-failure monitor series, only when fallback= is armed
FALLBACK_KEYS = ("tel_fallback", "tel_pred_err")
# migration series only the region engine emits (fast_sim._TEL_REGION)
REGION_KEYS = ("tel_region", "tel_migration")


class TelemetryFrame(NamedTuple):
    """Host-numpy per-slot series; leading axes follow the source engine."""
    n_spot: np.ndarray
    n_od: np.ndarray
    spot_cost: np.ndarray
    od_cost: np.ndarray
    progress: np.ndarray
    active: np.ndarray
    reconfig_up: np.ndarray
    reconfig_down: np.ndarray
    preempted: np.ndarray
    demand: Optional[np.ndarray] = None
    grant: Optional[np.ndarray] = None
    slack: Optional[np.ndarray] = None
    waterfall_rank: Optional[np.ndarray] = None
    starved: Optional[np.ndarray] = None
    fallback_active: Optional[np.ndarray] = None
    pred_err: Optional[np.ndarray] = None
    region: Optional[np.ndarray] = None
    migrated: Optional[np.ndarray] = None


def has_telemetry(out: dict) -> bool:
    """Whether ``out`` came from a ``collect=True`` run."""
    return all(k in out for k in SLOT_KEYS)


def frame_from_out(out: dict) -> TelemetryFrame:
    """Assemble a TelemetryFrame from an engine result dict (``collect=True``
    run of ``simulate_pool[_jobs][_sharded]`` / ``simulate_fleet[_sharded]``
    / a ``SelectionResult.sim_out``). Raises KeyError if the run did not
    collect."""
    missing = [k for k in SLOT_KEYS if k not in out]
    if missing:
        raise KeyError(
            f"result has no telemetry ({missing[0]} absent) — "
            "was the engine called with collect=True?"
        )
    a = lambda k: np.asarray(out[k])
    return TelemetryFrame(
        n_spot=a("n_spot"), n_od=a("n_od"),
        spot_cost=a("tel_spot_cost"), od_cost=a("tel_od_cost"),
        progress=a("tel_progress"), active=a("tel_active"),
        reconfig_up=a("tel_up"), reconfig_down=a("tel_down"),
        preempted=a("tel_preempt"),
        demand=a("tel_demand") if "tel_demand" in out else None,
        grant=a("tel_grant") if "tel_grant" in out else None,
        slack=a("tel_slack") if "tel_slack" in out else None,
        waterfall_rank=a("tel_rank") if "tel_rank" in out else None,
        starved=a("tel_starved") if "tel_starved" in out else None,
        fallback_active=a("tel_fallback") if "tel_fallback" in out else None,
        pred_err=a("tel_pred_err") if "tel_pred_err" in out else None,
        region=a("tel_region") if "tel_region" in out else None,
        migrated=a("tel_migration") if "tel_migration" in out else None,
    )
