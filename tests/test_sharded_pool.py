"""Sharded-vs-single-device parity for the policy-pool simulator.

``simulate_pool_jobs_sharded`` / ``simulate_pool_regions_sharded`` must be
BITWISE-equal to their unsharded twins — per-(job, lane) cells are
independent and every op is elementwise over both grid axes, so laying the
grid over a device mesh (jobs-only 1-D, lanes-only, or the 2-D
(jobs, lanes) mesh) may not change a single bit. The multi-device half runs
in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(conftest forbids the forcing flag in the main test process), covering job
counts that divide the mesh, need padding, and undershoot the device count,
and lane partitions (15 AHAP / 9 cheap) that pad on every lane-axis layout.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

# Runs inside the forced-4-device subprocess. Lane counts (15 AHAP + 3
# AHANP + 3 RAND + 3 baselines = 24 lanes, partitions 15/9) exercise the
# kind partition AND lane-axis padding on both the (1, 4) and (2, 2)
# meshes (15 % 4 = 3, 9 % 4 = 1, 15 % 2 = 1, 9 % 2 = 1); job counts 1/3/5
# exercise the under-, non-dividing- and padding paths of the jobs axes.
_CHILD = r"""
import numpy as np
import jax

assert jax.device_count() == 4, jax.devices()

from benchmarks.common import job_stream
from repro.configs.base import ThroughputConfig
from repro.core import fast_sim
from repro.core.market import vast_like_trace
from repro.core.policy_pool import (
    baseline_specs, paper_pool, rand_deadline_pool, region_pool,
    specs_to_arrays,
)
from repro.core.predictor import NoisyPredictor, RegionalPredictor
from repro.core.region_market import vast_like_regions
from repro.launch.mesh import make_pool_mesh

TPUT = ThroughputConfig(mu1=0.9, mu2=0.95)
pool = (paper_pool(omegas=(2, 3), sigmas=(0.3, 0.7, 0.9))
        + rand_deadline_pool((0.25, 0.5, 0.75)) + baseline_specs())
arrs = specs_to_arrays(pool)
n_ahap = int((arrs["kind"] == 0).sum())
assert n_ahap % 4 and (len(pool) - n_ahap) % 4, (n_ahap, len(pool))
rng = np.random.default_rng(0)
d = 10
MESHES = [None, (1, 4), (2, 2)]  # default 1-D jobs, lanes-only, 2-D
for n_jobs in (1, 3, 5):
    jobs = list(job_stream(rng, n_jobs, deadline=d))
    traces = [vast_like_trace(seed=40 + i, days=1).window(0, d + 1)
              for i in range(n_jobs)]
    prices = np.stack([t.prices[:d] for t in traces]).astype(np.float32)
    avail = np.stack([t.avail[:d] for t in traces]).astype(np.int64)
    preds = np.stack([
        NoisyPredictor(t, "fixed_uniform", 0.2, seed=i).matrix(
            fast_sim.W1MAX - 1
        )[:d]
        for i, t in enumerate(traces)
    ]).astype(np.float32)
    stacked = fast_sim.stack_jobs(jobs)
    base = fast_sim.simulate_pool_jobs(arrs, stacked, TPUT, prices, avail, preds)
    for shape in MESHES:
        sh = fast_sim.simulate_pool_jobs_sharded(
            arrs, stacked, TPUT, prices, avail, preds,
            mesh=None if shape is None else make_pool_mesh(shape=shape),
        )
        for k in base:
            np.testing.assert_array_equal(
                np.asarray(base[k]), np.asarray(sh[k]),
                err_msg=f"{k} n_jobs={n_jobs} mesh={shape}",
            )

# collect=True: the telemetry-carrying program shards bitwise too, and its
# shared keys match the collect=False run (one config bounds the runtime;
# the loop leaves n_jobs=5 inputs in scope)
tel = fast_sim.simulate_pool_jobs(
    arrs, stacked, TPUT, prices, avail, preds, collect=True)
tel_sh = fast_sim.simulate_pool_jobs_sharded(
    arrs, stacked, TPUT, prices, avail, preds,
    mesh=make_pool_mesh(shape=(2, 2)), collect=True)
assert set(tel) == set(tel_sh) and len(tel) == len(base) + 7, sorted(tel)
for k in tel:
    np.testing.assert_array_equal(
        np.asarray(tel[k]), np.asarray(tel_sh[k]), err_msg=f"collect {k}")
for k in base:
    np.testing.assert_array_equal(
        np.asarray(base[k]), np.asarray(tel[k]),
        err_msg=f"collect-vs-base {k}")

# fallback: fallback=None rides the same compiled program as the default
# (bitwise vs base), and the ARMED prediction-failure monitor shards
# bitwise too — on storm-faulted inputs that actually trigger it
# (collect + fallback adds 7 slot keys + 2 fallback keys)
from repro.chaos import FallbackConfig, inject, storm_schedule
none = fast_sim.simulate_pool_jobs(
    arrs, stacked, TPUT, prices, avail, preds, fallback=None)
for k in base:
    np.testing.assert_array_equal(
        np.asarray(base[k]), np.asarray(none[k]), err_msg=f"fb-none {k}")
pf, af, prf = inject(prices, avail, preds,
                     storm_schedule(1, d, n_storms=2, storm_len=4,
                                    spike_mag=2.5, pred_fault="stale"))
cfg = FallbackConfig(threshold=0.5, lam=0.5)
fb = fast_sim.simulate_pool_jobs(
    arrs, stacked, TPUT, pf, af, prf, collect=True, fallback=cfg)
assert len(fb) == len(base) + 9, sorted(fb)
assert np.asarray(fb["tel_fallback"]).any(), "monitor never armed"
for shape in MESHES:
    fb_sh = fast_sim.simulate_pool_jobs_sharded(
        arrs, stacked, TPUT, pf, af, prf,
        mesh=None if shape is None else make_pool_mesh(shape=shape),
        collect=True, fallback=cfg)
    assert set(fb_sh) == set(fb)
    for k in fb:
        np.testing.assert_array_equal(
            np.asarray(fb[k]), np.asarray(fb_sh[k]),
            err_msg=f"fallback {k} mesh={shape}")

# multi-region: same meshes over the (J, R, T) market tensors
mkt = vast_like_regions(3, seed=1, days=1)
rarrs = specs_to_arrays(region_pool())
jobs = list(job_stream(rng, 3, deadline=d))
wins = [mkt.window(i * 4, d + 1) for i in range(3)]
rp = np.stack([w.prices[:, :d] for w in wins]).astype(np.float32)
ra = np.stack([w.avail[:, :d] for w in wins]).astype(np.int64)
rpm = np.stack([
    RegionalPredictor(
        w, lambda t, r: NoisyPredictor(t, "fixed_uniform", 0.2, seed=r)
    ).matrix(fast_sim.W1MAX - 1)[:, :d]
    for w in wins
]).astype(np.float32)
stacked = fast_sim.stack_jobs(jobs)
rbase = fast_sim.simulate_pool_regions(
    rarrs, stacked, TPUT, rp, ra, rpm, delta_mig=1)
for shape in MESHES:
    sh = fast_sim.simulate_pool_regions_sharded(
        rarrs, stacked, TPUT, rp, ra, rpm, delta_mig=1,
        mesh=None if shape is None else make_pool_mesh(shape=shape),
    )
    for k in rbase:
        np.testing.assert_array_equal(
            np.asarray(rbase[k]), np.asarray(sh[k]),
            err_msg=f"{k} regions mesh={shape}",
        )

# region engine knobs: collect=True (+9 keys: 7 slot + 2 migration
# series), the armed fallback monitor (+2 more), and per-region od
# multipliers — all three shard bitwise on every mesh layout, and the
# collect run's shared keys match the plain region run
p_od = np.array([1.0, 1.5, 0.7], np.float32)
rfull = fast_sim.simulate_pool_regions(
    rarrs, stacked, TPUT, rp, ra, rpm, delta_mig=1,
    collect=True, fallback=FallbackConfig(threshold=0.5, lam=0.5),
    p_od=p_od)
rtel = fast_sim.simulate_pool_regions(
    rarrs, stacked, TPUT, rp, ra, rpm, delta_mig=1, collect=True)
assert len(rtel) == len(rbase) + 9, sorted(rtel)
assert len(rfull) == len(rbase) + 11, sorted(rfull)
for k in rbase:
    np.testing.assert_array_equal(
        np.asarray(rbase[k]), np.asarray(rtel[k]),
        err_msg=f"region collect-vs-base {k}")
np.testing.assert_array_equal(
    np.asarray(rtel["tel_migration"]).sum(axis=-1),
    np.asarray(rtel["migrations"]), err_msg="migration reconciliation")
for name, ref, kw in (
    ("collect", rtel, dict(collect=True)),
    ("full", rfull, dict(collect=True,
                         fallback=FallbackConfig(threshold=0.5, lam=0.5),
                         p_od=p_od)),
):
    for shape in MESHES:
        sh = fast_sim.simulate_pool_regions_sharded(
            rarrs, stacked, TPUT, rp, ra, rpm, delta_mig=1,
            mesh=None if shape is None else make_pool_mesh(shape=shape),
            **kw)
        assert set(sh) == set(ref)
        for k in ref:
            np.testing.assert_array_equal(
                np.asarray(ref[k]), np.asarray(sh[k]),
                err_msg=f"region {name} {k} mesh={shape}")
print("SHARDED-PARITY-OK")
"""


def test_sharded_matches_single_device_4dev_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [SRC, os.path.dirname(SRC)] + env.get("PYTHONPATH", "").split(os.pathsep)
    ).rstrip(os.pathsep)
    out = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED-PARITY-OK" in out.stdout


def test_make_pool_mesh_shapes():
    """Shape validation + axis naming for the 1-D and 2-D pool meshes."""
    import jax

    from repro.launch.mesh import make_pool_mesh, parse_pool_mesh_shape

    mesh = make_pool_mesh()
    assert mesh.axis_names == ("jobs",)
    assert mesh.devices.shape == (jax.device_count(),)
    mesh2 = make_pool_mesh(shape=(1, 1))
    assert mesh2.axis_names == ("jobs", "lanes")
    with pytest.raises(ValueError):
        make_pool_mesh(shape=(2, 3))  # does not cover 1 device
    with pytest.raises(ValueError):
        make_pool_mesh(shape=(1, 1, 1))
    assert parse_pool_mesh_shape("") is None
    assert parse_pool_mesh_shape("auto") is None
    assert parse_pool_mesh_shape("4") == (4,)
    assert parse_pool_mesh_shape("2x2") == (2, 2)


def test_sharded_single_device_fallback_bitwise():
    """With one visible device the sharded entry point must fall through to
    (and bitwise-match) simulate_pool_jobs, and accept explicit 1-device
    meshes of either rank."""
    import jax

    from benchmarks.common import job_stream
    from repro.configs.base import ThroughputConfig
    from repro.core import fast_sim
    from repro.core.market import vast_like_trace
    from repro.core.policy_pool import (
        baseline_specs,
        paper_pool,
        rand_deadline_pool,
        specs_to_arrays,
    )
    from repro.core.predictor import NoisyPredictor
    from repro.launch.mesh import make_pool_mesh

    assert jax.device_count() == 1
    tput = ThroughputConfig(mu1=0.9, mu2=0.95)
    pool = (paper_pool(omegas=(2,), sigmas=(0.5,))
            + rand_deadline_pool((0.4,)) + baseline_specs())
    arrs = specs_to_arrays(pool)
    rng = np.random.default_rng(3)
    d = 10
    jobs = list(job_stream(rng, 3, deadline=d))
    traces = [vast_like_trace(seed=60 + i, days=1).window(0, d + 1)
              for i in range(3)]
    prices = np.stack([t.prices[:d] for t in traces]).astype(np.float32)
    avail = np.stack([t.avail[:d] for t in traces]).astype(np.int64)
    preds = np.stack([
        NoisyPredictor(t, "fixed_uniform", 0.2, seed=i).matrix(
            fast_sim.W1MAX - 1
        )[:d]
        for i, t in enumerate(traces)
    ]).astype(np.float32)
    stacked = fast_sim.stack_jobs(jobs)
    base = fast_sim.simulate_pool_jobs(arrs, stacked, tput, prices, avail, preds)
    for mesh in (None, make_pool_mesh(), make_pool_mesh(shape=(1, 1))):
        sh = fast_sim.simulate_pool_jobs_sharded(
            arrs, stacked, tput, prices, avail, preds, mesh=mesh
        )
        for k in base:
            np.testing.assert_array_equal(
                np.asarray(base[k]), np.asarray(sh[k]), err_msg=k
            )
