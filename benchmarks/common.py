"""Shared benchmark utilities. Every benchmark returns rows of
(name, us_per_call, derived) — us_per_call is the wall-time of the dominant
computation, derived is the figure's headline number."""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, List, Tuple

import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig

Row = Tuple[str, float, float]

# the paper's evaluation setting (Sec. VI-A): LLaMA2-7B LoRA job, 30-min
# slots, workload 80 over deadline 10, N in [1, 12], mu = 0.9
PAPER_JOB = JobConfig(workload=80.0, deadline=10, n_min=1, n_max=12,
                      value=120.0, gamma=2.0, on_demand_price=1.0)
PAPER_TPUT = ThroughputConfig(alpha=1.0, beta=0.0, mu1=0.9, mu2=0.95)


def _block(x) -> None:
    """Recursively block until every jax array inside ``x`` is ready.
    Duck-typed (``block_until_ready``) so numpy/python leaves are free and
    no jax import is needed; descends dicts, sequences, NamedTuples and
    dataclasses (SelectionResult, EGState, result dicts...)."""
    if x is None:
        return
    if hasattr(x, "block_until_ready"):
        x.block_until_ready()
    elif isinstance(x, dict):
        for v in x.values():
            _block(v)
    elif isinstance(x, (list, tuple)):
        for v in x:
            _block(v)
    elif dataclasses.is_dataclass(x) and not isinstance(x, type):
        for f in dataclasses.fields(x):
            _block(getattr(x, f.name))


def timed(fn: Callable, *args, repeat: int = 1, block: bool = True, **kw):
    """Wall-time ``fn(*args, **kw)`` averaged over ``repeat`` calls.

    ``block=True`` (the default) blocks on every jax array reachable from
    the return value INSIDE the timed region — jax dispatch is async, so
    without it a benchmark measures enqueue time, not compute time.
    ``block=False`` restores the raw dispatch measurement."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
        if block:
            _block(out)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6  # us


class StageTimer:
    """Accumulating named stage clock for a benchmark's prep/simulate/select
    split. ``with st.stage("simulate"): ...`` adds that block's wall time
    (blocking on ``block_on`` if given); ``rows(prefix)`` emits standard
    bench rows (derived = share of total)."""

    def __init__(self):
        self.totals: dict = {}

    @contextlib.contextmanager
    def stage(self, name: str, block_on=None):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            if block_on is not None:
                _block(block_on() if callable(block_on) else block_on)
            self.totals[name] = (self.totals.get(name, 0.0)
                                 + time.perf_counter() - t0)

    def rows(self, prefix: str) -> List[Row]:
        total = sum(self.totals.values()) or 1.0
        return [(f"{prefix}_stage_{name}", dt * 1e6, dt / total)
                for name, dt in self.totals.items()]


def job_stream_arrays(rng: np.random.Generator, n: int, deadline: int = 10,
                      workload_scale: float = 1.0):
    """Fig. 9 job distribution as stacked fast_sim.JobArrays — ONE vectorized
    rng call per field (the engine-scale path; no per-job python loop).
    L ~ U[70,120], Nmin in [1,4), Nmax in [12,17); value/gamma/on-demand
    price from the paper job. Leaf dtypes match fast_sim.stack_jobs, so
    ``stack_jobs(list(job_stream(rng, n)))`` equals
    ``job_stream_arrays(rng2, n)`` bitwise for equal rng states.

    ``workload_scale`` multiplies the drawn workloads (in f64, before the
    f32 cast) — the scenario grid's deadline-tightness axis: the deadline
    stays 10 slots so market tensors stay uniform, while the same base
    draws get proportionally more or less work. 1.0 is a bitwise no-op."""
    from repro.core.fast_sim import JobArrays

    cfg = JobConfig(deadline=deadline, value=PAPER_JOB.value)
    return JobArrays(
        workload=(rng.uniform(70, 120, n) * workload_scale).astype(np.float32),
        deadline=np.full(n, cfg.deadline, np.int32),
        n_min=rng.integers(1, 4, n).astype(np.int32),
        n_max=rng.integers(12, 17, n).astype(np.int32),
        value=np.full(n, cfg.value, np.float32),
        gamma=np.full(n, cfg.gamma, np.float32),
        p_o=np.full(n, cfg.on_demand_price, np.float32),
    )


def job_stream(rng: np.random.Generator, n: int, deadline: int = 10):
    """Fig. 9 job distribution as JobConfig rows — delegates to
    :func:`job_stream_arrays` so figure scripts and the engine benchmarks
    draw identical jobs from equal rng states (note: the delegation draws
    each field in one vectorized call, so the stream consumption differs
    from the pre-engine per-job loop)."""
    arrs = job_stream_arrays(rng, n, deadline)
    for k in range(n):
        yield JobConfig(
            workload=float(arrs.workload[k]),
            deadline=int(arrs.deadline[k]),
            n_min=int(arrs.n_min[k]),
            n_max=int(arrs.n_max[k]),
            value=float(arrs.value[k]),
            gamma=float(arrs.gamma[k]),
            on_demand_price=float(arrs.p_o[k]),
        )


def print_rows(rows: List[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.6g}")


def merge_bench_rows(json_path: str, prefix: str, key: str, rows: List[Row],
                     extra: dict) -> None:
    """Fold one module's rows into a shared BENCH json in place: rows whose
    name starts with ``prefix`` are replaced, everything else is untouched,
    and the module's non-row extras live under the single top-level ``key``
    (so pool_sim_bench's full rewrite has one thing per module to carry
    over). Shared by region_sim and selection_e2e."""
    import json

    try:
        with open(json_path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError):
        payload = {"rows": []}
    payload["rows"] = [
        r for r in payload.get("rows", [])
        if not str(r.get("name", "")).startswith(prefix)
    ] + [{"name": n, "us_per_call": us, "derived": d} for n, us, d in rows]
    payload[key] = extra
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=2)


# ---------------------------------------------------------------------------
# Shared policy-evaluation harness for the Fig. 5-8 sweeps
# ---------------------------------------------------------------------------

EVAL_SPEC_NAMES = ("ahap", "ahanp", "od_only", "msu", "up")


def eval_specs():
    """Representative AHAP/AHANP + the three baselines (paper Fig. 5-8)."""
    from repro.core.policy_pool import (
        KIND_AHANP,
        KIND_AHAP,
        PolicySpec,
        baseline_specs,
    )

    return [
        PolicySpec(KIND_AHAP, omega=3, v=1, sigma=0.7),
        PolicySpec(KIND_AHANP, sigma=0.7),
    ] + baseline_specs()


def best_of_family_utilities(jobs, traces, tput, **kw):
    """Paper methodology: 'the selected optimal policy is always the better
    of the two' — evaluate the whole 112-policy pool and report
    (best_ahap, best_ahanp, od, msu, up) mean utilities."""
    from repro.core.policy_pool import baseline_specs, paper_pool

    pool = paper_pool()
    specs = pool + baseline_specs()
    u = mean_utilities(jobs, traces, tput, specs=specs, **kw)
    ahap_u = max(u[i] for i, s in enumerate(pool) if s.kind == 0)
    ahanp_u = max(u[i] for i, s in enumerate(pool) if s.kind == 1)
    return np.array([ahap_u, ahanp_u, u[-3], u[-2], u[-1]])


def mean_utilities(
    jobs,
    traces,
    tput,
    noise_kind: str = "fixed_uniform",
    noise_level: float = 0.10,
    specs=None,
) -> np.ndarray:
    """(P,) mean utility of each spec over the (job, trace) pairs."""
    from repro.core import fast_sim
    from repro.core.policy_pool import specs_to_arrays
    from repro.core.predictor import NoisyPredictor, PerfectPredictor

    specs = specs or eval_specs()
    arrs = specs_to_arrays(specs)
    d = jobs[0].deadline
    assert all(j.deadline == d for j in jobs)
    prices = np.stack([t.prices[:d] for t in traces])
    avail = np.stack([t.avail[:d] for t in traces])
    preds = []
    for i, t in enumerate(traces):
        if noise_level <= 0:
            m = PerfectPredictor(t).matrix(fast_sim.W1MAX - 1)
        else:
            m = NoisyPredictor(t, noise_kind, noise_level, seed=i).matrix(
                fast_sim.W1MAX - 1
            )
        preds.append(m[:d])
    out = fast_sim.simulate_pool_jobs(
        arrs, fast_sim.stack_jobs(jobs), tput,
        np.asarray(prices, np.float32), np.asarray(avail, np.int64),
        np.asarray(np.stack(preds), np.float32),
    )
    return np.asarray(out["utility"]).mean(axis=0)


def paper_market(seed: int = 11, days: float = 30, **overrides):
    """The evaluation market regime: scarce availability with a strong
    diurnal cycle and volatile prices that regularly approach the on-demand
    rate — the conditions under which prediction pays (paper Sec. VI).
    Under abundant cheap spot, MSU is near-optimal and the paper's gaps
    vanish (EXPERIMENTS.md notes this sensitivity)."""
    from repro.core.market import vast_like_trace

    kw = dict(mean_price=0.7, price_sigma=0.5, avail_mean=5.5,
              avail_season_amp=3.0)
    kw.update(overrides)
    return vast_like_trace(seed=seed, days=days, **kw)


def windows(trace, n, deadline, rng):
    return [
        trace.window(int(rng.integers(0, len(trace) - deadline - 1)), deadline + 1)
        for _ in range(n)
    ]
