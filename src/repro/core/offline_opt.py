"""Offline optimal (hindsight) solution of the full problem (Eq. 5) by DP.

State: (slot t, previous instance count n_prev, workload bin z). Exact up to
the workload discretization (bins of ``gran`` * alpha units; mu in {mu1, mu2,
1} makes progress non-integer). Per-slot action = total instance count n in
{0} u [Nmin, Nmax]; the spot/on-demand split is greedily optimal given n
(spot iff p^s <= p^o, capped by availability). Used for:
  * the paper Fig. 4-style OPT column,
  * Theorem 1 empirical gap U(OPT) - U(AHAP) (benchmarks/theorem1),
  * sanity upper bound in property tests (no policy may beat OPT).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.job import tilde_value
from repro.core.market import Trace


@dataclass
class OfflineResult:
    utility: float
    plan_total: np.ndarray   # (d,) total instances per slot
    plan_spot: np.ndarray    # (d,)
    plan_od: np.ndarray      # (d,)
    cost: float
    z_ddl: float


def solve_offline(
    job: JobConfig,
    tput: ThroughputConfig,
    trace: Trace,
    gran: float = 0.25,
) -> OfflineResult:
    d = job.deadline
    prices = np.asarray(trace.prices[:d], float)
    avail = np.asarray(trace.avail[:d], int)
    p_o = job.on_demand_price

    actions = np.array([0] + list(range(job.n_min, job.n_max + 1)))
    n_actions = len(actions)
    zmax = job.workload  # progress beyond L is worthless
    dz = gran * tput.alpha
    nz = int(np.floor(zmax / dz)) + 1
    n_prev_states = job.n_max + 1

    # value[n_prev, zbin] = max over remaining slots of (future utility)
    # terminal: tilde_value(z) (cost already subtracted along the way)
    zgrid = np.minimum(np.arange(nz) * dz, zmax)
    term = np.asarray(tilde_value(job, tput, zgrid))  # (nz,)
    value = np.tile(term[None, :], (n_prev_states, 1))
    # choice[t, n_prev, zbin] -> action index
    choice = np.zeros((d, n_prev_states, nz), np.int32)

    n_prev_grid = np.arange(n_prev_states)[:, None, None]      # (P,1,1)
    act = actions[None, :, None]                               # (1,A,1)

    h = np.where(act > 0, tput.alpha * act + tput.beta, 0.0)   # (1,A,1)
    mu = np.where(
        act > n_prev_grid, tput.mu1, np.where(act < n_prev_grid, tput.mu2, 1.0)
    )
    mu = np.where((act == 0) & (n_prev_grid == 0), 1.0, mu)    # (P,A,1)

    for t in range(d - 1, -1, -1):
        ns = np.minimum(actions, avail[t]) if prices[t] <= p_o else np.zeros_like(actions)
        no = actions - ns
        cost = ns * prices[t] + no * p_o                        # (A,)
        dzt = mu * h                                            # (P,A,1)
        znew = zgrid[None, None, :] + dzt                       # (P,A,nz)
        zbin_new = np.minimum((znew / dz).astype(np.int64), nz - 1)
        # future value: V_{t+1}[n_now, zbin_new]
        fut = value[actions[None, :, None], zbin_new]           # broadcast (P,A,nz)
        q = fut - cost[None, :, None]
        best = q.argmax(axis=1)                                 # (P, nz)
        choice[t] = best
        value = np.take_along_axis(q, best[:, None, :], axis=1)[:, 0, :]

    # roll forward to extract the plan
    z, n_prev, zbin = 0.0, 0, 0
    tot, spot, od = [], [], []
    cost_acc = 0.0
    for t in range(d):
        a = choice[t, n_prev, zbin]
        n = int(actions[a])
        ns = min(n, int(avail[t])) if prices[t] <= p_o else 0
        no = n - ns
        m = 1.0 if n == n_prev else (tput.mu1 if n > n_prev else tput.mu2)
        if n == 0 and n_prev == 0:
            m = 1.0
        z = min(z + m * (tput.alpha * n + (tput.beta if n > 0 else 0.0)), zmax)
        cost_acc += ns * prices[t] + no * p_o
        tot.append(n)
        spot.append(ns)
        od.append(no)
        n_prev = n
        zbin = min(int(z / dz), nz - 1)
    util = float(tilde_value(job, tput, z)) - cost_acc
    return OfflineResult(
        utility=util,
        plan_total=np.array(tot),
        plan_spot=np.array(spot),
        plan_od=np.array(od),
        cost=cost_acc,
        z_ddl=float(z),
    )
