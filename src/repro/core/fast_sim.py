"""Vectorized policy simulator: one lax.scan over slots, batched over the
whole policy pool (the paper's 112 plus any RAND_DEADLINE / Robust-AHAP
expansions) and over jobs — this is what makes the paper's Fig. 9/10
experiments (1000s of jobs x 100+ policies) take seconds instead of hours.

Semantics mirror repro.core.simulator.simulate exactly (pinned by
tests/test_selector_fastsim.py): same feasibility pipeline, same mu/billing/
termination rules, same rounding (jnp.round == python round, half-to-even).

Policies are encoded as arrays (see policy_pool.specs_to_arrays). The pool
entry points partition the lanes by ``kind``: AHAP lanes run the DP-bearing
scan, where each scan slot issues ONE lane-batched ``solve_window_batch``
call — a single (P_ahap, w1, tn+1) DP (one fused kernel launch on the
Pallas backends) instead of vmap's per-lane grid batching. All other kinds
(AHANP/OD/MSU/UP/RAND_DEADLINE) run a cheap scan that never touches the
window DP, and the results are scattered back to the original pool order —
the public API and semantics are unchanged.

Multi-device: ``simulate_pool_jobs_sharded`` lays the (jobs x lanes) grid
over a mesh (repro.launch.mesh.make_pool_mesh) with ``shard_map``. On the
default 1-D mesh jobs ride the single axis; a 2-D ``("jobs", "lanes")``
mesh (``make_pool_mesh(shape=(a, b))``) additionally shards each kind
partition's policy-lane axis — because the kind partition splits DP-heavy
AHAP lanes from cheap lanes *before* sharding, every lane shard carries a
uniform workload (load balance is by construction). Both entry points
(``simulate_pool_jobs_sharded``, ``simulate_pool_regions_sharded``) pad
both grid axes to divisibility and fall back bitwise-identically to their
unsharded twins on a single device; the shard_map'd partition runners are
built once per static config (``_sharded_pool_call``) so steady-state calls
never retrace.

``simulate_one`` keeps the seed's monolithic all-kinds step (every decision
rule evaluated at every slot, DP included) and doubles as the benchmark
baseline via ``simulate_pool_monolithic``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.job import value_fn
from repro.core.policies import RSEL_BIG, RSEL_PRED_WINDOW
from repro.core.policy_pool import KIND_AHAP
from repro.core.window_opt import solve_window, solve_window_batch

W1MAX = 6   # max omega + 1
VMAX = 5    # max commitment level
NTABLE = 16  # static unit-table width (paper availability cap)


class JobArrays(NamedTuple):
    workload: jnp.ndarray
    deadline: jnp.ndarray       # int32 (dynamic; scan runs d_max slots)
    n_min: jnp.ndarray
    n_max: jnp.ndarray
    value: jnp.ndarray
    gamma: jnp.ndarray
    p_o: jnp.ndarray

    @staticmethod
    def of(job: JobConfig) -> "JobArrays":
        return JobArrays(
            jnp.float32(job.workload), jnp.int32(job.deadline),
            jnp.int32(job.n_min), jnp.int32(job.n_max),
            jnp.float32(job.value), jnp.float32(job.gamma),
            jnp.float32(job.on_demand_price),
        )


def _job_cfg(j: JobArrays) -> JobConfig:
    """JobConfig carrying tracers (fine: frozen dataclass of leaves)."""
    return JobConfig(
        workload=j.workload, deadline=j.deadline, n_min=j.n_min,
        n_max=j.n_max, value=j.value, gamma=j.gamma, on_demand_price=j.p_o,
    )


def _feasible(n_o, n_s, price, avail, j: JobArrays):
    """Mirror of BasePolicy._feasible."""
    n_s = jnp.minimum(jnp.minimum(n_s, avail), j.n_max)
    n_o = jnp.maximum(n_o, 0)
    total = n_o + n_s
    need = jnp.maximum(j.n_min - total, 0)
    spot_room = (price <= j.p_o) & (avail - n_s >= need)
    n_s = jnp.where((total > 0) & (total < j.n_min) & spot_room, n_s + need, n_s)
    n_o = jnp.where((total > 0) & (total < j.n_min) & ~spot_room, n_o + need, n_o)
    over = jnp.maximum(n_o + n_s - j.n_max, 0)
    drop_od = jnp.where(price <= j.p_o, jnp.minimum(over, n_o), 0)
    n_o = n_o - drop_od
    n_s = n_s - (over - drop_od)
    zero = total <= 0
    return jnp.where(zero, 0, n_o), jnp.where(zero, 0, n_s)


def _sim_clip(n_o, n_s, avail, j: JobArrays):
    """Mirror of simulate()'s hard feasibility clip."""
    n_s = jnp.clip(n_s, 0, jnp.minimum(avail, j.n_max))
    n_o = jnp.clip(n_o, 0, j.n_max - n_s)
    n = n_o + n_s
    n_o = jnp.where((n > 0) & (n < j.n_min), n_o + (j.n_min - n), n_o)
    return n_o, n_s


# ---------------------------------------------------------------------------
# Decision rules — shared between the monolithic and kind-partitioned scans
# ---------------------------------------------------------------------------

def _ahap_precompute(j: JobArrays, omega, sigma, rho, ts, pred):
    """Scan-invariant AHAP scaffolding, vectorized over a leading slot axis
    (or scalar ts/per-slot pred in the monolithic per-step path).

    Robust-AHAP discounts *predicted* availability (entries j >= 1 only)."""
    disc_av = jnp.floor(rho * pred[..., 1]).at[..., 0].set(pred[..., 0, 1])
    pr = jnp.stack([pred[..., 0], disc_av], axis=-1)
    in_w = jnp.arange(W1MAX) <= omega
    z_exp_end = j.workload / j.deadline * jnp.minimum(
        (ts + 1 + omega).astype(jnp.float32), j.deadline.astype(jnp.float32)
    )
    thr_s = jnp.where(
        in_w
        & (pr[..., 0] <= sigma * j.p_o)
        & (pr[..., 1] >= j.n_min),
        jnp.minimum(pr[..., 1].astype(jnp.int32), j.n_max),
        0,
    )
    eff_slots = jnp.minimum(j.deadline - ts, omega + 1)
    return pr, thr_s, z_exp_end, eff_slots


def _ahap_rule(jcfg, j: JobArrays, tput, v, backend, z, t, price, av, plans,
               pr_t, thr_s_t, z_exp_end_t, eff_slots_t):
    """AHAP (Alg. 1): CHC window solve when behind, threshold plan when
    ahead, v-step plan averaging. Returns (n_o, n_s, new_plans)."""
    ahead = z >= z_exp_end_t
    chc_o, chc_s, _ = solve_window(
        jcfg, tput, z, eff_slots_t, pr_t[:, 0], pr_t[:, 1].astype(jnp.int32),
        j.p_o, table_n=NTABLE, backend=backend,
    )
    plan = jnp.where(
        ahead,
        jnp.stack([jnp.zeros(W1MAX, jnp.int32), thr_s_t], axis=-1),
        jnp.stack([chc_o, chc_s], axis=-1),
    ).astype(jnp.float32)  # (W1MAX, 2)
    plans = jnp.concatenate([plan[None], plans[:-1]], axis=0)  # (VMAX, W1MAX, 2)
    kk = jnp.arange(VMAX)
    # a plan only exists if it was actually made (k <= t): matches the
    # python policy's growing history, not zero-padded averaging
    valid = ((kk < v) & (kk <= t))[:, None].astype(jnp.float32)
    diag = plans[kk, jnp.minimum(kk, W1MAX - 1)]  # (VMAX, 2)
    cnt = jnp.maximum(valid.sum(), 1.0)
    avg = (diag * valid).sum(axis=0) / cnt
    # round-half-up, matching the python reference exactly
    ah_o = jnp.floor(avg[0] + 0.5).astype(jnp.int32)
    ah_s = jnp.minimum(jnp.floor(avg[1] + 0.5).astype(jnp.int32), av)
    ah_zero = (ah_o + ah_s) == 0
    ah_o_f, ah_s_f = _feasible(ah_o, ah_s, price, av, j)
    ah_o = jnp.where(ah_zero, 0, ah_o_f)
    ah_s = jnp.where(ah_zero, 0, ah_s_f)
    return ah_o, ah_s, plans


def _ahanp_rule(j: JobArrays, sigma, z, t, price, av, n_prev, prev_avail):
    """AHANP (Alg. 3): reactive indicators z_hat / p_hat / n_hat."""
    z_exp_prev = j.workload / j.deadline * t.astype(jnp.float32)
    z_hat = jnp.where(z_exp_prev > 0, z / z_exp_prev, 1.0)
    p_hat = price / (sigma * j.p_o)
    n_hat = jnp.where(
        av == 0, 0.0,
        jnp.where(prev_avail == 0, jnp.inf,
                  av / jnp.maximum(prev_avail, 1).astype(jnp.float32)),
    )
    ahead1 = z_hat >= 1.0
    n_an = jnp.where(
        ahead1,
        jnp.where(
            av == 0,
            0,
            jnp.where(
                n_hat <= 0.5,
                jnp.maximum(n_prev // 2, j.n_min),
                jnp.where(
                    n_hat <= 1.0,
                    n_prev,
                    jnp.where(p_hat > 1.0, n_prev, jnp.maximum(n_prev, av)),
                ),
            ),
        ),
        jnp.maximum(2 * n_prev, j.n_min),
    )
    an_zero = n_an <= 0
    n_an_c = jnp.clip(n_an, j.n_min, j.n_max)
    an_s = jnp.minimum(av, n_an_c)
    an_o_f, an_s_f = _feasible(n_an_c - an_s, an_s, price, av, j)
    an_o = jnp.where(an_zero, 0, an_o_f)
    an_s = jnp.where(an_zero, 0, an_s_f)
    return an_o, an_s


def _od_rule(j: JobArrays, tput, z, t, price, av):
    """OD-Only: constant on-demand sized to finish exactly at the deadline."""
    remaining = jnp.maximum(j.workload - z, 0.0)
    slots_left = (j.deadline - t).astype(jnp.float32)
    od_need = jnp.ceil(
        remaining / jnp.maximum(slots_left, 1.0) / tput.alpha
    ).astype(jnp.int32)
    od_zero = (remaining <= 0) | (slots_left <= 0)
    od_o_f, od_s_f = _feasible(jnp.clip(od_need, j.n_min, j.n_max), 0, price, av, j)
    od_o = jnp.where(od_zero, 0, od_o_f)
    od_s = jnp.where(od_zero, 0, od_s_f)
    return od_o, od_s


def _msu_rule(j: JobArrays, tput, z, t, price, av):
    """MSU: all spot; on-demand only once N^max can no longer finish."""
    remaining = jnp.maximum(j.workload - z, 0.0)
    slots_left = (j.deadline - t).astype(jnp.float32)
    od_need = jnp.ceil(
        remaining / jnp.maximum(slots_left, 1.0) / tput.alpha
    ).astype(jnp.int32)
    ms_s = jnp.minimum(av, j.n_max)
    h_max = tput.alpha * j.n_max.astype(jnp.float32) + tput.beta
    panic = remaining > h_max * jnp.maximum(slots_left - 1.0, 0.0)
    ms_o = jnp.where(
        panic,
        jnp.maximum(jnp.minimum(od_need, j.n_max) - ms_s, 0),
        0,
    )
    ms_zero = (remaining <= 0) | ((ms_s + ms_o) == 0)
    ms_o_f, ms_s_f = _feasible(ms_o, ms_s, price, av, j)
    ms_o = jnp.where(ms_zero, 0, ms_o_f)
    ms_s = jnp.where(ms_zero, 0, ms_s_f)
    return ms_o, ms_s


def _up_rule(j: JobArrays, tput, z, t, price, av):
    """UP (Wu et al. [16]): track the L/d line, spot-first."""
    remaining = jnp.maximum(j.workload - z, 0.0)
    rate = j.workload / j.deadline.astype(jnp.float32)
    deficit = jnp.maximum(rate * t.astype(jnp.float32) - z, 0.0)
    up_need = jnp.clip(
        jnp.ceil((rate + deficit) / tput.alpha).astype(jnp.int32), j.n_min, j.n_max
    )
    up_s = jnp.minimum(av, up_need)
    up_o = jnp.where(deficit > 0, up_need - up_s, 0)
    up_zero = (remaining <= 0) | ((up_s + up_o) == 0)
    up_o_f, up_s_f = _feasible(up_o, up_s, price, av, j)
    up_o = jnp.where(up_zero, 0, up_o_f)
    up_s = jnp.where(up_zero, 0, up_s_f)
    return up_o, up_s


def _rand_rule(j: JobArrays, tput, cfrac, z, t, price, av):
    """RAND_DEADLINE (arXiv:2601.14612): randomized commitment threshold.
    All-spot before the committed slot tau = floor(cfrac * d); from tau on,
    on-demand sized to finish exactly at the deadline. ``cfrac`` is the
    inverse optimal-commitment CDF at the lane's quantile, precomputed in
    float64 by specs_to_arrays, so the f32 floor here matches the python
    reference bit-for-bit."""
    tau = jnp.floor(cfrac * j.deadline.astype(jnp.float32))
    committed = t.astype(jnp.float32) >= tau
    remaining = jnp.maximum(j.workload - z, 0.0)
    slots_left = (j.deadline - t).astype(jnp.float32)
    od_need = jnp.ceil(
        remaining / jnp.maximum(slots_left, 1.0) / tput.alpha
    ).astype(jnp.int32)
    rd_o = jnp.where(committed, jnp.clip(od_need, j.n_min, j.n_max), 0)
    rd_s = jnp.where(committed, 0, jnp.minimum(av, j.n_max))
    rd_zero = (remaining <= 0) | (slots_left <= 0) | ((rd_o + rd_s) == 0)
    rd_o_f, rd_s_f = _feasible(rd_o, rd_s, price, av, j)
    rd_o = jnp.where(rd_zero, 0, rd_o_f)
    rd_s = jnp.where(rd_zero, 0, rd_s_f)
    return rd_o, rd_s


def _execute(j: JobArrays, tput, z, n_prev, cost, done, T, t, n_o, n_s,
             price, av):
    """Mirror of simulate()'s slot execution: hard clip, mu, billing,
    fractional completion. Returns the updated exec state + (n_o, n_s, active)."""
    active = (t < j.deadline) & ~done
    n_o, n_s = _sim_clip(n_o, n_s, av, j)
    n_o = jnp.where(active, n_o, 0)
    n_s = jnp.where(active, n_s, 0)
    n = n_o + n_s

    mu = jnp.where(n > n_prev, tput.mu1, jnp.where(n < n_prev, tput.mu2, 1.0))
    mu = jnp.where((n == 0) & (n_prev == 0), 1.0, mu)
    work = mu * jnp.where(n > 0, tput.alpha * n.astype(jnp.float32) + tput.beta, 0.0)
    will_done = active & (work > 0) & (z + work >= j.workload)
    frac = jnp.where(work > 0, (j.workload - z) / jnp.maximum(work, 1e-9), 0.0)
    T = jnp.where(will_done, t.astype(jnp.float32) + frac, T)
    cost = cost + jnp.where(
        active, n_s.astype(jnp.float32) * price + n_o.astype(jnp.float32) * j.p_o, 0.0
    )
    z = jnp.minimum(z + jnp.where(active, work, 0.0), j.workload)
    n_prev = jnp.where(active, n, n_prev)
    done = done | will_done
    return z, n_prev, cost, done, T, n_o, n_s, active


# flight-recorder slot series (repro.obs): emitted as extra stacked scan
# outputs when a pool entry point runs with collect=True, riding the result
# dict as flat "tel_*" keys (same (.., T) layout as n_od/n_spot) so the
# scatter-merge / shard_map / padding plumbing carries them unchanged.
# Order matches _slot_telemetry's return tuple.
_TEL_SLOTS = ("tel_spot_cost", "tel_od_cost", "tel_progress", "tel_active",
              "tel_up", "tel_down", "tel_preempt")

# prediction-health series, emitted ONLY when a collect run also enables
# the fallback monitor (fallback is not None): plain collect runs keep the
# exact _TEL_SLOTS key set the subprocess parity tests count on.
# Order matches the (fallback-active, ewma-error) ys appended by the scans.
_TEL_FALLBACK = ("tel_fallback", "tel_pred_err")

# region-path series, emitted only by the multi-region scans under collect:
# the region occupied each slot and the switch-decision events. The slot
# sums of tel_migration must equal the ``migrations`` result leaves
# (reconciled in repro.obs.ledger.migration_reconciliation).
_TEL_REGION = ("tel_region", "tel_migration")

# floor for the relative-error denominators of the fallback monitor
# (traces clip prices >= 0.02; availability errors normalize by >= 1 unit)
_FB_PRICE_EPS = 0.01


def _fallback_error(fallback, err, price, av, prev1_t):
    """One EWMA update of the prediction-health monitor: blend the relative
    errors of last slot's 1-step-ahead forecast ``prev1_t`` (price, avail)
    against this slot's observed market. All ``fallback`` fields are static
    constants baked into the trace; only traced when fallback is enabled."""
    avf = av.astype(jnp.float32)
    e_p = jnp.abs(price - prev1_t[0]) / jnp.maximum(price, _FB_PRICE_EPS)
    e_a = jnp.abs(avf - prev1_t[1]) / jnp.maximum(avf, 1.0)
    w_p = jnp.float32(fallback.price_weight)
    e = w_p * e_p + (jnp.float32(1.0) - w_p) * e_a
    lam = jnp.float32(fallback.lam)
    return (jnp.float32(1.0) - lam) * err + lam * e


def _fallback_prev1(pred):
    """(T, 2) realized 1-step-ahead forecast series: at slot t, the value
    the predictor issued at t-1 for t. Slot 0 uses its own observed-present
    row, so the monitor starts cold (zero error)."""
    return jnp.concatenate([pred[:1, 0, :], pred[:-1, 1, :]], axis=0)


def _slot_telemetry(j: JobArrays, n_prev_before, z, n_o, n_s, active,
                    price, av):
    """One flight-recorder sample, taken AFTER :func:`_execute` ran the
    slot: the spot/on-demand cost split billed this slot, cumulative
    progress, and the reconfiguration events — ``preempt`` flags a shrink
    forced by supply (available spot — the fleet's waterfall grant — fell
    below last slot's allocation). Pure elementwise ops; the collect=False
    path never traces this function, which is what keeps the default
    program bitwise-identical to the pre-telemetry build."""
    n = n_o + n_s
    act_f = active.astype(jnp.float32)
    up = active & (n > n_prev_before)
    down = active & (n < n_prev_before)
    preempt = down & (av < n_prev_before)
    return (
        act_f * n_s.astype(jnp.float32) * price,
        act_f * n_o.astype(jnp.float32) * j.p_o,
        z,
        active,
        up,
        down,
        preempt,
    )


def _finalize(jcfg, j: JobArrays, tput, z, cost, done, T, no_hist, ns_hist):
    """Termination configuration (N^max on-demand past the deadline)."""
    h_max = tput.alpha * j.n_max.astype(jnp.float32) + tput.beta
    dt = jnp.maximum(j.workload - z, 0.0) / h_max
    T_final = jnp.where(done, T, j.deadline.astype(jnp.float32) + dt)
    cost_final = cost + jnp.where(done, 0.0, j.p_o * j.n_max.astype(jnp.float32) * dt)
    value = value_fn(jcfg, T_final)
    return {
        "utility": value - cost_final,
        "value": value,
        "cost": cost_final,
        "completion_time": T_final,
        "z_ddl": z,
        "completed": done,
        "n_od": no_hist,
        "n_spot": ns_hist,
    }


# ---------------------------------------------------------------------------
# Monolithic single-lane scan (seed path; benchmark baseline)
# ---------------------------------------------------------------------------

def simulate_one(
    kind, omega, v, sigma,                 # policy encoding (scalars)
    j: JobArrays,
    tput: ThroughputConfig,
    prices, avail, pred,                   # (dmax,), (dmax,), (dmax, W1MAX, 2)
    rho=jnp.float32(1.0),                  # Robust-AHAP availability discount
    cfrac=jnp.float32(0.0),                # RAND_DEADLINE commitment fraction
    backend: str = "xla",                  # window-DP backend (static)
):
    """All six decision rules at every slot, selected by ``kind`` — the
    seed formulation. The pool entry points below partition by kind instead
    and only fall back to this for the monolithic baseline."""
    dmax = prices.shape[0]
    jcfg = _job_cfg(j)

    def step(carry, xs):
        z, n_prev, cost, done, T, plans, prev_avail = carry
        price, av, pr_raw, t = xs  # scalar, scalar, (W1MAX, 2), scalar

        pr, thr_s, z_exp_end, eff_slots = _ahap_precompute(
            j, omega, sigma, rho, t, pr_raw
        )
        ah_o, ah_s, plans = _ahap_rule(
            jcfg, j, tput, v, backend, z, t, price, av, plans,
            pr, thr_s, z_exp_end, eff_slots,
        )
        an_o, an_s = _ahanp_rule(j, sigma, z, t, price, av, n_prev, prev_avail)
        od_o, od_s = _od_rule(j, tput, z, t, price, av)
        ms_o, ms_s = _msu_rule(j, tput, z, t, price, av)
        up_o, up_s = _up_rule(j, tput, z, t, price, av)
        rd_o, rd_s = _rand_rule(j, tput, cfrac, z, t, price, av)

        n_o = jnp.select(
            [kind == 0, kind == 1, kind == 2, kind == 3, kind == 4, kind == 5],
            [ah_o, an_o, od_o, ms_o, up_o, rd_o],
        )
        n_s = jnp.select(
            [kind == 0, kind == 1, kind == 2, kind == 3, kind == 4, kind == 5],
            [ah_s, an_s, od_s, ms_s, up_s, rd_s],
        )
        z, n_prev, cost, done, T, n_o, n_s, active = _execute(
            j, tput, z, n_prev, cost, done, T, t, n_o, n_s, price, av
        )
        prev_avail = jnp.where(active, av, prev_avail)
        return (z, n_prev, cost, done, T, plans, prev_avail), (n_o, n_s)

    init = (
        jnp.float32(0.0), jnp.int32(0), jnp.float32(0.0),
        jnp.bool_(False), jnp.float32(0.0),
        jnp.zeros((VMAX, W1MAX, 2), jnp.float32), avail[0].astype(jnp.int32),
    )
    (z, _, cost, done, T, _, _), (no_hist, ns_hist) = jax.lax.scan(
        step, init,
        (prices, avail.astype(jnp.int32), pred, jnp.arange(dmax)),
    )
    return _finalize(jcfg, j, tput, z, cost, done, T, no_hist, ns_hist)


# ---------------------------------------------------------------------------
# Kind-partitioned lane scans (the hot path)
# ---------------------------------------------------------------------------

def _ahap_rule_batch(jcfg, j: JobArrays, tput, v, backend, z, t, price, av,
                     plans, pr_t, thr_t, zee_t, eff_t):
    """Lane-batched :func:`_ahap_rule`: z/v/eff_t are (P,), pr_t is
    (P, W1MAX, 2), plans is (P, VMAX, W1MAX, 2). The CHC solve is ONE
    ``solve_window_batch`` call across all lanes — a single fused kernel
    launch per slot on the Pallas backends. Elementwise ops broadcast over
    the lane axis, so results are bitwise-equal to the per-lane rule.

    ``t`` may be a scalar (all lanes share the slot clock, the pool path)
    or a (P,) vector of per-lane local clocks (the fleet path, where lanes
    are jobs with different arrivals); scalar callers are unchanged
    bitwise. In the vector case ``jcfg``/``j``/``av`` may be per-lane too —
    ``solve_window_batch`` and the elementwise rules broadcast them."""
    p = z.shape[0]
    ahead = z >= zee_t
    chc_o, chc_s, _ = solve_window_batch(
        jcfg, tput, z, eff_t, pr_t[..., 0], pr_t[..., 1].astype(jnp.int32),
        j.p_o, table_n=NTABLE, backend=backend,
    )
    plan = jnp.where(
        ahead[:, None, None],
        jnp.stack([jnp.zeros((p, W1MAX), jnp.int32), thr_t], axis=-1),
        jnp.stack([chc_o, chc_s], axis=-1),
    ).astype(jnp.float32)                               # (P, W1MAX, 2)
    plans = jnp.concatenate([plan[:, None], plans[:, :-1]], axis=1)
    kk = jnp.arange(VMAX)
    t_arr = jnp.asarray(t)
    made = kk[None, :] <= (t_arr[:, None] if t_arr.ndim else t_arr)
    valid = (kk[None, :] < v[:, None]) & made
    valid = valid[..., None].astype(jnp.float32)        # (P, VMAX, 1)
    diag = plans[:, kk, jnp.minimum(kk, W1MAX - 1)]     # (P, VMAX, 2)
    cnt = jnp.maximum(valid.sum(axis=(1, 2)), 1.0)      # (P,)
    avg = (diag * valid).sum(axis=1) / cnt[:, None]     # (P, 2)
    ah_o = jnp.floor(avg[:, 0] + 0.5).astype(jnp.int32)
    ah_s = jnp.minimum(jnp.floor(avg[:, 1] + 0.5).astype(jnp.int32), av)
    ah_zero = (ah_o + ah_s) == 0
    ah_o_f, ah_s_f = _feasible(ah_o, ah_s, price, av, j)
    ah_o = jnp.where(ah_zero, 0, ah_o_f)
    ah_s = jnp.where(ah_zero, 0, ah_s_f)
    return ah_o, ah_s, plans


def _simulate_lanes_ahap(omega, v, sigma, rho, j: JobArrays, tput,
                         prices, avail, pred, backend: str,
                         collect: bool = False, fallback=None):
    """All AHAP lanes in ONE scan over slots. Each scan slot issues a single
    batched (P_ahap, w1, tn+1) window DP instead of relying on vmap's
    per-lane grid batching (``_simulate_one_ahap`` under vmap — kept below
    as the equivalence oracle). Scan-invariant scaffolding is precomputed
    per (lane, slot) and fed slot-major through the scan xs. ``collect``
    (static) appends the ``_TEL_SLOTS`` flight-recorder series to the scan
    ys — the False branch traces the identical program.

    ``fallback`` (a static :class:`repro.chaos.FallbackConfig`, or None)
    arms the online prediction-health monitor: the scan carries a
    realized-forecast-error EWMA (one scalar — every lane of a job reads
    the same forecast stack) and, while it exceeds the threshold, every
    lane's decision is taken from the prediction-free AHANP rule instead
    of the window solve (the AHANP "previous availability" is the shifted
    supply, matching the fleet engine's convention). Plans keep updating
    underneath so recovery resumes AHAP with a warm history. ``None``
    traces the bitwise-identical shipped program; with collect also on,
    the ``_TEL_FALLBACK`` series join the ys."""
    dmax = prices.shape[0]
    p = omega.shape[0]
    jcfg = _job_cfg(j)
    ts = jnp.arange(dmax)
    # slot-major from the start: slots on the OUTER vmap, lanes inner, so the
    # scan-xs layout (dmax leading) is the only one ever materialized. The
    # old lane-major vmap + per-tensor swapaxes built the (P, dmax, ...)
    # tensors AND their transposed copies at every scan boundary — at Fig.
    # 9/10 scale (1000 jobs x 105 AHAP lanes) that doubled the largest
    # buffers in the whole simulation for pure data movement.
    pr, thr_s, z_exp_end, eff_slots = jax.vmap(
        lambda t, pm: jax.vmap(
            lambda w, s, r: _ahap_precompute(j, w, s, r, t, pm)
        )(omega, sigma, rho)
    )(ts, pred)
    # pr (dmax, P, W1MAX, 2); thr_s (dmax, P, W1MAX); rest (dmax, P)
    av_i = avail.astype(jnp.int32)
    if fallback is not None:
        thr = jnp.float32(fallback.threshold)
        prev1 = _fallback_prev1(pred)                   # (dmax, 2)
        prev_av = jnp.concatenate([av_i[:1], av_i[:-1]])

    def step(carry, xs):
        if fallback is not None:
            z, n_prev, cost, done, T, plans, err = carry
            price, av, pr_t, thr_t, zee_t, eff_t, t, p1_t, pav_t = xs
            err = _fallback_error(fallback, err, price, av, p1_t)
            fb = err > thr
        else:
            z, n_prev, cost, done, T, plans = carry
            price, av, pr_t, thr_t, zee_t, eff_t, t = xs
        n_o, n_s, plans = _ahap_rule_batch(
            jcfg, j, tput, v, backend, z, t, price, av, plans,
            pr_t, thr_t, zee_t, eff_t,
        )
        if fallback is not None:
            an_o, an_s = _ahanp_rule(j, sigma, z, t, price, av, n_prev, pav_t)
            n_o = jnp.where(fb, an_o, n_o)
            n_s = jnp.where(fb, an_s, n_s)
        n_prev0 = n_prev
        z, n_prev, cost, done, T, n_o, n_s, active = _execute(
            j, tput, z, n_prev, cost, done, T, t, n_o, n_s, price, av
        )
        ys = (n_o, n_s)
        if collect:
            ys = ys + _slot_telemetry(j, n_prev0, z, n_o, n_s, active,
                                      price, av)
            if fallback is not None:
                ys = ys + (jnp.broadcast_to(fb, n_o.shape),
                           jnp.broadcast_to(err, n_o.shape))
        new_carry = (z, n_prev, cost, done, T, plans)
        if fallback is not None:
            new_carry = new_carry + (err,)
        return new_carry, ys

    init = (
        jnp.zeros((p,), jnp.float32), jnp.zeros((p,), jnp.int32),
        jnp.zeros((p,), jnp.float32), jnp.zeros((p,), jnp.bool_),
        jnp.zeros((p,), jnp.float32),
        jnp.zeros((p, VMAX, W1MAX, 2), jnp.float32),
    )
    xs = (prices, av_i, pr, thr_s, z_exp_end, eff_slots, ts)
    if fallback is not None:
        init = init + (jnp.float32(0.0),)
        xs = xs + (prev1, prev_av)
    (z, _, cost, done, T, *_rest), ys = jax.lax.scan(step, init, xs)
    out = _finalize(jcfg, j, tput, z, cost, done, T,
                    jnp.swapaxes(ys[0], 0, 1), jnp.swapaxes(ys[1], 0, 1))
    if collect:
        keys = _TEL_SLOTS + (_TEL_FALLBACK if fallback is not None else ())
        for key, hist in zip(keys, ys[2:]):
            out[key] = jnp.swapaxes(hist, 0, 1)
    return out


def _simulate_one_ahap(omega, v, sigma, rho, j: JobArrays, tput,
                       prices, avail, pred, backend: str):
    """AHAP-only lane, one lane per call (the pre-batching formulation —
    ``jax.vmap`` of this is the equivalence oracle for
    ``_simulate_lanes_ahap``). All scan-invariant scaffolding
    (rho-discounted forecasts, threshold plans, schedule line, effective
    window lengths) is hoisted out of the step."""
    dmax = prices.shape[0]
    jcfg = _job_cfg(j)
    ts = jnp.arange(dmax)
    pr, thr_s, z_exp_end, eff_slots = _ahap_precompute(
        j, omega, sigma, rho, ts, pred
    )

    def step(carry, xs):
        z, n_prev, cost, done, T, plans = carry
        price, av, pr_t, thr_s_t, zee_t, eff_t, t = xs
        n_o, n_s, plans = _ahap_rule(
            jcfg, j, tput, v, backend, z, t, price, av, plans,
            pr_t, thr_s_t, zee_t, eff_t,
        )
        z, n_prev, cost, done, T, n_o, n_s, _ = _execute(
            j, tput, z, n_prev, cost, done, T, t, n_o, n_s, price, av
        )
        return (z, n_prev, cost, done, T, plans), (n_o, n_s)

    init = (
        jnp.float32(0.0), jnp.int32(0), jnp.float32(0.0),
        jnp.bool_(False), jnp.float32(0.0),
        jnp.zeros((VMAX, W1MAX, 2), jnp.float32),
    )
    (z, _, cost, done, T, _), (no_hist, ns_hist) = jax.lax.scan(
        step, init,
        (prices, avail.astype(jnp.int32), pr, thr_s, z_exp_end, eff_slots, ts),
    )
    return _finalize(jcfg, j, tput, z, cost, done, T, no_hist, ns_hist)


def _simulate_one_cheap(kind, sigma, cfrac, j: JobArrays, tput, prices, avail,
                        collect: bool = False, fallback=None):
    """Non-AHAP lane (AHANP/OD/MSU/UP/RAND_DEADLINE): no forecasts, no
    window DP — the whole step is a handful of VPU ops. ``collect``
    (static) appends the ``_TEL_SLOTS`` series to the scan ys. Cheap lanes
    consume no predictions, so ``fallback`` never changes their decisions;
    it only (with collect) appends all-zero ``_TEL_FALLBACK`` placeholder
    series so the merged pool result keeps one uniform key set."""
    dmax = prices.shape[0]
    jcfg = _job_cfg(j)

    def step(carry, xs):
        z, n_prev, cost, done, T, prev_avail = carry
        price, av, t = xs
        an_o, an_s = _ahanp_rule(j, sigma, z, t, price, av, n_prev, prev_avail)
        od_o, od_s = _od_rule(j, tput, z, t, price, av)
        ms_o, ms_s = _msu_rule(j, tput, z, t, price, av)
        up_o, up_s = _up_rule(j, tput, z, t, price, av)
        rd_o, rd_s = _rand_rule(j, tput, cfrac, z, t, price, av)
        n_o = jnp.select(
            [kind == 1, kind == 2, kind == 3, kind == 4, kind == 5],
            [an_o, od_o, ms_o, up_o, rd_o],
        )
        n_s = jnp.select(
            [kind == 1, kind == 2, kind == 3, kind == 4, kind == 5],
            [an_s, od_s, ms_s, up_s, rd_s],
        )
        n_prev0 = n_prev
        z, n_prev, cost, done, T, n_o, n_s, active = _execute(
            j, tput, z, n_prev, cost, done, T, t, n_o, n_s, price, av
        )
        prev_avail = jnp.where(active, av, prev_avail)
        ys = (n_o, n_s)
        if collect:
            ys = ys + _slot_telemetry(j, n_prev0, z, n_o, n_s, active,
                                      price, av)
            if fallback is not None:
                ys = ys + (jnp.bool_(False), jnp.float32(0.0))
        return (z, n_prev, cost, done, T, prev_avail), ys

    init = (
        jnp.float32(0.0), jnp.int32(0), jnp.float32(0.0),
        jnp.bool_(False), jnp.float32(0.0), avail[0].astype(jnp.int32),
    )
    (z, _, cost, done, T, _), ys = jax.lax.scan(
        step, init, (prices, avail.astype(jnp.int32), jnp.arange(dmax))
    )
    out = _finalize(jcfg, j, tput, z, cost, done, T, ys[0], ys[1])
    if collect:
        keys = _TEL_SLOTS + (_TEL_FALLBACK if fallback is not None else ())
        for key, hist in zip(keys, ys[2:]):
            out[key] = hist
    return out


# ---------------------------------------------------------------------------
# Pool entry points: partition by kind, scatter back to pool order
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("tput", "backend", "collect", "fallback"))
def _pool_ahap(omega, v, sigma, rho, j: JobArrays, tput, prices, avail, pred,
               backend: str, collect: bool = False, fallback=None):
    return _simulate_lanes_ahap(
        omega, v, sigma, rho, j, tput, prices, avail, pred, backend,
        collect=collect, fallback=fallback,
    )


@functools.partial(jax.jit, static_argnames=("tput", "collect", "fallback"))
def _pool_cheap(kind, sigma, cfrac, j: JobArrays, tput, prices, avail,
                collect: bool = False, fallback=None):
    fn = lambda k, s, c: _simulate_one_cheap(k, s, c, j, tput, prices, avail,
                                             collect=collect,
                                             fallback=fallback)
    return jax.vmap(fn)(kind, sigma, cfrac)


@functools.partial(jax.jit,
                   static_argnames=("tput", "backend", "collect", "fallback"))
def _pool_jobs_ahap(omega, v, sigma, rho, jobs: JobArrays, tput,
                    prices, avail, pred, backend: str, collect: bool = False,
                    fallback=None):
    def per_job(job_row, pr_, av_, pm_):
        return _simulate_lanes_ahap(
            omega, v, sigma, rho, job_row, tput, pr_, av_, pm_, backend,
            collect=collect, fallback=fallback,
        )

    return jax.vmap(per_job)(jobs, prices, avail, pred)


@functools.partial(jax.jit, static_argnames=("tput", "collect", "fallback"))
def _pool_jobs_cheap(kind, sigma, cfrac, jobs: JobArrays, tput, prices, avail,
                     collect: bool = False, fallback=None):
    def per_job(job_row, pr_, av_):
        fn = lambda k, s, c: _simulate_one_cheap(
            k, s, c, job_row, tput, pr_, av_, collect=collect,
            fallback=fallback,
        )
        return jax.vmap(fn)(kind, sigma, cfrac)

    return jax.vmap(per_job)(jobs, prices, avail)


def _partition(pool_arrays: dict):
    """(ahap_idx, other_idx, rho, cfrac) as concrete numpy — the pool
    encoding is data, not a tracer, so the split happens once at trace/call
    time."""
    kind = np.asarray(pool_arrays["kind"])
    n = len(kind)
    rho = pool_arrays.get("rho")
    rho = np.ones(n, np.float32) if rho is None else np.asarray(rho, np.float32)
    cfrac = pool_arrays.get("cfrac")
    cfrac = (np.zeros(n, np.float32) if cfrac is None
             else np.asarray(cfrac, np.float32))
    ahap_idx = np.flatnonzero(kind == KIND_AHAP)
    other_idx = np.flatnonzero(kind != KIND_AHAP)
    return ahap_idx, other_idx, rho, cfrac


def _scatter_merge(parts, index_arrays, axis: int):
    """Stitch per-partition result dicts back into original pool order."""
    if len(parts) == 1:
        return parts[0]
    order = np.argsort(np.concatenate(index_arrays), kind="stable")
    return {
        k: jnp.take(
            jnp.concatenate([p[k] for p in parts], axis=axis), order, axis=axis
        )
        for k in parts[0]
    }


def _partition_lane_args(pool_arrays: dict, with_regions: bool):
    """(ahap_idx, other_idx, ahap_args, cheap_args): the per-partition lane
    parameter tuples (numpy) shared by the local and sharded drivers —
    slicing lives in ONE place so a new pool-array slot cannot be wired into
    one driver and silently zero-defaulted in the other. With
    ``with_regions`` each tuple additionally carries the partition's
    (rsel, rmargin) region-strategy slices (defaulting to stay-put lanes
    when the pool encoding predates the region slots)."""
    ahap_idx, other_idx, rho, cfrac = _partition(pool_arrays)
    arr = lambda k: np.asarray(pool_arrays[k])
    n = len(arr("kind"))
    extras = lambda idx: ()
    if with_regions:
        rsel = pool_arrays.get("rsel")
        rsel = (np.zeros(n, np.int32) if rsel is None
                else np.asarray(rsel, np.int32))
        rmargin = pool_arrays.get("rmargin")
        rmargin = (np.zeros(n, np.float32) if rmargin is None
                   else np.asarray(rmargin, np.float32))
        extras = lambda idx: (rsel[idx], rmargin[idx])
    ahap_args = (arr("omega")[ahap_idx], arr("v")[ahap_idx],
                 arr("sigma")[ahap_idx], rho[ahap_idx], *extras(ahap_idx))
    cheap_args = (arr("kind")[other_idx], arr("sigma")[other_idx],
                  cfrac[other_idx], *extras(other_idx))
    return ahap_idx, other_idx, ahap_args, cheap_args


def _run_partitioned(pool_arrays, ahap_call, cheap_call, axis: int,
                     with_regions: bool = False):
    """Shared partition -> dispatch -> scatter-back driver for every
    single-device pool entry point (axis is the policy-lane axis of the
    result leaves; lane slicing in :func:`_partition_lane_args`)."""
    ahap_idx, other_idx, ahap_args, cheap_args = _partition_lane_args(
        pool_arrays, with_regions
    )
    parts, idxs = [], []
    if ahap_idx.size:
        parts.append(ahap_call(*(jnp.asarray(a) for a in ahap_args)))
        idxs.append(ahap_idx)
    if other_idx.size:
        parts.append(cheap_call(*(jnp.asarray(a) for a in cheap_args)))
        idxs.append(other_idx)
    return _scatter_merge(parts, idxs, axis=axis)


def simulate_pool(pool_arrays: dict, j: JobArrays, tput: ThroughputConfig,
                  prices, avail, pred, backend: str = "xla",
                  collect: bool = False, fallback=None):
    """Kind-partitioned pool simulation. pool_arrays from specs_to_arrays;
    results are returned in the original pool order (same leaves/shapes as
    the seed monolithic path, pinned against simulator.simulate).
    ``collect=True`` adds the (P, T) ``tel_*`` flight-recorder series
    (repro.obs) to the result; False is the bitwise-pinned default.
    ``fallback`` (static repro.chaos.FallbackConfig) arms the AHAP lanes'
    online prediction-failure fallback; None is the bitwise-pinned
    default."""
    return _run_partitioned(
        pool_arrays,
        lambda w, v, s, r: _pool_ahap(
            w, v, s, r, j, tput, prices, avail, pred, backend, collect,
            fallback,
        ),
        lambda k, s, c: _pool_cheap(k, s, c, j, tput, prices, avail, collect,
                                    fallback),
        axis=0,
    )


def simulate_pool_jobs(pool_arrays: dict, jobs: JobArrays, tput: ThroughputConfig,
                       prices, avail, pred, backend: str = "xla",
                       collect: bool = False, fallback=None):
    """Double vmap: jobs (leading axis) x policy pool -> dict of (J, P, ...).

    ``jobs`` leaves are stacked (J,) arrays; prices/avail: (J, d_max);
    pred: (J, d_max, W1MAX, 2). One XLA call per kind-partition simulates
    the paper's whole Fig. 9/10 workload. ``collect=True`` adds the
    (J, P, T) ``tel_*`` flight-recorder series (repro.obs); ``fallback``
    (static repro.chaos.FallbackConfig) arms the AHAP lanes' online
    prediction-failure fallback (None — the default — is bitwise-pinned
    to the shipped program)."""
    return _run_partitioned(
        pool_arrays,
        lambda w, v, s, r: _pool_jobs_ahap(
            w, v, s, r, jobs, tput, prices, avail, pred, backend, collect,
            fallback,
        ),
        lambda k, s, c: _pool_jobs_cheap(k, s, c, jobs, tput, prices, avail,
                                         collect, fallback),
        axis=1,
    )


def _pad_leading(x, pad: int):
    """Pad axis 0 by repeating the last entry ``pad`` times (dropped from the
    result after the sharded run)."""
    x = jnp.asarray(x)
    if not pad:
        return x
    return jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)], axis=0)


@functools.lru_cache(maxsize=None)
def _sharded_pool_call(mesh, tput, backend: str, delta_mig: int,
                       with_regions: bool, ahap: bool, lspec, jspec, ospec,
                       collect: bool = False, fallback=None,
                       has_p_od: bool = False):
    """jit(shard_map)-wrapped runner for one kind partition, cached on the
    static configuration (``collect``, ``fallback`` and ``has_p_od`` are
    part of the key: the telemetry, degradation and per-region-od programs
    are different lowerings; ``has_p_od`` adds a replicated (R,) operand).
    The cache is what keeps the sharded path's per-call cost at dispatch
    level: a fresh shard_map closure per call would retrace (and re-lower)
    the whole pool program every invocation — the prime mover of the old
    1000-job sharded-scale regression."""
    from jax.experimental.shard_map import shard_map

    if ahap and with_regions:
        if has_p_od:
            def local(w, v_, s, r, rs, rm, jb, pr_, av_, pm_, po):
                return _pool_jobs_ahap_regions(
                    w, v_, s, r, rs, rm, jb, tput, pr_, av_, pm_, backend,
                    delta_mig, collect, fallback, po,
                )
        else:
            def local(w, v_, s, r, rs, rm, jb, pr_, av_, pm_):
                return _pool_jobs_ahap_regions(
                    w, v_, s, r, rs, rm, jb, tput, pr_, av_, pm_, backend,
                    delta_mig, collect, fallback,
                )
        n_lane = 6
    elif ahap:
        def local(w, v_, s, r, jb, pr_, av_, pm_):
            return _pool_jobs_ahap(w, v_, s, r, jb, tput, pr_, av_, pm_,
                                   backend, collect, fallback)
        n_lane = 4
    elif with_regions:
        if has_p_od:
            def local(k, s, c, rs, rm, jb, pr_, av_, pm_, po):
                return _pool_jobs_cheap_regions(
                    k, s, c, rs, rm, jb, tput, pr_, av_, pm_, delta_mig,
                    collect, fallback, po,
                )
        else:
            def local(k, s, c, rs, rm, jb, pr_, av_, pm_):
                return _pool_jobs_cheap_regions(
                    k, s, c, rs, rm, jb, tput, pr_, av_, pm_, delta_mig,
                    collect, fallback,
                )
        n_lane = 5
    else:
        # pm_ rides along unused: cheap lanes take no forecasts
        def local(k, s, c, jb, pr_, av_, pm_):
            return _pool_jobs_cheap(k, s, c, jb, tput, pr_, av_, collect,
                                    fallback)
        n_lane = 3
    from jax.sharding import PartitionSpec

    # the tiny (R,) od-multiplier vector is replicated to every device
    pod_spec = (PartitionSpec(),) if has_p_od else ()
    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(lspec,) * n_lane + (jspec,) * 4 + pod_spec,
        out_specs=ospec, check_rep=False,
    ))


def _run_partitioned_sharded(pool_arrays, jobs, tput, prices, avail, pred,
                             backend: str, mesh, *, with_regions: bool = False,
                             delta_mig: int = 0, collect: bool = False,
                             fallback=None, p_od=None):
    """Sharded twin of :func:`_run_partitioned`: partition by kind on the
    host, then lay each partition's (jobs x lanes) grid over ``mesh``.

    Jobs shard the mesh's job axes; on a 2-D ``("jobs", "lanes")`` pool mesh
    (launch.mesh.make_pool_mesh(shape=(a, b))) each partition's policy-lane
    axis additionally shards over ``"lanes"`` — the kind split happens
    first, so a lane shard is uniformly DP-heavy (AHAP) or uniformly cheap.
    Both axes pad to divisibility by repeating the last entry; padding is
    dropped before the scatter-merge back to pool order. Market data
    (prices/avail/pred) is sharded over jobs and replicated only across the
    lane axis, where every lane shard genuinely needs all of it."""
    from repro import sharding as shardlib
    from repro.launch.mesh import pool_mesh_job_axes

    jobs_axes, n_jobs_dev, n_lane_dev = pool_mesh_job_axes(mesh)

    n_jobs = int(np.shape(jobs.workload)[0])
    pad_j = (-n_jobs) % n_jobs_dev
    if pad_j:
        jobs = JobArrays(*[_pad_leading(f, pad_j) for f in jobs])
        prices, avail, pred = (
            _pad_leading(x, pad_j) for x in (prices, avail, pred)
        )
    # resolve the logical axes against the mesh (divisibility always holds
    # after padding; a non-matching mesh degrades to replication)
    rules = {**shardlib.DEFAULT_RULES, "jobs": jobs_axes}
    jspec = shardlib.resolve_spec(("jobs",), (n_jobs + pad_j,), mesh, rules)

    ahap_idx, other_idx, ahap_args, cheap_args = _partition_lane_args(
        pool_arrays, with_regions
    )
    pr_j, av_j, pm_j = (jnp.asarray(x) for x in (prices, avail, pred))
    pod_args = () if p_od is None else (jnp.asarray(p_od, jnp.float32),)

    def run_part(ahap: bool, lane_arrays):
        p_l = int(np.shape(lane_arrays[0])[0])
        pad_l = (-p_l) % n_lane_dev
        lane_in = tuple(_pad_leading(a, pad_l) for a in lane_arrays)
        lspec = shardlib.resolve_spec(("lanes",), (p_l + pad_l,), mesh, rules)
        ospec = shardlib.resolve_spec(
            ("jobs", "lanes"), (n_jobs + pad_j, p_l + pad_l), mesh, rules
        )
        call = _sharded_pool_call(
            mesh, tput, backend, int(delta_mig), with_regions, ahap,
            lspec, jspec, ospec, collect, fallback, p_od is not None,
        )
        out = call(*lane_in, jobs, pr_j, av_j, pm_j, *pod_args)
        if pad_l:
            out = {k: v[:, :p_l] for k, v in out.items()}
        return out

    parts, idxs = [], []
    if ahap_idx.size:
        parts.append(run_part(True, ahap_args))
        idxs.append(ahap_idx)
    if other_idx.size:
        parts.append(run_part(False, cheap_args))
        idxs.append(other_idx)
    out = _scatter_merge(parts, idxs, axis=1)
    if pad_j:
        out = {k: v[:n_jobs] for k, v in out.items()}
    return out


def simulate_pool_jobs_sharded(
    pool_arrays: dict,
    jobs: JobArrays,
    tput: ThroughputConfig,
    prices, avail, pred,
    backend: str = "xla",
    mesh=None,
    collect: bool = False,
    fallback=None,
):
    """Device-sharded :func:`simulate_pool_jobs`: the (jobs x lanes) grid is
    laid over ``mesh`` (default: repro.launch.mesh.make_pool_mesh over every
    visible device, jobs-only). On a 1-D mesh jobs ride the mesh axis and
    lanes stay whole per device; a 2-D ``("jobs", "lanes")`` mesh
    (``make_pool_mesh(shape=(a, b))``) additionally shards each kind
    partition's lane axis — for small job counts with huge pools the lane
    axis is where the parallelism is. The kind partition happens *before*
    sharding, so each device runs a uniform DP-heavy-AHAP or cheap lane
    slice of its job shard (load balance by construction). Jobs and lanes
    that do not divide their mesh axis are padded by repeating the last
    entry; padding is dropped from the result.

    Per-(job, lane) cells are independent and every op is elementwise over
    both axes, so the result is BITWISE-equal to ``simulate_pool_jobs``
    (pinned in tests/test_sharded_pool.py for the jobs, lanes and 2-D
    layouts). With one visible device this falls through to
    ``simulate_pool_jobs`` itself. ``collect=True`` adds the (J, P, T)
    ``tel_*`` flight-recorder series (repro.obs); telemetry shards like
    the allocation histories, so sharded collect runs stay bitwise-equal
    to unsharded ones. ``fallback`` (static repro.chaos.FallbackConfig)
    arms the AHAP lanes' online prediction-failure fallback — the monitor
    is per-(job, lane)-cell local, so sharded fallback runs stay
    bitwise-equal to unsharded ones too.
    """
    from repro.launch.mesh import make_pool_mesh

    if mesh is None:
        mesh = make_pool_mesh()
    if int(np.prod(mesh.devices.shape)) == 1:
        return simulate_pool_jobs(
            pool_arrays, jobs, tput, prices, avail, pred, backend=backend,
            collect=collect, fallback=fallback,
        )
    return _run_partitioned_sharded(
        pool_arrays, jobs, tput, prices, avail, pred, backend, mesh,
        collect=collect, fallback=fallback,
    )


# ---------------------------------------------------------------------------
# Multi-region lanes (BEYOND-PAPER, SkyNomad arXiv:2601.06520)
# ---------------------------------------------------------------------------
#
# ``simulate_pool_regions`` layers per-slot region selection over the kind-
# partitioned scans: every lane carries a current-region state, scores all
# regions each slot (vectorized, from data precomputed outside the scan),
# switches with a hysteresis margin, pays ``delta_mig`` zero-allocation
# slots per switch (checkpoint transfer), and feeds the selected region's
# (price, avail, forecast) into the unmodified decision rules. With R == 1
# the selector can never leave region 0 and every migration branch is a
# no-op ``where`` passthrough, so results are BITWISE-identical to
# ``simulate_pool_jobs`` (pinned in tests/test_region_sim.py).

# the pred_horizon score averages a fixed-width forecast window; the python
# reference (policies.RegionSelector.scores) pads/trims to the same width
assert RSEL_PRED_WINDOW == W1MAX


def _region_scores(j: JobArrays, prices, avail, pred):
    """(dmax, 4, R) lower-better scores from (R, dmax) market data and
    (R, dmax, W1MAX, 2) forecasts — the jnp twin of
    policies.RegionSelector.scores, all four RSEL_* strategies at once
    (lanes gather their row by ``rsel``). Scan-invariant: computed once per
    (job, trace)."""
    nmin_f = j.n_min.astype(jnp.float32)
    dead = (avail < j.n_min).astype(jnp.float32)
    price_sc = prices + RSEL_BIG * dead                   # (R, dmax)
    avail_sc = -avail.astype(jnp.float32)
    pdead = (pred[..., 1] < nmin_f).astype(jnp.float32)
    pred_sc = jnp.mean(pred[..., 0] + RSEL_BIG * pdead, axis=-1)
    sc = jnp.stack(
        [jnp.zeros_like(price_sc), price_sc, avail_sc, pred_sc]
    )                                                     # (4, R, dmax)
    return jnp.transpose(sc, (2, 0, 1))


def _region_step(cur, mig_left, sc_row, rmargin, delta_mig: int, inactive):
    """One slot of region selection: argmin with hysteresis + migration
    bookkeeping. Batched over lanes (cur/mig_left (P,), sc_row (P, R)) or
    scalar (cur/mig_left scalars, sc_row (R,)). Returns
    (cur, mig_left, migrating, switched); ``migrating`` slots execute with
    zero instances (the checkpoint is in transit). ``inactive`` lanes
    (completed, or past their deadline in a heterogeneous-deadline batch)
    never switch — the reference loop has stopped by then, so late score
    flips must not move (or count against) such a job."""
    best = jnp.argmin(sc_row, axis=-1).astype(jnp.int32)
    cur_sc = jnp.take_along_axis(sc_row, cur[..., None], -1)[..., 0]
    best_sc = jnp.take_along_axis(sc_row, best[..., None], -1)[..., 0]
    switch = ((best != cur) & (best_sc + rmargin < cur_sc)
              & (mig_left == 0) & ~inactive)
    cur = jnp.where(switch, best, cur)
    mig_left = jnp.where(
        switch, jnp.int32(delta_mig), jnp.maximum(mig_left - 1, 0)
    )
    return cur, mig_left, mig_left > 0, switch


def _simulate_lanes_ahap_regions(omega, v, sigma, rho, rsel, rmargin,
                                 j: JobArrays, tput, prices, avail, pred,
                                 backend: str, delta_mig: int,
                                 collect: bool = False, fallback=None,
                                 p_od=None):
    """Region-aware :func:`_simulate_lanes_ahap`: prices/avail are (R, dmax),
    pred is (R, dmax, W1MAX, 2). The AHAP scaffolding is precomputed per
    (lane, region, slot); each scan slot selects a region per lane and
    gathers that region's row before the unchanged lane-batched CHC rule.

    ``collect`` (static) appends the ``_TEL_SLOTS`` series plus the
    ``_TEL_REGION`` pair (per-slot region occupancy + switch events) to the
    scan ys; False traces the identical shipped program. ``fallback``
    (static FallbackConfig, or None) arms the prediction-health monitor of
    :func:`_simulate_lanes_ahap`, except the error EWMA is per-lane (P,) —
    lanes occupy different regions, so each lane scores the 1-step-ahead
    forecast of ITS region against that region's realized market. ``p_od``
    (traced (R,) array, or None) scales the job's on-demand price per
    region (multipliers; termination billing uses the lane's final region);
    None traces the flat-od program unchanged."""
    dmax = prices.shape[1]
    p = omega.shape[0]
    jcfg = _job_cfg(j)
    ts = jnp.arange(dmax)
    av_i = avail.astype(jnp.int32)
    # per-region od price: thr_s thresholds see the (R, 1) effective price
    # broadcast against the (R, W1MAX) forecast rows
    j_pre = j if p_od is None else j._replace(p_o=j.p_o * p_od[:, None])
    # slot-major from the start (see _simulate_lanes_ahap): the (R, dmax)
    # raw forecast stack is transposed ONCE (small), then slots ride the
    # outer vmap so the big per-(slot, lane, region) tensors are born in
    # scan-xs layout — the old lane-major vmap built (P, R, dmax, ...)
    # tensors and 5-D transposed copies of them at every scan boundary.
    pred_sm = jnp.swapaxes(pred, 0, 1)           # (dmax, R, W1MAX, 2)
    pr, thr_s, z_exp_end, eff_slots = jax.vmap(
        lambda t, pm: jax.vmap(
            lambda w, s, r: _ahap_precompute(j_pre, w, s, r, t, pm)
        )(omega, sigma, rho)
    )(ts, pred_sm)
    # pr (dmax, P, R, W1MAX, 2); thr_s (dmax, P, R, W1MAX); rest (dmax, P)
    sc = _region_scores(j, prices, av_i, pred)[:, rsel]  # (dmax, P, R)
    lane = jnp.arange(p)
    if fallback is not None:
        thr = jnp.float32(fallback.threshold)
        prev1 = jnp.swapaxes(jax.vmap(_fallback_prev1)(pred), 0, 1)
        prev_av = jnp.swapaxes(
            jnp.concatenate([av_i[:, :1], av_i[:, :-1]], axis=1), 0, 1
        )                                        # (dmax, R)

    def step(carry, xs):
        if fallback is not None:
            z, n_prev, cost, done, T, plans, cur, mig_left, err = carry
            (prices_t, avail_t, pr_t, thr_t, zee_t, eff_t, sc_t, t,
             p1_t, pav_t) = xs
        else:
            z, n_prev, cost, done, T, plans, cur, mig_left = carry
            prices_t, avail_t, pr_t, thr_t, zee_t, eff_t, sc_t, t = xs
        cur, mig_left, migrating, switch = _region_step(
            cur, mig_left, sc_t, rmargin, delta_mig,
            done | (t >= j.deadline),
        )
        price = prices_t[cur]                    # (P,) per-lane region price
        av = avail_t[cur]
        j_t = j if p_od is None else j._replace(p_o=j.p_o * p_od[cur])
        jcfg_t = jcfg if p_od is None else _job_cfg(j_t)
        if fallback is not None:
            p1_sel = p1_t[cur]                   # (P, 2) lane-region forecasts
            err = _fallback_error(fallback, err, price, av,
                                  (p1_sel[:, 0], p1_sel[:, 1]))
            fb = err > thr
        n_o, n_s, plans = _ahap_rule_batch(
            jcfg_t, j_t, tput, v, backend, z, t, price, av, plans,
            pr_t[lane, cur], thr_t[lane, cur], zee_t, eff_t,
        )
        if fallback is not None:
            an_o, an_s = _ahanp_rule(j_t, sigma, z, t, price, av, n_prev,
                                     pav_t[cur])
            n_o = jnp.where(fb, an_o, n_o)
            n_s = jnp.where(fb, an_s, n_s)
        n_o = jnp.where(migrating, 0, n_o)
        n_s = jnp.where(migrating, 0, n_s)
        n_prev0 = n_prev
        z, n_prev, cost, done, T, n_o, n_s, active = _execute(
            j_t, tput, z, n_prev, cost, done, T, t, n_o, n_s, price, av
        )
        ys = (n_o, n_s, cur, switch)
        if collect:
            ys = ys + _slot_telemetry(j_t, n_prev0, z, n_o, n_s, active,
                                      price, av)
            ys = ys + (cur, switch)
            if fallback is not None:
                ys = ys + (jnp.broadcast_to(fb, n_o.shape),
                           jnp.broadcast_to(err, n_o.shape))
        new_carry = (z, n_prev, cost, done, T, plans, cur, mig_left)
        if fallback is not None:
            new_carry = new_carry + (err,)
        return new_carry, ys

    init = (
        jnp.zeros((p,), jnp.float32), jnp.zeros((p,), jnp.int32),
        jnp.zeros((p,), jnp.float32), jnp.zeros((p,), jnp.bool_),
        jnp.zeros((p,), jnp.float32),
        jnp.zeros((p, VMAX, W1MAX, 2), jnp.float32),
        jnp.argmin(sc[0], axis=-1).astype(jnp.int32),  # free initial placement
        jnp.zeros((p,), jnp.int32),
    )
    xs = (jnp.swapaxes(prices, 0, 1), jnp.swapaxes(av_i, 0, 1),
          pr, thr_s, z_exp_end, eff_slots, sc, ts)
    if fallback is not None:
        init = init + (jnp.zeros((p,), jnp.float32),)
        xs = xs + (prev1, prev_av)
    (z, _, cost, done, T, _, cur_end, *_rest), ys = jax.lax.scan(
        step, init, xs
    )
    no_hist, ns_hist, cur_hist, sw_hist = ys[:4]
    j_fin = j if p_od is None else j._replace(p_o=j.p_o * p_od[cur_end])
    jcfg_fin = jcfg if p_od is None else _job_cfg(j_fin)
    out = _finalize(jcfg_fin, j_fin, tput, z, cost, done, T,
                    jnp.swapaxes(no_hist, 0, 1), jnp.swapaxes(ns_hist, 0, 1))
    out["region"] = jnp.swapaxes(cur_hist, 0, 1)
    out["migrations"] = sw_hist.astype(jnp.int32).sum(axis=0)
    if collect:
        keys = (_TEL_SLOTS + _TEL_REGION
                + (_TEL_FALLBACK if fallback is not None else ()))
        for key, hist in zip(keys, ys[4:]):
            out[key] = jnp.swapaxes(hist, 0, 1)
    return out


def _simulate_one_cheap_regions(kind, sigma, cfrac, rsel, rmargin,
                                j: JobArrays, tput, prices, avail, scores,
                                delta_mig: int, collect: bool = False,
                                fallback=None, p_od=None):
    """Region-aware :func:`_simulate_one_cheap`: same DP-free rules, fed the
    per-slot selected region's (price, avail). ``scores`` is the
    (dmax, N_RSEL, R) tensor from :func:`_region_scores` (shared across the
    cheap lanes of one job). ``collect`` appends the ``_TEL_SLOTS`` +
    ``_TEL_REGION`` series; cheap lanes consume no predictions, so
    ``fallback`` only (with collect) appends the all-zero ``_TEL_FALLBACK``
    placeholders that keep the merged pool key set uniform. ``p_od``
    ((R,) multipliers, or None) scales the on-demand price by the occupied
    region, as in :func:`_simulate_lanes_ahap_regions`."""
    dmax = prices.shape[1]
    jcfg = _job_cfg(j)
    av_i = avail.astype(jnp.int32)
    sc = scores[:, rsel]                                  # (dmax, R)
    cur0 = jnp.argmin(sc[0]).astype(jnp.int32)

    def step(carry, xs):
        z, n_prev, cost, done, T, prev_avail, cur, mig_left = carry
        prices_t, avail_t, sc_t, t = xs
        cur, mig_left, migrating, switch = _region_step(
            cur, mig_left, sc_t, rmargin, delta_mig,
            done | (t >= j.deadline),
        )
        price = prices_t[cur]
        av = avail_t[cur]
        j_t = j if p_od is None else j._replace(p_o=j.p_o * p_od[cur])
        an_o, an_s = _ahanp_rule(j_t, sigma, z, t, price, av, n_prev,
                                 prev_avail)
        od_o, od_s = _od_rule(j_t, tput, z, t, price, av)
        ms_o, ms_s = _msu_rule(j_t, tput, z, t, price, av)
        up_o, up_s = _up_rule(j_t, tput, z, t, price, av)
        rd_o, rd_s = _rand_rule(j_t, tput, cfrac, z, t, price, av)
        n_o = jnp.select(
            [kind == 1, kind == 2, kind == 3, kind == 4, kind == 5],
            [an_o, od_o, ms_o, up_o, rd_o],
        )
        n_s = jnp.select(
            [kind == 1, kind == 2, kind == 3, kind == 4, kind == 5],
            [an_s, od_s, ms_s, up_s, rd_s],
        )
        n_o = jnp.where(migrating, 0, n_o)
        n_s = jnp.where(migrating, 0, n_s)
        n_prev0 = n_prev
        z, n_prev, cost, done, T, n_o, n_s, active = _execute(
            j_t, tput, z, n_prev, cost, done, T, t, n_o, n_s, price, av
        )
        prev_avail = jnp.where(active, av, prev_avail)
        ys = (n_o, n_s, cur, switch)
        if collect:
            ys = ys + _slot_telemetry(j_t, n_prev0, z, n_o, n_s, active,
                                      price, av)
            ys = ys + (cur, switch)
            if fallback is not None:
                ys = ys + (jnp.bool_(False), jnp.float32(0.0))
        return ((z, n_prev, cost, done, T, prev_avail, cur, mig_left), ys)

    init = (
        jnp.float32(0.0), jnp.int32(0), jnp.float32(0.0),
        jnp.bool_(False), jnp.float32(0.0), av_i[cur0, 0],
        cur0, jnp.int32(0),
    )
    (z, _, cost, done, T, _, cur_end, _), ys = jax.lax.scan(
        step, init,
        (jnp.swapaxes(prices, 0, 1), jnp.swapaxes(av_i, 0, 1), sc,
         jnp.arange(dmax)),
    )
    no_hist, ns_hist, cur_hist, sw_hist = ys[:4]
    j_fin = j if p_od is None else j._replace(p_o=j.p_o * p_od[cur_end])
    jcfg_fin = jcfg if p_od is None else _job_cfg(j_fin)
    out = _finalize(jcfg_fin, j_fin, tput, z, cost, done, T, no_hist, ns_hist)
    out["region"] = cur_hist
    out["migrations"] = sw_hist.astype(jnp.int32).sum()
    if collect:
        keys = (_TEL_SLOTS + _TEL_REGION
                + (_TEL_FALLBACK if fallback is not None else ()))
        for key, hist in zip(keys, ys[4:]):
            out[key] = hist
    return out


@functools.partial(jax.jit, static_argnames=("tput", "backend", "delta_mig",
                                             "collect", "fallback"))
def _pool_jobs_ahap_regions(omega, v, sigma, rho, rsel, rmargin,
                            jobs: JobArrays, tput, prices, avail, pred,
                            backend: str, delta_mig: int,
                            collect: bool = False, fallback=None, p_od=None):
    def per_job(job_row, pr_, av_, pm_):
        return _simulate_lanes_ahap_regions(
            omega, v, sigma, rho, rsel, rmargin, job_row, tput,
            pr_, av_, pm_, backend, delta_mig,
            collect=collect, fallback=fallback, p_od=p_od,
        )

    return jax.vmap(per_job)(jobs, prices, avail, pred)


@functools.partial(jax.jit, static_argnames=("tput", "delta_mig", "collect",
                                             "fallback"))
def _pool_jobs_cheap_regions(kind, sigma, cfrac, rsel, rmargin,
                             jobs: JobArrays, tput, prices, avail, pred,
                             delta_mig: int, collect: bool = False,
                             fallback=None, p_od=None):
    def per_job(job_row, pr_, av_, pm_):
        scores = _region_scores(job_row, pr_, av_.astype(jnp.int32), pm_)
        fn = lambda k, s, c, rs, rm: _simulate_one_cheap_regions(
            k, s, c, rs, rm, job_row, tput, pr_, av_, scores, delta_mig,
            collect=collect, fallback=fallback, p_od=p_od,
        )
        return jax.vmap(fn)(kind, sigma, cfrac, rsel, rmargin)

    return jax.vmap(per_job)(jobs, prices, avail, pred)


def _as_p_od(p_od, n_regions: int):
    """Normalize a per-region on-demand price multiplier: None passes
    through (the flat-od program is traced unchanged), a scalar broadcasts
    to (R,), an (R,) array is taken as-is."""
    if p_od is None:
        return None
    return jnp.broadcast_to(
        jnp.asarray(p_od, jnp.float32).reshape(-1), (n_regions,)
    )


def simulate_pool_regions(pool_arrays: dict, jobs: JobArrays,
                          tput: ThroughputConfig, prices, avail, pred,
                          backend: str = "xla", *, delta_mig: int,
                          collect: bool = False, fallback=None, p_od=None):
    """Multi-region :func:`simulate_pool_jobs`: jobs x pool over an R-region
    market. ``prices``/``avail`` are (J, R, d_max), ``pred`` is
    (J, R, d_max, W1MAX, 2) (see ``prepare_inputs_regions``); ``delta_mig``
    is the checkpoint-transfer cost in lost slots — required (pass
    ``market.delta_mig``; a default here would silently override the cost a
    RegionalMarket was built with). Lanes read their region-selection
    strategy from pool_arrays' ``rsel``/``rmargin`` slots
    (policy_pool.region_pool; absent keys mean every lane stays put).

    Returns the ``simulate_pool_jobs`` leaves (J, P, ...) plus ``region``
    (the lane's region each slot) and ``migrations`` (completed switches).
    With R == 1 the shared leaves are bitwise-identical to
    ``simulate_pool_jobs``.

    ``collect=True`` adds the (J, P, T) ``tel_*`` flight-recorder series
    plus ``tel_region``/``tel_migration`` (per-slot occupancy and switch
    events; slot sums reconcile against ``migrations`` in
    obs.ledger.migration_reconciliation); ``fallback`` (static
    repro.chaos.FallbackConfig) arms the AHAP lanes' per-lane online
    prediction-failure monitor; ``p_od`` (scalar or (R,)) scales the
    on-demand price by occupied region (``market.p_od``; multipliers of the
    job's flat ``on_demand_price``). All three default to the
    bitwise-pinned shipped program."""
    p_od = _as_p_od(p_od, np.shape(prices)[1])
    return _run_partitioned(
        pool_arrays,
        lambda w, v, s, r, rs, rm: _pool_jobs_ahap_regions(
            w, v, s, r, rs, rm, jobs, tput, prices, avail, pred,
            backend, delta_mig, collect, fallback, p_od,
        ),
        lambda k, s, c, rs, rm: _pool_jobs_cheap_regions(
            k, s, c, rs, rm, jobs, tput, prices, avail, pred, delta_mig,
            collect, fallback, p_od,
        ),
        axis=1, with_regions=True,
    )


def simulate_pool_regions_sharded(
    pool_arrays: dict,
    jobs: JobArrays,
    tput: ThroughputConfig,
    prices, avail, pred,
    backend: str = "xla",
    *,
    delta_mig: int,
    mesh=None,
    collect: bool = False,
    fallback=None,
    p_od=None,
):
    """Device-sharded :func:`simulate_pool_regions`: jobs (and, on a 2-D
    pool mesh, lanes) shard exactly as in
    :func:`simulate_pool_jobs_sharded`; the small region axis rides along
    whole per device inside the (J, R, T) market tensors (``p_od``, when
    set, is replicated to every device). BITWISE-equal to
    ``simulate_pool_regions`` (pinned in tests/test_region_sim.py and the
    forced-4-device subprocess in tests/test_sharded_pool.py); falls
    through to it on one device. ``collect``/``fallback``/``p_od`` as in
    :func:`simulate_pool_regions` (per-(job, lane)-cell local, so sharded
    runs stay bitwise-equal to unsharded ones)."""
    from repro.launch.mesh import make_pool_mesh

    if mesh is None:
        mesh = make_pool_mesh()
    if int(np.prod(mesh.devices.shape)) == 1:
        return simulate_pool_regions(
            pool_arrays, jobs, tput, prices, avail, pred, backend=backend,
            delta_mig=delta_mig, collect=collect, fallback=fallback,
            p_od=p_od,
        )
    return _run_partitioned_sharded(
        pool_arrays, jobs, tput, prices, avail, pred, backend, mesh,
        with_regions=True, delta_mig=int(delta_mig), collect=collect,
        fallback=fallback, p_od=_as_p_od(p_od, np.shape(prices)[1]),
    )


def prepare_inputs_regions(market, pred_matrix, d_max: int):
    """Regional twin of :func:`prepare_inputs`: (R, d_max) prices/avail and
    an (R, d_max, W1MAX, 2) prediction stack (pad/trim per region; None
    falls back to broadcasting the observed present, as single-region)."""
    prices = jnp.asarray(market.prices[:, :d_max], jnp.float32)
    avail = jnp.asarray(market.avail[:, :d_max], jnp.int32)
    if pred_matrix is None:
        pm = np.zeros(market.prices[:, :d_max].shape + (W1MAX, 2), np.float32)
        pm[..., 0] = np.asarray(market.prices[:, :d_max])[..., None]
        pm[..., 1] = np.asarray(market.avail[:, :d_max])[..., None]
    else:
        pm = np.asarray(pred_matrix[:, :d_max, :W1MAX], np.float32)
        if pm.shape[2] < W1MAX:
            pad = np.repeat(pm[:, :, -1:], W1MAX - pm.shape[2], axis=2)
            pm = np.concatenate([pm, pad], axis=2)
    return prices, avail, jnp.asarray(pm)


@functools.partial(jax.jit, static_argnames=("tput", "backend"))
def simulate_pool_monolithic(pool_arrays: dict, j: JobArrays,
                             tput: ThroughputConfig, prices, avail, pred,
                             backend: str = "xla-gather"):
    """The seed path: every lane runs every rule (window DP included) and
    selects by kind. Kept as the perf baseline (benchmarks/pool_sim_bench.py)
    and as a parity cross-check for the partitioned path."""
    n = len(pool_arrays["kind"])
    rho = pool_arrays.get("rho")
    rho = jnp.ones(n, jnp.float32) if rho is None else jnp.asarray(rho)
    cfrac = pool_arrays.get("cfrac")
    cfrac = jnp.zeros(n, jnp.float32) if cfrac is None else jnp.asarray(cfrac)
    fn = lambda k, w, v, s, r, c: simulate_one(
        k, w, v, s, j, tput, prices, avail, pred, rho=r, cfrac=c,
        backend=backend,
    )
    return jax.vmap(fn)(
        jnp.asarray(pool_arrays["kind"]), jnp.asarray(pool_arrays["omega"]),
        jnp.asarray(pool_arrays["v"]), jnp.asarray(pool_arrays["sigma"]),
        rho, cfrac,
    )


def stack_jobs(jobs) -> JobArrays:
    return JobArrays(*[
        jnp.stack([jnp.asarray(getattr(JobArrays.of(j), f)) for j in jobs])
        for f in JobArrays._fields
    ])


def slice_jobs(jobs: JobArrays, start: int, stop: int) -> JobArrays:
    """Job-axis slice of stacked (K,) JobArrays leaves — the unit of
    core.engine's job-chunked streaming mode."""
    return JobArrays(*[f[start:stop] for f in jobs])


def concat_jobs(parts) -> JobArrays:
    """Concatenate stacked JobArrays along the job axis (host numpy leaves)
    — the inverse of repeated :func:`slice_jobs`; how the scenario grid
    stacks per-regime job blocks regime-major onto one jobs axis."""
    parts = list(parts)
    if len(parts) == 1:
        return parts[0]
    return JobArrays(*[
        np.concatenate([np.asarray(getattr(p, f)) for p in parts])
        for f in JobArrays._fields
    ])


def unstack_jobs(jobs: JobArrays):
    """Stacked (K,) JobArrays -> list of JobConfig (host scalars) — the
    inverse of :func:`stack_jobs`, for python-reference paths that need
    per-job configs (e.g. the pre-engine normalize_utility loop)."""
    n = int(np.shape(jobs.workload)[0])
    rows = [np.asarray(f) for f in jobs]
    return [
        JobConfig(
            workload=float(rows[0][k]), deadline=int(rows[1][k]),
            n_min=int(rows[2][k]), n_max=int(rows[3][k]),
            value=float(rows[4][k]), gamma=float(rows[5][k]),
            on_demand_price=float(rows[6][k]),
        )
        for k in range(n)
    ]


def prepare_inputs(trace, pred_matrix, d_max: int):
    """Pad/trim trace + prediction matrix to (d_max, ...) jnp arrays."""
    prices = jnp.asarray(trace.prices[:d_max], jnp.float32)
    avail = jnp.asarray(trace.avail[:d_max], jnp.int32)
    if pred_matrix is None:
        pm = np.zeros((d_max, W1MAX, 2), np.float32)
        pm[:, :, 0] = np.asarray(trace.prices[:d_max])[:, None]
        pm[:, :, 1] = np.asarray(trace.avail[:d_max])[:, None]
    else:
        pm = np.asarray(pred_matrix[:d_max, :W1MAX], np.float32)
        if pm.shape[1] < W1MAX:
            pad = np.repeat(pm[:, -1:], W1MAX - pm.shape[1], axis=1)
            pm = np.concatenate([pm, pad], axis=1)
    return prices, avail, jnp.asarray(pm)
