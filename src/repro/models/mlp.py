"""Dense MLP: SwiGLU (llama-style, 3 matrices) or plain act (2 matrices, opt bias)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lora as lora_lib
from repro.models.common import act_fn, normal_param, zeros_param
from repro.sharding import shard


def init_mlp(key, cfg, dtype, d_ff=None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 4)
    p = {
        "w1": normal_param(ks[0], (d, f), ("fsdp", "tensor"), dtype),
        "w2": normal_param(ks[1], (f, d), ("tensor", "fsdp"), dtype),
    }
    if cfg.mlp_act == "silu":  # SwiGLU gate
        p["w3"] = normal_param(ks[2], (d, f), ("fsdp", "tensor"), dtype)
    if cfg.mlp_bias:
        p["b1"] = zeros_param((f,), ("tensor",), dtype)
        p["b2"] = zeros_param((d,), (None,), dtype)
    if "mlp" in cfg.lora.targets:
        p["lora"] = lora_lib.init_lora_pair(ks[3], d, (f,), cfg.lora.rank)
    return p


def apply_mlp(cfg, p, x):
    act = act_fn(cfg.mlp_act)
    scale = cfg.lora.alpha / cfg.lora.rank
    h = lora_lib.proj(x, p["w1"], p.get("b1"), p.get("lora"), scale)
    if "w3" in p:  # SwiGLU
        h = act(h) * jnp.einsum("...d,df->...f", x, p["w3"])
    else:
        h = act(h)
    h = shard(h, "batch", "seq", "tensor")
    y = jnp.einsum("...f,fd->...d", h, p["w2"])
    if "b2" in p:
        y = y + p["b2"]
    return y
