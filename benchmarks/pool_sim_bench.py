"""Pool-simulator throughput: the repo's perf trajectory for the hottest path.

Measures slots * policies * jobs / sec over the paper's mixed workload
(112-policy pool + 9 RAND_DEADLINE + 3 baselines, Fig. 9 job distribution):

  seed         the monolithic simulator (every lane evaluates every decision
               rule each slot, window DP included, gather-formulated DP) —
               the state of the repo before the kind-partitioned refactor.
  partitioned  fast_sim.simulate_pool_jobs: AHAP lanes on the DP-bearing
               scan with ONE batched (P_ahap, w1, tn+1) window DP per slot,
               cheap kinds (AHANP/OD/MSU/UP/RAND_DEADLINE) on the DP-free
               scan, scattered back to pool order.
  pallas       the partitioned path with the fused Pallas window-DP kernel —
               one kernel launch per scan slot for the whole lane batch
               (interpret mode on CPU, compiled on TPU).
  sharded      fast_sim.simulate_pool_jobs_sharded over the POOL_SIM_MESH
               pool mesh (default: 1-D jobs mesh over every visible device;
               identical to `partitioned` when one device is visible; force
               more with XLA_FLAGS=--xla_force_host_platform_device_count=N).
  sharded_lanes / sharded_2d   (multi-device only) the lanes-only
               (1, n_dev) and balanced 2-D (a, b) pool meshes — the lane
               axis is the parallelism frontier for small-jobs/huge-pool
               workloads.

`*_scale` rows rerun the XLA paths at the paper's Fig. 9/10 job counts
(1000s of jobs; POOL_SIM_SCALE_JOBS to override). The seed path is not
rerun at scale — it would take minutes; the 3x regression guard
(tests/test_bench_regression.py) reads `speedup_partitioned_vs_seed` from
the base workload. `pool_sim_sharded_scale_vs_partitioned` is the
multi-device scale ratio (partitioned_scale secs / sharded_scale secs,
>= 1.0 means sharding pays for itself at Fig. 9/10 scale) — the guard's
multi-device half pins it.

Env knobs: POOL_SIM_JOBS, POOL_SIM_REPEAT, POOL_SIM_SCALE_JOBS,
POOL_SIM_SCALE_REPEAT (0 skips the scale rows), POOL_SIM_MESH ("4", "2x2",
"1x4", ... — the mesh shape for the sharded rows; "auto"/unset = 1-D over
all devices), POOL_SIM_JSON (redirect the JSON artifact — the regression
guard uses this so its shrunken config never clobbers the tracked
BENCH_pool_sim.json).

Writes BENCH_pool_sim.json (machine-readable rows + speedups) so successive
PRs can track the trajectory; also returned as benchmark rows for run.py.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PAPER_JOB, PAPER_TPUT, Row, job_stream, paper_market

N_JOBS = int(os.environ.get("POOL_SIM_JOBS", "8"))
SCALE_JOBS = int(os.environ.get("POOL_SIM_SCALE_JOBS", "1000"))
DEADLINE = 10
REPEAT = int(os.environ.get("POOL_SIM_REPEAT", "5"))
SCALE_REPEAT = int(os.environ.get("POOL_SIM_SCALE_REPEAT", "2"))

_JSON_PATH = os.environ.get(
    "POOL_SIM_JSON",
    os.path.join(os.path.dirname(os.path.dirname(__file__)),
                 "BENCH_pool_sim.json"),
)


def _workload(n_jobs: int):
    """Fig. 9-style workload: random jobs on random market windows."""
    from repro.core import fast_sim
    from repro.core.predictor import NoisyPredictor

    rng = np.random.default_rng(7)
    jobs = list(job_stream(rng, n_jobs, deadline=DEADLINE))
    market = paper_market(seed=13, days=4)
    traces = [
        market.window(int(rng.integers(0, len(market) - DEADLINE - 1)), DEADLINE + 1)
        for _ in range(n_jobs)
    ]
    prices = np.stack([t.prices[:DEADLINE] for t in traces]).astype(np.float32)
    avail = np.stack([t.avail[:DEADLINE] for t in traces]).astype(np.int64)
    preds = np.stack([
        NoisyPredictor(t, "fixed_uniform", 0.2, seed=i).matrix(
            fast_sim.W1MAX - 1
        )[:DEADLINE]
        for i, t in enumerate(traces)
    ]).astype(np.float32)
    return jobs, prices, avail, preds


def _bench(fn, repeat: int = REPEAT) -> float:
    """Seconds per call at steady state (first call pays compilation)."""
    jax.block_until_ready(fn()["utility"])
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(fn()["utility"])
    return (time.perf_counter() - t0) / repeat


def _balanced_2d(n_dev: int):
    """Largest (a, b) factorization of n_dev with a <= b and a > 1, or None
    (prime / single device — the lanes-only mesh already covers it)."""
    for a in range(int(n_dev ** 0.5), 1, -1):
        if n_dev % a == 0:
            return (a, n_dev // a)
    return None


def run():
    from repro.core import fast_sim
    from repro.core.policy_pool import (
        baseline_specs,
        paper_pool,
        rand_deadline_pool,
        specs_to_arrays,
    )
    from repro.launch.mesh import make_pool_mesh, parse_pool_mesh_shape

    # 112 + 9 + 3: mixed AHAP/AHANP/RAND_DEADLINE/baseline
    pool = paper_pool() + rand_deadline_pool() + baseline_specs()
    arrs = specs_to_arrays(pool)
    jobs, prices, avail, preds = _workload(N_JOBS)
    stacked = fast_sim.stack_jobs(jobs)
    n_pol = len(pool)
    n_dev = jax.device_count()
    work_units = DEADLINE * n_pol * N_JOBS   # slots * policies * jobs per call

    on_tpu = jax.default_backend() == "tpu"
    pallas_backend = "pallas" if on_tpu else "pallas-interpret"
    mesh_shape = parse_pool_mesh_shape(os.environ.get("POOL_SIM_MESH", ""))
    pool_mesh = make_pool_mesh(shape=mesh_shape)

    kind, omega = jnp.asarray(arrs["kind"]), jnp.asarray(arrs["omega"])
    v_, sigma = jnp.asarray(arrs["v"]), jnp.asarray(arrs["sigma"])
    rho, cfrac = jnp.asarray(arrs["rho"]), jnp.asarray(arrs["cfrac"])

    @jax.jit
    def _seed_jobs(jobs_, pr_, av_, pm_):
        # the seed simulate_pool_jobs: double vmap of the monolithic lane
        # (every lane pays the window DP, gather-formulated)
        def per_job(jr, p_, a_, m_):
            fn = lambda k, w, vv, s, r, c: fast_sim.simulate_one(
                k, w, vv, s, jr, PAPER_TPUT, p_, a_, m_, rho=r, cfrac=c,
                backend="xla-gather",
            )
            return jax.vmap(fn)(kind, omega, v_, sigma, rho, cfrac)

        return jax.vmap(per_job)(jobs_, pr_, av_, pm_)

    def seed_path():
        return _seed_jobs(stacked, prices, avail, preds)

    paths = {
        "seed": seed_path,
        "partitioned": lambda: fast_sim.simulate_pool_jobs(
            arrs, stacked, PAPER_TPUT, prices, avail, preds, backend="xla"
        ),
        "pallas": lambda: fast_sim.simulate_pool_jobs(
            arrs, stacked, PAPER_TPUT, prices, avail, preds,
            backend=pallas_backend,
        ),
        "sharded": lambda: fast_sim.simulate_pool_jobs_sharded(
            arrs, stacked, PAPER_TPUT, prices, avail, preds, backend="xla",
            mesh=pool_mesh,
        ),
    }
    if n_dev > 1:
        # the lane-axis frontier: all devices on lanes, and the balanced 2-D
        # grid when the device count factors
        lane_mesh = make_pool_mesh(shape=(1, n_dev))
        paths["sharded_lanes"] = lambda: fast_sim.simulate_pool_jobs_sharded(
            arrs, stacked, PAPER_TPUT, prices, avail, preds, backend="xla",
            mesh=lane_mesh,
        )
        shape_2d = _balanced_2d(n_dev)
        if shape_2d:
            mesh_2d = make_pool_mesh(shape=shape_2d)
            paths["sharded_2d"] = lambda: fast_sim.simulate_pool_jobs_sharded(
                arrs, stacked, PAPER_TPUT, prices, avail, preds,
                backend="xla", mesh=mesh_2d,
            )

    secs, rows = {}, []
    for name, fn in paths.items():
        secs[name] = _bench(fn)
        rate = work_units / secs[name]
        rows.append((f"pool_sim_{name}", secs[name] * 1e6, rate))

    # Fig. 9/10-scale workload (1000s of jobs): XLA paths only — the seed
    # path at this size takes minutes and the interpreter far longer.
    scale_secs = {}
    if SCALE_REPEAT > 0 and SCALE_JOBS > 0:
        s_jobs, s_prices, s_avail, s_preds = _workload(SCALE_JOBS)
        s_stacked = fast_sim.stack_jobs(s_jobs)
        scale_units = DEADLINE * n_pol * SCALE_JOBS
        scale_paths = {
            "partitioned_scale": lambda: fast_sim.simulate_pool_jobs(
                arrs, s_stacked, PAPER_TPUT, s_prices, s_avail, s_preds,
                backend="xla",
            ),
            "sharded_scale": lambda: fast_sim.simulate_pool_jobs_sharded(
                arrs, s_stacked, PAPER_TPUT, s_prices, s_avail, s_preds,
                backend="xla", mesh=pool_mesh,
            ),
        }
        for name, fn in scale_paths.items():
            scale_secs[name] = _bench(fn, repeat=SCALE_REPEAT)
            rows.append((
                f"pool_sim_{name}", scale_secs[name] * 1e6,
                scale_units / scale_secs[name],
            ))
        # >= 1.0 means the sharded path is no slower than single-device
        # partitioned at Fig. 9/10 scale (trivially ~1.0 on one device,
        # where sharded falls back to the partitioned path)
        rows.append((
            "pool_sim_sharded_scale_vs_partitioned", 0.0,
            scale_secs["partitioned_scale"] / scale_secs["sharded_scale"],
        ))

    speedup = secs["seed"] / secs["partitioned"]
    rows.append(("pool_sim_partitioned_speedup", 0.0, speedup))
    rows.append((
        "pool_sim_pallas_speedup", 0.0, secs["seed"] / secs["pallas"]
    ))
    rows.append((
        "pool_sim_sharded_speedup", 0.0, secs["seed"] / secs["sharded"]
    ))

    payload = {
        "workload": {
            "policies": n_pol, "jobs": N_JOBS, "slots": DEADLINE,
            "scale_jobs": SCALE_JOBS if scale_secs else 0,
            "pool": "paper_pool(112) + rand_deadline(9) + baselines(3)",
        },
        "backend": jax.default_backend(),
        "devices": n_dev,
        "pool_mesh": "x".join(map(str, pool_mesh.devices.shape)),
        "pallas_mode": pallas_backend,
        "rows": [
            {"name": n, "us_per_call": us, "derived": d} for n, us, d in rows
        ],
        "speedup_partitioned_vs_seed": speedup,
    }
    # benchmarks/{region_sim,selection_e2e,fleet_sim,scenario_grid}.py merge
    # their rows into the same file in place; a pool_sim rerun must carry
    # them over, not clobber them
    try:
        with open(_JSON_PATH) as f:
            prev = json.load(f)
    except (OSError, json.JSONDecodeError):
        prev = {}
    payload["rows"] += [
        r for r in prev.get("rows", [])
        if str(r.get("name", "")).startswith(
            ("region_sim", "selection_e2e", "fleet_sim", "scenario_grid"))
    ]
    for key in ("region", "selection", "fleet", "scenario_grid"):
        if key in prev:
            payload[key] = prev[key]
    with open(_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
