"""Shard-aware host data loader.

Feeds jitted steps with globally-consistent batches. On a multi-host cluster
each process would load only its shard (``host_slice``); on this single-host
environment the full batch is built and jax distributes it per the step's
in_shardings. Deterministic per (seed, step) so elastic restarts (spot
preemption -> checkpoint restore) resume the exact stream position — that is
what makes the paper's switching cost purely a *time* cost, not a data loss.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from repro.data.synthetic import MarkovLM, token_stream


class ShardedLMLoader:
    def __init__(self, vocab_size: int, global_batch: int, seq_len: int,
                 seed: int = 0):
        self.vocab_size = vocab_size
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.src = MarkovLM(vocab_size, seed)

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a given global step (restart-safe)."""
        rows = []
        for b in range(self.global_batch):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 4099 + b
            )
            rows.append(self.src.sample(rng, self.seq_len).astype(np.int32))
        return {"tokens": np.stack(rows)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def host_slice(self, batch: dict, host_id: int, n_hosts: int) -> dict:
        per = self.global_batch // n_hosts
        return {k: v[host_id * per : (host_id + 1) * per] for k, v in batch.items()}
