"""Policy pool construction (Sec. V-A / VI-A).

The paper's pool: 105 AHAP policies (omega in 1..5, v in 1..omega, sigma in
{0.3 .. 0.9}) + 7 AHANP policies (same sigmas) = 112, indexed 1..112 in
Fig. 10. ``PolicySpec`` is the array encoding shared by the python policies
and the vmapped JAX simulator.

BEYOND-PAPER pool expansions (selector breadth is the robustness lever —
Thm. 2's regret only grows as sqrt(log M)):

* Robust-AHAP (``robust_pool``): availability-pessimistic AHAP, rho < 1.
* RAND_DEADLINE (``rand_deadline_pool``): the optimal randomized
  commitment-threshold strategies of arXiv:2601.14612, discretized as
  quantiles of the optimal commitment CDF — each pool member commits to
  on-demand at a different deterministic fraction of the deadline, so the
  *pool* carries the randomization and the selector learns the best
  quantile for the observed market. These lanes run on the cheap (DP-free)
  scan, so they are nearly free to add. ``rand_deadline_pool(qs, qfn)``
  takes any quantile function; ``uniform_rand_deadline_pool`` is the
  uniform-commitment control family.
* Region lanes (``region_pool``): scheduling policies crossed with
  multi-region selection strategies (greedy-price / greedy-avail /
  predicted-horizon, plain and hysteresis-sticky) for
  fast_sim.simulate_pool_regions — the selector learns region strategy and
  scheduling policy jointly (SkyNomad, arXiv:2601.06520).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.policies import (
    AHANP,
    AHANPParams,
    AHAP,
    AHAPParams,
    BasePolicy,
    MSU,
    ODOnly,
    RSEL_AVAIL,
    RSEL_FIXED,
    RSEL_NAMES,
    RSEL_PRED,
    RSEL_PRICE,
    RandDeadline,
    RandDeadlineParams,
    RegionSelector,
    RegionSelectorParams,
    UP,
    rand_commit_frac,
    uniform_commit_frac,
)

KIND_AHAP, KIND_AHANP, KIND_OD, KIND_MSU, KIND_UP = 0, 1, 2, 3, 4
KIND_RAND = 5
KIND_NAMES = {0: "ahap", 1: "ahanp", 2: "od_only", 3: "msu", 4: "up",
              5: "rand_deadline"}

SIGMAS = (0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)
OMEGAS = (1, 2, 3, 4, 5)
RAND_QS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)


@dataclass(frozen=True)
class PolicySpec:
    kind: int
    omega: int = 0
    v: int = 0
    sigma: float = 0.0
    rho: float = 1.0  # Robust-AHAP availability discount (1.0 = paper AHAP)
    # RAND_DEADLINE commitment-fraction override; < 0 derives the ski-rental
    # optimal fraction from sigma (the quantile) via rand_commit_frac.
    cfrac: float = -1.0
    # multi-region selection: strategy (RSEL_*) + hysteresis margin. The
    # defaults are a no-op for single-region simulation paths, which ignore
    # both fields.
    rsel: int = RSEL_FIXED
    rmargin: float = 0.0

    @property
    def name(self) -> str:
        if self.kind == KIND_AHAP:
            r = f",r={self.rho:.2f}" if self.rho < 1.0 else ""
            base = f"ahap(w={self.omega},v={self.v},s={self.sigma:.1f}{r})"
        elif self.kind == KIND_AHANP:
            base = f"ahanp(s={self.sigma:.1f})"
        elif self.kind == KIND_RAND:
            f = f",f={self.cfrac:.2f}" if self.cfrac >= 0 else ""
            base = f"rand_ddl(q={self.sigma:.2f}{f})"
        else:
            base = KIND_NAMES[self.kind]
        if self.rsel != RSEL_FIXED:
            m = f",m={self.rmargin:g}" if self.rmargin > 0 else ""
            base += f"@{RSEL_NAMES[self.rsel]}{m}"
        return base

    def build(self) -> BasePolicy:
        if self.kind == KIND_AHAP:
            return AHAP(AHAPParams(self.omega, self.v, self.sigma, self.rho))
        if self.kind == KIND_AHANP:
            return AHANP(AHANPParams(self.sigma))
        if self.kind == KIND_RAND:
            cf = self.cfrac if self.cfrac >= 0 else None
            return RandDeadline(RandDeadlineParams(self.sigma, cf))
        return {KIND_OD: ODOnly, KIND_MSU: MSU, KIND_UP: UP}[self.kind]()

    def build_selector(self) -> RegionSelector:
        return RegionSelector(RegionSelectorParams(self.rsel, self.rmargin))


def paper_pool(
    omegas: Sequence[int] = OMEGAS,
    sigmas: Sequence[float] = SIGMAS,
    fixed_v: Optional[int] = None,
    fixed_sigma: Optional[float] = None,
    include_ahanp: bool = True,
    rand_qs: Optional[Sequence[float]] = None,
) -> List[PolicySpec]:
    """105 AHAP + 7 AHANP by default; the fixed_* arguments reproduce the
    Fig. 9 hyperparameter-ablation pools (e.g. v=1 only, or sigma=0.9 only).
    ``rand_qs`` appends RAND_DEADLINE lanes (see rand_deadline_pool) —
    opt-in so the default composition stays the paper's 112."""
    pool: List[PolicySpec] = []
    for w in omegas:
        for v in range(1, w + 1):
            if fixed_v is not None and v != fixed_v:
                continue
            for s in sigmas:
                if fixed_sigma is not None and abs(s - fixed_sigma) > 1e-9:
                    continue
                pool.append(PolicySpec(KIND_AHAP, w, v, s))
    if include_ahanp:
        for s in sigmas:
            if fixed_sigma is not None and abs(s - fixed_sigma) > 1e-9:
                continue
            pool.append(PolicySpec(KIND_AHANP, 0, 0, s))
    if rand_qs is not None:
        pool.extend(rand_deadline_pool(rand_qs))
    return pool


def rand_deadline_pool(
    qs: Sequence[float] = RAND_QS,
    qfn: Optional[Callable[[float], float]] = None,
) -> List[PolicySpec]:
    """BEYOND-PAPER: randomized commitment-threshold strategies
    (arXiv:2601.14612), one lane per quantile of the commitment CDF. The
    quantile rides the ``sigma`` slot of the array encoding.

    ``qfn`` is the quantile function (inverse CDF) of the commitment
    distribution. None keeps the ski-rental-optimal family
    (policies.rand_commit_frac, the default since PR 2); any other
    callable — e.g. ``policies.uniform_commit_frac`` for the naive
    uniform-commitment family — is evaluated here in float64 and carried on
    the spec's ``cfrac`` slot so the python policy and the fast-sim lane
    floor identical f32 bits."""
    if qfn is None:
        return [PolicySpec(KIND_RAND, 0, 0, q) for q in qs]
    pool = []
    for q in qs:
        cf = float(qfn(q))
        if not 0.0 <= cf <= 1.0:  # a negative cf would silently collide
            raise ValueError(     # with the 'unset' cfrac sentinel (< 0)
                f"quantile function returned commitment fraction {cf} for "
                f"q={q}; must lie in [0, 1] (a fraction of the deadline)"
            )
        pool.append(PolicySpec(KIND_RAND, 0, 0, q, cfrac=cf))
    return pool


def uniform_rand_deadline_pool(qs: Sequence[float] = RAND_QS) -> List[PolicySpec]:
    """The uniform-commitment control family: commit at fraction q itself."""
    return rand_deadline_pool(qs, qfn=uniform_commit_frac)


def baseline_specs() -> List[PolicySpec]:
    return [PolicySpec(KIND_OD), PolicySpec(KIND_MSU), PolicySpec(KIND_UP)]


def robust_pool(
    rhos: Sequence[float] = (0.5, 0.7, 0.85),
    omegas: Sequence[int] = (3, 5),
    sigmas: Sequence[float] = (0.3, 0.5, 0.7, 0.9),
) -> List[PolicySpec]:
    """BEYOND-PAPER: Robust-AHAP candidates (availability-pessimistic)."""
    return [
        PolicySpec(KIND_AHAP, w, 1, s, rho=r)
        for r in rhos for w in omegas for s in sigmas
    ]


def region_pool(
    base: Optional[Sequence[PolicySpec]] = None,
    strategies: Sequence[int] = (RSEL_PRICE, RSEL_AVAIL, RSEL_PRED),
    margins: Sequence[float] = (0.0, 0.05),
) -> List[PolicySpec]:
    """BEYOND-PAPER (SkyNomad): cross scheduling policies with region-
    selection strategies so the selector learns region strategy and
    scheduling policy *jointly* — a greedy-price mover wrapped around AHAP
    competes in the same pool as a sticky predicted-horizon mover wrapped
    around MSU, and Thm. 2's sqrt(log M) regret keeps the expansion cheap.

    ``base`` defaults to a compact scheduling slate (three AHAP corners,
    one AHANP, MSU, UP); each base spec is crossed with every (strategy,
    hysteresis margin) pair. margin 0 = plain greedy, margin > 0 = sticky
    variant (no-thrash)."""
    if base is None:
        base = [
            PolicySpec(KIND_AHAP, 3, 1, 0.5),
            PolicySpec(KIND_AHAP, 3, 1, 0.9),
            PolicySpec(KIND_AHAP, 5, 2, 0.7),
            PolicySpec(KIND_AHANP, 0, 0, 0.7),
            PolicySpec(KIND_MSU),
            PolicySpec(KIND_UP),
        ]
    return [
        replace(spec, rsel=s, rmargin=m)
        for spec in base for s in strategies for m in margins
    ]


def specs_to_arrays(pool: Sequence[PolicySpec]) -> dict:
    """Array encoding for the vmapped simulator. ``cfrac`` is the
    RAND_DEADLINE commitment fraction, precomputed in float64 here (and in
    RandDeadline.__init__) so both simulators floor identical f32 bits —
    either the spec's explicit quantile-family override or the default
    ski-rental-optimal fraction of the spec's quantile. ``rsel``/``rmargin``
    encode the region-selection strategy; single-region entry points ignore
    them."""
    return {
        "kind": np.array([p.kind for p in pool], np.int32),
        "omega": np.array([p.omega for p in pool], np.int32),
        "v": np.array([max(p.v, 1) for p in pool], np.int32),
        "sigma": np.array([p.sigma for p in pool], np.float32),
        "rho": np.array([p.rho for p in pool], np.float32),
        "cfrac": np.array(
            [(p.cfrac if p.cfrac >= 0 else rand_commit_frac(p.sigma))
             if p.kind == KIND_RAND else 0.0
             for p in pool], np.float32,
        ),
        "rsel": np.array([p.rsel for p in pool], np.int32),
        "rmargin": np.array([p.rmargin for p in pool], np.float32),
    }
