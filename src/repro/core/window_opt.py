"""Exact solver for the CHC window problem (Eq. 10).

    max_{n^o, n^s}  Ṽ(Z_t-1 + alpha * units) - sum_tau (n^o p^o + n^s p^s_tau)

Structure: with H linear (beta=0, the paper's evaluation setting), a decision
is just a multiset of (slot, instance) *units*, each contributing alpha
workload at its own price; per-slot supply is min(avail, Nmax) spot units at
p^s plus on-demand units at p^o, capped at Nmax total. The optimal multiset
is a prefix of the price-sorted unit list — BUT Ṽ is piecewise-linear and
NOT concave (slope jumps up where completion crosses gamma*d), so greedy
marginal stopping is wrong. We instead evaluate the objective at *every*
prefix length via cumsum and take the argmax: exact, O(W log W), fully
vectorizable (vmap/scan safe — used inside the policy-pool simulator).

Slots beyond the job deadline get infinite price (the paper only schedules
up to d; the termination configuration handles the rest). An N^min repair
pass rounds up/zeroes out violating slots (exactness for N^min=1; checked
against brute force in tests for N^min>1).

Backends (``backend=`` on :func:`solve_window`):

``"xla"``            default; min-plus DP as tn+1 statically-shifted slices
                     of a padded cost vector (no gathers — much faster on
                     CPU/TPU than the seed formulation, bitwise-identical
                     results).
``"xla-gather"``     the seed formulation (per-step (U+1, tn+1) gather +
                     argmin). Kept as the benchmark baseline.
``"pallas"``         fused Pallas kernel (repro.kernels.window_dp): DP,
                     objective argmax and backtrack in one kernel.
``"pallas-interpret"`` same kernel through the Pallas interpreter (CPU).

:func:`solve_window_batch` solves a whole lane batch in ONE call — a single
(B, w1, tn+1) shifted-slice DP on the XLA backends, a single kernel launch
on the Pallas backends. It is what the pool simulator issues per scan slot.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.job import tilde_value

_BIG = 1.0e9

# Deterministic near-tie resolution. The paper's round constants (p^o = 1,
# value/deadline ratios) make "the marginal unit is exactly free" a
# structural occurrence, and at an exact f32 tie the argmax is at the mercy
# of compilation: XLA may or may not emit FMA for the cost/gain products
# depending on the surrounding program, so two jit programs of this very
# file can disagree by 1 ulp and pick opposite sides of the tie (observed:
# the python-policy jit entry vs the fleet scan). Biasing the gain by
# -TIE_EPS per unit makes every near-tie (true marginal value < TIE_EPS)
# resolve to FEWER units in every compilation — 2^-10 is exact in f32
# (no new rounding), ~2 orders above FMA noise at the objective's scale,
# and ~2 orders below any real marginal value. The reported objective is
# un-biased before returning, so achieved-utility pins are unaffected.
TIE_EPS = np.float32(2.0 ** -10)

BACKENDS = ("xla", "xla-gather", "pallas", "pallas-interpret")


def _unit_cost_table(job, tput, z0, slots_to_deadline, prices, avail, p_o, tn):
    """Shared scaffolding for every backend.

    Returns (slot_cost (w1, tn+1), spot_units (w1,), gain (U+1,)) where
    slot_cost[tau, k] is the cheapest cost of buying k units in slot tau
    (spot-first split; infeasible k priced out with _BIG) and gain[u] is
    Ṽ(z0 + alpha * u).
    """
    w1 = prices.shape[0]
    nmax = job.n_max                       # may be a tracer

    in_horizon = jnp.arange(w1) < slots_to_deadline
    spot_ok = (prices <= p_o) & in_horizon
    spot_units = jnp.where(spot_ok, jnp.minimum(avail, nmax), 0)  # (w1,)

    ks = jnp.arange(tn + 1)[None, :].astype(jnp.float32)  # (1, tn+1)
    n_sp = jnp.minimum(ks, spot_units[:, None].astype(jnp.float32))
    slot_cost = n_sp * prices[:, None] + (ks - n_sp) * p_o
    feasible_k = (ks == 0) | (
        (ks >= job.n_min) & (ks <= nmax) & in_horizon[:, None]
    )
    slot_cost = jnp.where(feasible_k, slot_cost, _BIG)

    u_grid = jnp.arange(w1 * tn + 1)
    zs = jnp.asarray(z0, jnp.float32) + tput.alpha * u_grid.astype(jnp.float32)
    gain = tilde_value(job, tput, zs) - TIE_EPS * u_grid.astype(jnp.float32)
    return slot_cost, spot_units, gain


def _dp_step_shifted(C, row, tn: int, U: int):
    """One min-plus DP step as tn+1 statically-shifted adds (no gather).

    Bitwise-identical to the gather formulation: the candidate values
    C[u-k] + row[k] are the same floats, min/argmin are exact, and the
    running `<` comparison keeps the smallest k on ties exactly like
    jnp.argmin."""
    padded = jnp.concatenate([jnp.full((tn,), _BIG, C.dtype), C])
    best = C + row[0]
    bestk = jnp.zeros(C.shape, jnp.int32)
    for k in range(1, tn + 1):
        cand = jax.lax.slice(padded, (tn - k,), (tn - k + U + 1,)) + row[k]
        take = cand < best
        best = jnp.where(take, cand, best)
        bestk = jnp.where(take, k, bestk)
    return best, bestk


def _dp_step_shifted_batch(C, row, tn: int, U: int):
    """Lane-batched twin of :func:`_dp_step_shifted`: C is (B, U+1), row is
    (B, tn+1). Same candidate floats, same running `<` tie-breaking — bitwise
    identical per lane to the scalar step (pinned in tests)."""
    b = C.shape[0]
    padded = jnp.concatenate(
        [jnp.full((b, tn), _BIG, C.dtype), C], axis=1
    )
    best = C + row[:, 0:1]
    bestk = jnp.zeros(C.shape, jnp.int32)
    for k in range(1, tn + 1):
        cand = jax.lax.slice(
            padded, (0, tn - k), (b, tn - k + U + 1)
        ) + row[:, k : k + 1]
        take = cand < best
        best = jnp.where(take, cand, best)
        bestk = jnp.where(take, k, bestk)
    return best, bestk


def _dp_step_gather(C, row, tn: int, U: int):
    """Seed formulation: per-step (U+1, tn+1) candidate matrix via gather."""
    u_grid = jnp.arange(U + 1)
    uk = u_grid[:, None] - jnp.arange(tn + 1)[None, :]
    prevC = jnp.where(uk >= 0, C[jnp.clip(uk, 0, U)], _BIG)
    cand = prevC + row[None, :]
    choice = jnp.argmin(cand, axis=1)
    return jnp.min(cand, axis=1), choice


def _solve_xla(slot_cost, gain, tn: int, *, gather: bool):
    """DP forward + objective argmax + backtrack in plain XLA ops."""
    w1 = slot_cost.shape[0]
    U = w1 * tn
    step = _dp_step_gather if gather else _dp_step_shifted

    def dp_step(C, row):
        return step(C, row, tn, U)

    C0 = jnp.where(jnp.arange(U + 1) == 0, 0.0, _BIG)
    C, choices = jax.lax.scan(dp_step, C0, slot_cost)  # choices: (w1, U+1)

    obj = gain - C
    obj = jnp.where(C < _BIG / 2, obj, -jnp.inf)
    u_star = jnp.argmax(obj)

    # backtrack: slots in reverse order
    def back_step(u, choice_row):
        k = choice_row[u]
        return u - k, k

    _, k_rev = jax.lax.scan(back_step, u_star, choices, reverse=True)
    return k_rev.astype(jnp.int32), obj[u_star]


def _solve_xla_batch(slot_cost, gain, tn: int):
    """Lane-batched DP forward + objective argmax + backtrack: one call for a
    (B, w1, tn+1) table instead of vmap-per-lane. Slots ride the scan axis,
    lanes the array batch axis."""
    b, w1, _ = slot_cost.shape
    U = w1 * tn

    def dp_step(C, row):
        return _dp_step_shifted_batch(C, row, tn, U)

    C0 = jnp.broadcast_to(
        jnp.where(jnp.arange(U + 1) == 0, 0.0, _BIG), (b, U + 1)
    )
    # scan over slots: xs leading axis must be w1
    C, choices = jax.lax.scan(
        dp_step, C0, jnp.swapaxes(slot_cost, 0, 1)
    )  # choices: (w1, B, U+1)

    obj = gain - C
    obj = jnp.where(C < _BIG / 2, obj, -jnp.inf)
    u_star = jnp.argmax(obj, axis=1)  # (B,) smallest-u on ties, like argmax

    def back_step(u, choice_row):
        k = jnp.take_along_axis(choice_row, u[:, None], axis=1)[:, 0]
        return u - k, k

    _, k_rev = jax.lax.scan(back_step, u_star, choices, reverse=True)
    n_tot = jnp.swapaxes(k_rev, 0, 1).astype(jnp.int32)  # (B, w1)
    return n_tot, jnp.take_along_axis(obj, u_star[:, None], axis=1)[:, 0]


def solve_window_batch(
    job: JobConfig,
    tput: ThroughputConfig,
    z0,                         # (B,) progress per lane
    slots_to_deadline,          # (B,) per-lane window cut-off
    prices,                     # (B, w1) per-lane predicted spot prices
    avail,                      # (B, w1) per-lane predicted availability
    p_o,
    table_n: int,               # static unit-table width (required: job.n_max
                                # may be a tracer in the vmapped simulator)
    backend: str = "xla",
):
    """Batched :func:`solve_window`: ONE DP call for a whole lane batch.

    This is the in-scan entry point of the pool simulator — each scan slot
    issues a single (B, w1, tn+1) solve across all AHAP lanes instead of
    relying on vmap's per-lane grid batching. The Pallas backends hand the
    full batch to one ``window_dp`` kernel launch; the XLA backends run the
    lane-batched shifted-slice DP. Bitwise-equal per lane to
    ``jax.vmap(solve_window)`` (pinned in tests/test_window_dp_kernel.py).

    ``job`` fields (and ``p_o``) may also be (B,) vectors — one job per
    batch row, the fleet engine's shape — in which case the unit table is
    built per row. Every op in ``_unit_cost_table`` is elementwise in the
    job fields, so the shared-job lane path is unchanged bitwise.

    Returns (n_o (B, w1), n_s (B, w1), objective (B,)).
    """
    assert backend in BACKENDS, backend
    prices = jnp.asarray(prices, jnp.float32)
    avail = jnp.asarray(avail, jnp.int32)
    tn = int(table_n)
    assert tn > 0, "solve_window_batch needs a static table_n"

    if jnp.asarray(job.workload).ndim or jnp.asarray(p_o).ndim:
        b = prices.shape[0]
        bc = lambda x: jnp.broadcast_to(jnp.asarray(x), (b,))

        def _row_table(z, std, pr, av, wl, dl, nmin, nmax, val, gam, po):
            row_job = JobConfig(workload=wl, deadline=dl, n_min=nmin,
                                n_max=nmax, value=val, gamma=gam,
                                on_demand_price=po)
            return _unit_cost_table(row_job, tput, z, std, pr, av, po, tn)

        slot_cost, spot_units, gain = jax.vmap(_row_table)(
            jnp.asarray(z0, jnp.float32), jnp.asarray(slots_to_deadline),
            prices, avail, bc(job.workload), bc(job.deadline), bc(job.n_min),
            bc(job.n_max), bc(job.value), bc(job.gamma), bc(p_o),
        )
    else:
        slot_cost, spot_units, gain = jax.vmap(
            lambda z, std, pr, av: _unit_cost_table(
                job, tput, z, std, pr, av, p_o, tn
            )
        )(jnp.asarray(z0, jnp.float32), jnp.asarray(slots_to_deadline),
          prices, avail)

    if backend in ("pallas", "pallas-interpret"):
        from repro.kernels.window_dp import window_dp

        n_tot, obj_star = window_dp(
            slot_cost, gain, interpret=(backend == "pallas-interpret")
        )
    elif backend == "xla":
        n_tot, obj_star = _solve_xla_batch(slot_cost, gain, tn)
    else:  # "xla-gather": keep the seed formulation, vmapped per lane
        n_tot, obj_star = jax.vmap(
            lambda c, g: _solve_xla(c, g, tn, gather=True)
        )(slot_cost, gain)

    n_s = jnp.minimum(n_tot, spot_units).astype(jnp.int32)
    n_o = n_tot - n_s
    obj_star = obj_star + TIE_EPS * jnp.sum(n_tot, axis=1).astype(jnp.float32)
    return n_o, n_s, obj_star


def solve_window(
    job: JobConfig,
    tput: ThroughputConfig,
    z0,
    slots_to_deadline,          # d - t: how many window slots are before d
    prices,                     # (w1,) predicted spot prices  [t..t+w]
    avail,                      # (w1,) predicted spot availability
    p_o: float,
    table_n: int = 0,           # static unit-table width (0 -> job.n_max)
    backend: str = "xla",
):
    """Returns (n_o (w1,), n_s (w1,), predicted_objective scalar).

    jnp-traceable, including *dynamic* job fields (n_max/n_min/L may be
    tracers inside the vmapped simulator) — only w1, table_n and backend
    set shapes / dispatch.
    """
    assert backend in BACKENDS, backend
    prices = jnp.asarray(prices, jnp.float32)
    avail = jnp.asarray(avail, jnp.int32)
    tn = int(table_n) if table_n else int(job.n_max)

    slot_cost, spot_units, gain = _unit_cost_table(
        job, tput, z0, slots_to_deadline, prices, avail, p_o, tn
    )

    if backend in ("pallas", "pallas-interpret"):
        from repro.kernels.window_dp import window_dp

        n_tot_b, obj_b = window_dp(
            slot_cost[None], gain[None], interpret=(backend == "pallas-interpret")
        )
        n_tot, obj_star = n_tot_b[0], obj_b[0]
    else:
        n_tot, obj_star = _solve_xla(
            slot_cost, gain, tn, gather=(backend == "xla-gather")
        )

    n_s = jnp.minimum(n_tot, spot_units).astype(jnp.int32)
    n_o = n_tot - n_s
    obj_star = obj_star + TIE_EPS * jnp.sum(n_tot).astype(jnp.float32)
    return n_o, n_s, obj_star


@functools.lru_cache(maxsize=64)
def _jitted_solver(job: JobConfig, tput: ThroughputConfig, w1: int, p_o: float):
    fn = lambda z0, std, prices, avail: solve_window(
        job, tput, z0, std, prices, avail, p_o
    )
    return jax.jit(fn)


def solve_window_numpy(job, tput, z0, slots_to_deadline, prices, avail, p_o):
    """Eager wrapper (python policies). jitted + cached per (job, tput, w1)."""
    prices = np.asarray(prices, np.float32)
    fn = _jitted_solver(job, tput, len(prices), float(p_o))
    n_o, n_s, obj = fn(
        jnp.float32(z0), jnp.int32(slots_to_deadline),
        prices, np.asarray(avail, np.int32),
    )
    return np.asarray(n_o), np.asarray(n_s), float(obj)


def brute_force_window(job, tput, z0, slots_to_deadline, prices, avail, p_o,
                       beta_exact: bool = True):
    """Exponential-time exact reference (tests only): enumerates per-slot
    totals in {0} u [Nmin, Nmax], spot-first split."""
    prices = np.asarray(prices, float)
    avail = np.asarray(avail, int)
    w1 = len(prices)
    choices = [0] + list(range(job.n_min, job.n_max + 1))
    best = (-np.inf, None)

    def rec(tau, z, cost, plan):
        nonlocal best
        if tau == w1:
            u = float(tilde_value(job, tput, z)) - cost
            if u > best[0]:
                best = (u, list(plan))
            return
        if tau >= slots_to_deadline:
            rec(w1, z, cost, plan + [0] * (w1 - tau))
            return
        for n in choices:
            ns = min(n, avail[tau]) if prices[tau] <= p_o else 0
            no = n - ns
            c = ns * prices[tau] + no * p_o
            h = tput.alpha * n + (tput.beta if n > 0 else 0.0)
            rec(tau + 1, z + h, cost + c, plan + [n])

    rec(0, float(z0), 0.0, [])
    return best
