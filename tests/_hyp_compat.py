"""Fallback shim for the tiny slice of the hypothesis API this suite uses.

The container image does not ship ``hypothesis``; rather than losing the
property tests entirely (they pin the window solver and the kernels), this
module re-exports the real library when present and otherwise substitutes a
deterministic mini-runner: each ``@given`` test is executed ``max_examples``
times with values drawn from a seeded numpy Generator (seed = crc32 of the
test name, so failures reproduce). Only the strategies actually used by the
suite are implemented: integers, floats, sampled_from, just, builds.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw  # draw(rng) -> value

    class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: int(r.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: float(r.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: elements[int(r.integers(0, len(elements)))])

        @staticmethod
        def just(value):
            return _Strategy(lambda r: value)

        @staticmethod
        def builds(target, **kw):
            return _Strategy(
                lambda r: target(**{k: s.draw(r) for k, s in kw.items()})
            )

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # zero-arg wrapper: pytest must not mistake drawn params for
            # fixtures (none of the suite's @given tests use fixtures)
            def wrapper():
                n = getattr(wrapper, "_max_examples", 20)
                seed = zlib.crc32(fn.__name__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    kw = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(**kw)
                    except Exception:
                        print(f"falsifying example ({fn.__name__}): {kw!r}")
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = getattr(fn, "_max_examples", 20)
            return wrapper

        return deco
