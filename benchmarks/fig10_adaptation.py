"""Fig. 10: policy-weight dynamics under changing prediction quality.

Four phases (paper): Fixed-Mag+Uniform 10% -> Fixed-Mag+Heavy-Tail 30% ->
Fixed-Mag+Uniform 50% -> 200% noise. One ``engine.simulate_and_select``
call per phase, the EG state threading through the phases (the engine's
streaming contract); ``track_history`` captures the per-job weight
trajectory on device and the heatmap data is saved to
experiments/fig10_weights.npz."""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import PAPER_TPUT, timed
from benchmarks.fig9_convergence import _engine_inputs
from repro.core import engine, selector
from repro.core.policy_pool import paper_pool, specs_to_arrays

PHASES = [
    ("fixed_uniform", 0.1, 500),
    ("fixed_heavytail", 0.3, 500),
    ("fixed_uniform", 0.5, 500),
    ("fixed_uniform", 2.0, 600),
]


def run() -> list:
    pool = paper_pool()
    arrs = specs_to_arrays(pool)
    M = len(pool)
    K = sum(p[2] for p in PHASES)
    st = selector.eg_init(M, K)
    hist_parts = [np.full((1, M), 1.0 / M, np.float32)]  # initial weights
    phase_winners = []
    t0 = 0.0
    for i, (kind, level, n) in enumerate(PHASES):
        inputs, us_prep = timed(_engine_inputs, kind, level, n, 31 + i)
        jobs, prices, avail, preds = inputs
        res, us = timed(
            engine.simulate_and_select, arrs, jobs, PAPER_TPUT,
            prices, avail, preds, state=st, track_history=True,
        )
        t0 += us_prep + us
        st = res.state
        hist_parts.append(res.weight_history)
        phase_winners.append(selector.best_policy(st))

    os.makedirs("experiments", exist_ok=True)
    hist = np.concatenate(hist_parts)  # (K+1, M)
    np.savez_compressed(
        "experiments/fig10_weights.npz",
        weights=hist.astype(np.float32),
        phase_bounds=np.cumsum([p[2] for p in PHASES]),
        winners=np.array(phase_winners),
        pool_names=np.array([p.name for p in pool]),
    )
    rows = [("fig10_total_jobs", t0, K)]
    for i, w in enumerate(phase_winners):
        rows.append((f"fig10_phase{i}_winner_idx", 0.0, w))
        rows.append((f"fig10_phase{i}_winner_is_ahanp", 0.0, float(pool[w].kind == 1)))
    rows.append(("fig10_distinct_phase_winners", 0.0, float(len(set(phase_winners)))))
    # heavy noise should push weight toward non-predictive AHANP policies
    ahanp_mass_end = float(
        hist[-1, [i for i, p in enumerate(pool) if p.kind == 1]].sum()
    )
    rows.append(("fig10_final_ahanp_weight_mass", 0.0, ahanp_mass_end))
    return rows
