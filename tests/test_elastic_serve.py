"""Integration: elastic trainer (scheduler -> training) and serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_smoke_config
from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.market import from_arrays, vast_like_trace
from repro.core.policies import AHAP, AHAPParams, UP
from repro.core.predictor import PerfectPredictor
from repro.models import init_model
from repro.serve import Request, ServingEngine
from repro.train.elastic import ElasticTrainer


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_smoke_config("olmo-1b")
    tcfg = TrainConfig(seq_len=32, global_batch=2, total_steps=64, lr=2e-3)
    return cfg, tcfg


def test_elastic_trainer_end_to_end(tiny_setup, tmp_path):
    cfg, tcfg = tiny_setup
    job = JobConfig(workload=8, deadline=4, n_min=1, n_max=4, value=20.0)
    tput = ThroughputConfig(mu1=0.9, mu2=0.95)
    tr = vast_like_trace(seed=5, days=1)
    pred = PerfectPredictor(tr).matrix(5)
    t = ElasticTrainer(cfg, tcfg, job, tput, AHAP(AHAPParams(2, 1, 0.7)), tr,
                       pred, steps_per_unit=1.0, ckpt_dir=str(tmp_path))
    rep = t.run()
    assert rep.total_steps > 0
    assert np.isfinite(rep.utility)
    assert rep.z_final <= job.workload + 1e-6
    assert all(np.isfinite(l) for l in rep.losses)
    # reconfiguration events produced real checkpoints
    changes = [s for s in rep.slots if s.ckpt_bytes > 0]
    assert len(changes) >= 1
    assert all(s.reconfig_s > 0 for s in changes)


def test_elastic_global_batch_fixed_under_policy_change(tiny_setup, tmp_path):
    """Different policies -> identical update math for the same step index
    (paper III-B: convergence unaffected by scheduler decisions)."""
    cfg, tcfg = tiny_setup
    job = JobConfig(workload=6, deadline=3, n_min=1, n_max=4, value=20.0)
    tput = ThroughputConfig()
    tr = from_arrays([0.4, 0.4, 0.4], [4, 0, 2])
    pred = PerfectPredictor(tr).matrix(5)
    reps = []
    for pol in [AHAP(AHAPParams(2, 1, 0.7)), UP()]:
        t = ElasticTrainer(cfg, tcfg, job, tput, pol, tr,
                           pred if pol.name == "ahap" else None,
                           steps_per_unit=0.5, ckpt_dir=str(tmp_path))
        reps.append(t.run())
    n = min(len(reps[0].losses), len(reps[1].losses))
    assert n >= 2
    np.testing.assert_allclose(reps[0].losses[:n], reps[1].losses[:n], rtol=1e-5)


def test_serving_engine_greedy(rng):
    cfg = get_smoke_config("granite-20b")
    params, _ = init_model(rng, cfg)
    eng = ServingEngine(cfg, params, max_len=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (3, 8))
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    outs = eng.generate_batch(reqs)
    assert len(outs) == 3
    assert all(len(o) == 6 for o in outs)
    # greedy decode is deterministic
    outs2 = eng.generate_batch(reqs)
    for a, b in zip(outs, outs2):
        np.testing.assert_array_equal(a, b)


def test_serving_engine_matches_forward_argmax(rng):
    from repro.models import forward

    cfg = get_smoke_config("olmo-1b")
    params, _ = init_model(rng, cfg)
    eng = ServingEngine(cfg, params, max_len=32)
    prompt = np.random.default_rng(1).integers(0, cfg.vocab_size, (1, 10))
    out = eng.generate_batch([Request(prompt=prompt[0], max_new_tokens=1)])[0]
    logits, _ = forward(cfg, params, {"tokens": jnp.asarray(prompt)})
    assert int(out[0]) == int(jnp.argmax(logits[0, -1]))
