"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run forces 512 host devices *before* first jax init).

Production topology (TPU v5e):
  single-pod:  (data=16, model=16)            = 256 chips
  multi-pod:   (pod=2, data=16, model=16)     = 512 chips
One scheduler "instance" (paper's n_t) maps to one data-axis shard; the
16-way model axis is the intra-instance tensor parallelism held fixed.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh():
    """1-device mesh with the production axis names (tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_pool_mesh(devices=None, shape=None):
    """Mesh for the policy-pool simulator.

    Default (``shape=None``): 1-D over every visible device, jobs ride the
    single ``"jobs"`` axis and lanes stay whole per device — the
    kind-partitioned lane split already balances DP-heavy vs cheap work
    within each device.

    ``shape=(n_jobs_dev, n_lane_dev)`` builds the 2-D ``("jobs", "lanes")``
    mesh instead: jobs shard the first axis, AHAP/cheap lanes the second
    (``fast_sim.simulate_pool_jobs_sharded`` pads both axes to divisibility).
    ``shape=(n,)`` is the explicit 1-D form. The shape must multiply out to
    the device count. Works unchanged on 1 CPU device (tests), a
    forced-multi-device host, and a TPU slice."""
    from jax.sharding import Mesh
    import numpy as np

    devices = jax.devices() if devices is None else list(devices)
    if shape is None:
        shape = (len(devices),)
    shape = tuple(int(s) for s in shape)
    if len(shape) not in (1, 2) or any(s < 1 for s in shape):
        raise ValueError(f"pool mesh shape must be (jobs,) or (jobs, lanes): {shape}")
    if int(np.prod(shape)) != len(devices):
        raise ValueError(
            f"pool mesh shape {shape} does not cover {len(devices)} devices"
        )
    axes = ("jobs", "lanes")[: len(shape)]
    return Mesh(np.asarray(devices).reshape(shape), axes)


def pool_mesh_job_axes(mesh):
    """How a pool mesh splits the simulation grid.

    Returns ``(jobs_axes, n_jobs_dev, n_lane_dev)``: the mesh axis names
    that shard the job dimension, the total device count along them, and
    the lane-axis device count (1 on a 1-D mesh). Shared by the pool
    simulator (jobs x lanes grids) and the fleet engine (jobs only,
    replicated over ``"lanes"``)."""
    import numpy as np

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_lane_dev = int(sizes.get("lanes", 1))
    jobs_axes = tuple(a for a in mesh.axis_names if a != "lanes")
    n_jobs_dev = int(np.prod([sizes[a] for a in jobs_axes])) if jobs_axes else 1
    return jobs_axes, n_jobs_dev, n_lane_dev


def parse_pool_mesh_shape(spec: str):
    """``"4"`` -> (4,), ``"2x2"`` -> (2, 2) — the POOL_SIM_MESH knob format.
    Empty/``"auto"`` -> None (make_pool_mesh's 1-D default)."""
    spec = (spec or "").strip().lower()
    if spec in ("", "auto"):
        return None
    return tuple(int(s) for s in spec.split("x"))
