from repro.checkpoint.ckpt import (
    checkpoint_bytes,
    reconfiguration_mu,
    restore,
    save,
    serialize,
    deserialize,
    transfer_seconds,
)
