"""Multi-region market layer: RegionalMarket, phase-shifted generation,
regional predictors, and the Trace.window bounds contract."""
import numpy as np
import pytest

from repro.core.market import Trace, TraceStats, constant_trace, vast_like_trace
from repro.core.predictor import NoisyPredictor, PerfectPredictor, RegionalPredictor
from repro.core.region_market import RegionalMarket, vast_like_regions


# ---------------------------------------------------------------------------
# Trace.window bounds (was: silently returned a short window)
# ---------------------------------------------------------------------------

def test_trace_window_in_bounds_ok():
    tr = constant_trace(0.5, 4, 20)
    w = tr.window(5, 10)
    assert len(w) == 10 and w.meta["t0"] == 5


@pytest.mark.parametrize("t0,length", [(15, 10), (0, 21), (-1, 5), (3, -1)])
def test_trace_window_out_of_bounds_raises(t0, length):
    tr = constant_trace(0.5, 4, 20)
    with pytest.raises(ValueError):
        tr.window(t0, length)


def test_regional_window_out_of_bounds_raises():
    m = vast_like_regions(2, seed=0, days=1)
    assert len(m) == 48
    with pytest.raises(ValueError):
        m.window(40, 10)
    w = m.window(10, 20)
    assert len(w) == 20 and w.n_regions == 2
    assert w.delta_mig == m.delta_mig


# ---------------------------------------------------------------------------
# Phase-shifted generation
# ---------------------------------------------------------------------------

def test_zero_phase_is_bitwise_default():
    a = vast_like_trace(seed=3, days=2)
    b = vast_like_trace(seed=3, days=2, season_phase_slots=0.0)
    np.testing.assert_array_equal(a.prices, b.prices)
    np.testing.assert_array_equal(a.avail, b.avail)


def _tod_profile(trace):
    """Per-slot-of-day mean availability."""
    spd = trace.slots_per_day
    t = np.arange(len(trace)) % spd
    return np.array([trace.avail[t == k].mean() for k in range(spd)])


def test_vast_like_regions_phase_shifts_the_diurnal_peak():
    m = vast_like_regions(
        3, seed=2, days=10, phase_hours=(0.0, 8.0, 16.0),
        avail_season_amp=4.0, avail_sigma=0.5,
    )
    spd = m.slots_per_day
    base = _tod_profile(m.region(0))
    for r, phase_h in ((1, 8.0), (2, 16.0)):
        prof = _tod_profile(m.region(r))
        shift_slots = int(phase_h * spd / 24)
        # circular cross-correlation peaks at the region's phase shift
        lags = [
            np.dot(base - base.mean(), np.roll(prof - prof.mean(), -lag))
            for lag in range(spd)
        ]
        best_lag = int(np.argmax(lags))
        err = min(abs(best_lag - shift_slots), spd - abs(best_lag - shift_slots))
        assert err <= 2, (r, best_lag, shift_slots)


def test_phase_shift_flips_day_night_ratio():
    """TraceStats day/night ratio: > 1 for the reference region (paper
    Fig. 2: more availability by day), < 1 for a 12h-shifted region."""
    m = vast_like_regions(
        2, seed=5, days=10, phase_hours=(0.0, 12.0),
        avail_season_amp=4.0, avail_sigma=0.5,
    )
    s0, s1 = m.stats()
    assert s0.avail_day_night_ratio > 1.2, s0
    assert s1.avail_day_night_ratio < 0.85, s1


def test_per_region_price_levels():
    m = vast_like_regions(
        3, seed=1, days=10, mean_prices=(0.3, 0.45, 0.6), price_sigma=0.2,
    )
    med = [np.median(m.prices[r]) for r in range(3)]
    assert med[0] < med[1] < med[2], med
    # each region individually still passes the Fig. 2 shape check
    for r in range(3):
        st = TraceStats.of(m.region(r))
        assert 0.4 < st.median_over_p90 < 0.9, (r, st)


def test_from_traces_rejects_misaligned_traces():
    t0 = vast_like_trace(seed=0, days=1)
    short = vast_like_trace(seed=1, days=0.5)
    with pytest.raises(ValueError):
        RegionalMarket.from_traces([t0, short])
    hourly = vast_like_trace(seed=1, days=1, slots_per_day=24)
    with pytest.raises(ValueError):
        RegionalMarket.from_traces([t0, hourly])


def test_from_traces_roundtrip_and_views():
    t0 = vast_like_trace(seed=0, days=1)
    t1 = vast_like_trace(seed=1, days=1)
    m = RegionalMarket.from_traces([t0, t1], delta_mig=2,
                                   region_names=("us", "eu"))
    assert m.n_regions == 2 and m.delta_mig == 2
    assert m.region_names == ("us", "eu")
    np.testing.assert_array_equal(m.region(1).prices, t1.prices)
    np.testing.assert_array_equal(m.region(0).avail, t0.avail)
    assert isinstance(m.region(0), Trace)


# ---------------------------------------------------------------------------
# Regional predictors: (R, T, h+1, 2)
# ---------------------------------------------------------------------------

def test_regional_predictor_shapes_and_present_column():
    m = vast_like_regions(3, seed=4, days=1)
    h = 5
    pm = RegionalPredictor(m).matrix(h)  # default: PerfectPredictor
    assert pm.shape == (3, len(m), h + 1, 2)
    for r in range(3):
        np.testing.assert_array_equal(pm[r, :, 0, 0], m.prices[r])
        np.testing.assert_array_equal(pm[r, :, 0, 1], m.avail[r])
        # perfect predictor: j-step forecast equals the shifted truth
        np.testing.assert_array_equal(pm[r, :-h, h, 0], m.prices[r, h:])


def test_regional_predictor_factory_decorrelates_regions():
    m = vast_like_regions(2, seed=4, days=1)
    pm = RegionalPredictor(
        m, lambda tr, r: NoisyPredictor(tr, "fixed_uniform", 0.3, seed=r)
    ).matrix(5)
    assert pm.shape == (2, len(m), 6, 2)
    # the present column is observed, never noised
    np.testing.assert_array_equal(pm[0, :, 0, 0], m.prices[0])
    # per-region noise streams differ (beyond the underlying trace diff)
    err0 = pm[0, :-1, 1, 0] - m.prices[0, 1:]
    err1 = pm[1, :-1, 1, 0] - m.prices[1, 1:]
    assert not np.allclose(err0, err1)
