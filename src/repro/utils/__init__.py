from repro.utils.tree import count_params, tree_bytes, tree_map_with_path_names
