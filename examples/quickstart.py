"""Quickstart: schedule one fine-tuning job on a synthetic spot market.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's pipeline end-to-end in a few seconds: build a market,
forecast it with ARIMA, run AHAP / AHANP / the three baselines, and compare
against the offline optimum.
"""
import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.market import TraceStats, vast_like_trace
from repro.core.offline_opt import solve_offline
from repro.core.policies import AHANP, AHANPParams, AHAP, AHAPParams, MSU, ODOnly, UP
from repro.core.predictor import ARIMAPredictor
from repro.core.simulator import simulate

# --- the paper's evaluation job (Sec. VI-A): LLaMA2-7B LoRA, 80 units / 10 slots
job = JobConfig(workload=80, deadline=10, n_min=1, n_max=12, value=120.0)
tput = ThroughputConfig(alpha=1.0, beta=0.0, mu1=0.9, mu2=0.95)

# --- a Vast.ai-like A100 spot market (30-min slots)
market = vast_like_trace(seed=7, days=12, mean_price=0.7, price_sigma=0.5,
                         avail_mean=5.5, avail_season_amp=3.0)
print("market:", TraceStats.of(market))

# --- forecast it (seasonal-AR 'ARIMA', fit on the first 10 days)
t0 = 10 * 48  # schedule the job on day 11
window = market.window(t0, job.deadline + 1)
hist = market.window(0, t0 + job.deadline + 1)
pred_full = ARIMAPredictor(hist).matrix(5)
pred = pred_full[t0 : t0 + job.deadline]

# --- run the policies
print(f"\n{'policy':10s} {'utility':>8s} {'cost':>7s} {'T':>6s} {'done':>5s}  allocation")
for pol in [AHAP(AHAPParams(omega=3, v=1, sigma=0.7)),
            AHANP(AHANPParams(sigma=0.7)), ODOnly(), MSU(), UP()]:
    r = simulate(pol, job, tput, window, pred if pol.name == "ahap" else None)
    print(f"{pol.name:10s} {r.utility:8.2f} {r.cost:7.2f} {r.completion_time:6.2f} "
          f"{str(r.completed_by_deadline):>5s}  {list(r.n_total)}")

opt = solve_offline(job, tput, window)
print(f"{'OPT':10s} {opt.utility:8.2f} {opt.cost:7.2f}              {list(opt.plan_total)}")
