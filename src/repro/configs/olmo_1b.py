"""OLMo-1B [arXiv:2402.00838] — dense, non-parametric LayerNorm, no biases, tied embeddings."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        arch_type="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=50304,
        rope_theta=10000.0,
        norm_type="layernorm_np",  # non-parametric LN (no scale/bias)
        mlp_act="silu",
        tie_embeddings=True,
        source="arXiv:2402.00838",
    )


def smoke_config() -> ModelConfig:
    return config().reduced()
