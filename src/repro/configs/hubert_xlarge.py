"""HuBERT-XLarge [arXiv:2106.07447] — encoder-only audio transformer (w2v2 arch).

Audio: the mel-spectrogram + conv feature extractor frontend is a STUB per
spec — ``input_specs()`` supplies precomputed frame embeddings (B, S, d_model).
Training objective is masked prediction over 504 codebook classes.
Encoder-only: no decode step (decode shapes are skipped; see DESIGN.md).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        arch_type="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        vocab_size=504,      # codebook targets
        causal=False,
        encoder_only=True,
        embed_inputs=True,   # conv/mel frontend stubbed -> frame embeddings in
        norm_type="layernorm",
        mlp_act="gelu",
        mlp_bias=True,
        qkv_bias=True,
        o_bias=True,
        rope_theta=0.0,      # no RoPE; w2v2 uses conv positional (in stub frontend)
        source="arXiv:2106.07447",
    )


def smoke_config() -> ModelConfig:
    return config().reduced()
