from repro.checkpoint.ckpt import (
    CheckpointCorruptError,
    checkpoint_bytes,
    reconfiguration_mu,
    restore,
    save,
    serialize,
    deserialize,
    transfer_seconds,
)
