"""Flash attention (forward) Pallas TPU kernel — causal and sliding-window.

Online-softmax blockwise attention re-tiled for VMEM: a (bq x d) query tile
stays resident while (bk x d) KV tiles stream through; running max/sum and
the f32 output accumulator live in VMEM scratch across the sequential kv
grid dimension. Masking uses block-level position iotas, so a fully-masked
block costs one select, never an exp. This is the TPU adaptation of the
paper's serving substrate hot spot (prefill_32k / long_500k shapes); the
XLA twin lives in repro.models.attention._blockwise_attn.

Layout: (BH, S, D) with heads folded into batch (GQA head repetition is the
wrapper's job — see ops.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            sm_scale: float, causal: bool, window: Optional[int],
            bq: int, bk: int, kv_steps: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * sm_scale        # (bq, d)
    k = k_ref[0].astype(jnp.float32)                   # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == kv_steps - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (BH, Sq, D)
    k: jnp.ndarray,  # (BH, Sk, D)
    v: jnp.ndarray,  # (BH, Sk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq, bk = min(block_q, sq), min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0
    kv_steps = sk // bk

    return pl.pallas_call(
        functools.partial(
            _kernel, sm_scale=1.0 / math.sqrt(d), causal=causal,
            window=window, bq=bq, bk=bk, kv_steps=kv_steps,
        ),
        grid=(bh, sq // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running sum
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
