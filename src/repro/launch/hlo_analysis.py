"""Loop-aware HLO accounting for the roofline analysis.

``compiled.cost_analysis()`` on the CPU backend counts each ``while`` body
once, but our models scan over layers (and blockwise attention scans over KV
blocks), so naive numbers undercount by the trip counts. This module parses
the optimized HLO text into computations, extracts while-loop trip counts
from their condition computations, and walks the call graph accumulating:

  * dot FLOPs            (2 * result_elems * contracted_size, x multiplier)
  * kernel HBM traffic   (operand+result bytes of top-level ops, x multiplier)
  * collective bytes     (result-shape bytes per collective kind, x multiplier)

Fusion-internal ops contribute FLOPs (dots inside fusions) but not traffic
(fusion = one kernel: only its operands/results touch HBM).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# ops that move no real data / are free (while/conditional: their bodies'
# ops are counted; the op itself is control flow, not a kernel)
_SKIP_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "conditional",
}

_SLICING_OPS = {"dynamic-slice", "gather", "slice"}


def type_bytes(type_str: str, f32_as: int = 4) -> int:
    """Bytes of an HLO type. ``f32_as=2`` gives the bf16-equivalent count:
    the XLA *CPU* backend float-normalizes bf16 ops to f32 (converts inserted
    around dots/collectives), so raw byte counts are ~2x what the TPU target
    would move. The roofline reports both (EXPERIMENTS.md §Roofline)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        sz = f32_as if dt == "f32" else _DTYPE_BYTES.get(dt, 4)
        total += n * sz
    return total


def type_shape(type_str: str) -> Optional[Tuple[int, ...]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    args: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    entry: bool = False
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*\([^)]*\)?.*->.*\{\s*$")
_HEADER_RE2 = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _split_type_op(rest: str) -> Optional[Tuple[str, str, str, str]]:
    """'f32[2,3]{1,0} dot(%a, %b), attrs' -> (type, opcode, args, attrs)."""
    rest = rest.strip()
    if rest.startswith("("):  # tuple type: balanced parens
        depth, i = 0, 0
        while i < len(rest):
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        type_str, tail = rest[: i + 1], rest[i + 1 :].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, tail = rest[:sp], rest[sp + 1 :].strip()
    p = tail.find("(")
    if p < 0:
        return None
    opcode = tail[:p].strip()
    depth, i = 0, p
    while i < len(tail):
        if tail[i] == "(":
            depth += 1
        elif tail[i] == ")":
            depth -= 1
            if depth == 0:
                break
        i += 1
    args, attrs = tail[p + 1 : i], tail[i + 1 :]
    return type_str, opcode, args, attrs


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{") and "->" in line and not line.lstrip().startswith("//"):
                m = _HEADER_RE2.match(line.strip())
                if m:
                    cur = Computation(name=m.group(2), entry=bool(m.group(1)))
                    comps[cur.name] = cur
                    if cur.entry:
                        entry_name = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        parsed = _split_type_op(rest)
        if parsed is None:
            continue
        type_str, opcode, args, attrs = parsed
        arg_names = re.findall(r"%([\w.\-]+)", args)
        cur.types[name] = type_str
        cur.instrs.append(Instr(name, type_str, opcode, arg_names, attrs))
    return comps, entry_name


_COND_CONST_RE = re.compile(r"s32\[\]\{?\}?\s+constant\((\d+)\)")


def extract_trip_counts(text: str, comps: Dict[str, Computation]) -> Dict[str, int]:
    """cond-computation-name -> trip count, parsed from raw text blocks."""
    trips: Dict[str, int] = {}
    cur = None
    block: List[str] = []
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{") and "->" in line:
                m = _HEADER_RE2.match(line.strip())
                if m and m.group(2) in comps:
                    cur = m.group(2)
                    block = []
            continue
        if line.startswith("}"):
            vals = [int(v) for v in _COND_CONST_RE.findall("\n".join(block))]
            if vals:
                trips[cur] = max(vals)
            cur = None
            continue
        block.append(line)
    return trips


def _attr_comp(attrs: str, key: str) -> List[str]:
    m = re.search(key + r"=\{([^}]*)\}", attrs)
    if m:
        return re.findall(r"%?([\w.\-]+)", m.group(1))
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return [m.group(1)] if m else []


def analyze(text: str, top_k: int = 0) -> dict:
    """Set top_k > 0 to also return the top traffic-contributing
    instructions (hypothesis formation for §Perf)."""
    comps, entry = parse_module(text)
    trips = extract_trip_counts(text, comps)
    contrib: Dict[tuple, float] = {}
    if entry is None:
        # entry computation conventionally named 'main' or marked ENTRY
        entry = "main" if "main" in comps else next(iter(comps))

    acc = {
        "dot_flops": 0.0,
        "traffic_bytes": 0.0,
        "traffic_bytes_bf16eq": 0.0,
        "collectives": {
            k: {"bytes": 0.0, "bytes_bf16eq": 0.0, "count": 0.0}
            for k in COLLECTIVE_KINDS
        },
        "while_trips": [],
        "unknown_trip_whiles": 0,
    }

    def fusion_traffic(fused: Computation, f32_as: int) -> float:
        """HBM bytes of one fusion kernel: parameter reads (sliced params
        count at their slice size — the dominant over-count otherwise is a
        loop-invariant stacked weight array read in full every scan step)
        plus the write (in-place dynamic-update-slice roots count at the
        update size, not the full aliased buffer)."""
        instr_of = {i.name: i for i in fused.instrs}

        _TRANSPARENT = ("bitcast", "reshape", "copy", "transpose", "convert")
        # 'convert' is transparent for ALIASING purposes: the CPU backend's
        # float normalization wraps in-place DUS updates in full-buffer
        # convert chains (convert(dus(convert(x), convert(u)))) that the TPU
        # simplifier folds away — counting them would charge a full
        # checkpoint-buffer rewrite per scan step (found on qwen1.5-110b).

        def resolve(name: str) -> str:
            """Follow alias-transparent chains to the underlying value."""
            seen = 0
            while name in instr_of and instr_of[name].opcode in _TRANSPARENT \
                    and instr_of[name].args and seen < 16:
                name = instr_of[name].args[0]
                seen += 1
            return name

        # how much of each parameter is actually read
        param_read: Dict[str, float] = {}
        param_sliced: Dict[str, bool] = {}
        param_aliased: set = set()
        dus_updates: Dict[str, str] = {}  # dus instr name -> update operand
        for ins in fused.instrs:
            if ins.opcode == "parameter":
                param_read.setdefault(ins.name, 0.0)
                param_sliced.setdefault(ins.name, True)
            if ins.opcode == "dynamic-update-slice" and len(ins.args) >= 2:
                dus_updates[ins.name] = ins.args[1]
                tgt = resolve(ins.args[0])
                if tgt in param_read:
                    param_aliased.add(tgt)  # in-place buffer: not re-read
        for ins in fused.instrs:
            if ins.opcode == "parameter":
                continue
            for pos, a in enumerate(ins.args):
                ar = resolve(a)
                if ar not in param_read:
                    continue
                if ins.opcode in _SLICING_OPS and pos == 0:
                    param_read[ar] += type_bytes(ins.type_str, f32_as)
                elif ins.opcode == "dynamic-update-slice" and pos == 0:
                    pass  # aliased above
                elif ins.opcode in _TRANSPARENT:
                    pass  # transparent; real consumer accounted separately
                else:
                    param_sliced[ar] = False
        reads = 0.0
        for ins in fused.instrs:
            if ins.opcode != "parameter":
                continue
            if ins.name in param_aliased and param_read[ins.name] == 0:
                continue
            full = type_bytes(ins.type_str, f32_as)
            if param_sliced.get(ins.name) and param_read[ins.name] > 0:
                reads += min(full, param_read[ins.name])
            else:
                reads += full
        # write size: root DUS (or tuple of DUS) writes only its updates
        root = fused.instrs[-1] if fused.instrs else None
        write = 0.0
        if root is not None:
            def _write_of(name: str) -> float:
                name = resolve(name)
                if name in dus_updates:
                    upd = dus_updates[name]
                    return type_bytes(fused.types.get(upd, ""), f32_as)
                return type_bytes(fused.types.get(name, ""), f32_as)

            if root.opcode == "tuple":
                write = sum(_write_of(a) for a in root.args)
            else:
                write = _write_of(root.name)
        return reads + write

    def dot_flops(comp: Computation, ins: Instr) -> float:
        out_shape = type_shape(ins.type_str) or ()
        out_elems = 1
        for d in out_shape:
            out_elems *= d
        lhs = ins.args[0] if ins.args else None
        lhs_shape = type_shape(comp.types.get(lhs, "")) if lhs else None
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
        contracted = 1
        if lhs_shape and m and m.group(1):
            for d in m.group(1).split(","):
                contracted *= lhs_shape[int(d)]
        return 2.0 * out_elems * contracted

    seen_stack = set()

    def walk(comp_name: str, mult: float, in_fusion: bool):
        if comp_name not in comps or mult == 0:
            return
        key = (comp_name, in_fusion)
        comp = comps[comp_name]
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                acc["dot_flops"] += mult * dot_flops(comp, ins)
            for kind in COLLECTIVE_KINDS:
                if op == kind or op == kind + "-start":
                    acc["collectives"][kind]["bytes"] += mult * type_bytes(ins.type_str)
                    acc["collectives"][kind]["bytes_bf16eq"] += mult * type_bytes(
                        ins.type_str, f32_as=2
                    )
                    acc["collectives"][kind]["count"] += mult
            if not in_fusion and op not in _SKIP_TRAFFIC and not op.endswith("-done"):
                if op == "fusion":
                    called = _attr_comp(ins.attrs, "calls")
                    fc = comps.get(called[0]) if called else None
                    if fc is not None:
                        b, b2 = fusion_traffic(fc, 4), fusion_traffic(fc, 2)
                    else:
                        b = type_bytes(ins.type_str)
                        b2 = type_bytes(ins.type_str, 2)
                elif op in _SLICING_OPS:
                    # reads only the sliced region (~= result), writes result
                    b = 2 * type_bytes(ins.type_str)
                    b2 = 2 * type_bytes(ins.type_str, 2)
                elif op == "dynamic-update-slice":
                    upd = comp.types.get(ins.args[1], "") if len(ins.args) > 1 else ""
                    b = 2 * type_bytes(upd)
                    b2 = 2 * type_bytes(upd, 2)
                else:
                    b = type_bytes(ins.type_str)
                    b2 = type_bytes(ins.type_str, f32_as=2)
                    for a in ins.args:
                        b += type_bytes(comp.types.get(a, ""))
                        b2 += type_bytes(comp.types.get(a, ""), f32_as=2)
                acc["traffic_bytes"] += mult * b
                acc["traffic_bytes_bf16eq"] += mult * b2
                if top_k:
                    key = (comp_name, ins.name, op, ins.type_str[:48])
                    contrib[key] = contrib.get(key, 0.0) + mult * b2
            # descend
            if op == "while":
                bodies = _attr_comp(ins.attrs, "body")
                conds = _attr_comp(ins.attrs, "condition")
                trip = trips.get(conds[0], -1) if conds else -1
                if trip < 0:
                    trip = 1
                    acc["unknown_trip_whiles"] += 1
                else:
                    acc["while_trips"].append(trip)
                for b_ in bodies:
                    walk(b_, mult * trip, in_fusion)
                for c_ in conds:
                    walk(c_, mult * trip, True)  # cond is tiny; no traffic
            elif op == "fusion":
                for c_ in _attr_comp(ins.attrs, "calls"):
                    walk(c_, mult, True)
            elif op in ("call", "async-start"):
                for c_ in _attr_comp(ins.attrs, "to_apply") + _attr_comp(ins.attrs, "calls"):
                    walk(c_, mult, in_fusion)
            elif op == "conditional":
                branches = _attr_comp(ins.attrs, "branch_computations")
                branches += _attr_comp(ins.attrs, "true_computation")
                branches += _attr_comp(ins.attrs, "false_computation")
                for b_ in branches:
                    walk(b_, mult, in_fusion)

    walk(entry, 1.0, False)
    acc["collective_bytes_total"] = sum(
        v["bytes"] for v in acc["collectives"].values()
    )
    acc["collective_bytes_bf16eq"] = sum(
        v["bytes_bf16eq"] for v in acc["collectives"].values()
    )
    if top_k:
        acc["top_traffic"] = sorted(
            ((v, k) for k, v in contrib.items()), reverse=True
        )[:top_k]
    return acc
