"""Pool-simulator throughput: the repo's perf trajectory for the hottest path.

Measures slots * policies * jobs / sec over the paper's mixed workload
(112-policy pool + 3 baselines, Fig. 9 job distribution) for three paths:

  seed         the monolithic simulator (every lane evaluates every decision
               rule each slot, window DP included, gather-formulated DP) —
               the state of the repo before the kind-partitioned refactor.
  partitioned  fast_sim.simulate_pool: AHAP lanes on the DP-bearing scan
               (shifted-slice XLA DP), AHANP/OD/MSU/UP lanes on the cheap
               scan, scattered back to pool order.
  pallas       the partitioned path with the fused Pallas window-DP kernel
               (interpret mode on CPU, compiled on TPU).

Writes BENCH_pool_sim.json (machine-readable rows + speedups) so successive
PRs can track the trajectory; also returned as benchmark rows for run.py.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PAPER_JOB, PAPER_TPUT, Row, job_stream, paper_market

N_JOBS = 8
DEADLINE = 10
REPEAT = 5

_JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                          "BENCH_pool_sim.json")


def _workload(n_jobs: int):
    """Fig. 9-style workload: random jobs on random market windows."""
    from repro.core import fast_sim
    from repro.core.predictor import NoisyPredictor

    rng = np.random.default_rng(7)
    jobs = list(job_stream(rng, n_jobs, deadline=DEADLINE))
    market = paper_market(seed=13, days=4)
    traces = [
        market.window(int(rng.integers(0, len(market) - DEADLINE - 1)), DEADLINE + 1)
        for _ in range(n_jobs)
    ]
    prices = np.stack([t.prices[:DEADLINE] for t in traces]).astype(np.float32)
    avail = np.stack([t.avail[:DEADLINE] for t in traces]).astype(np.int64)
    preds = np.stack([
        NoisyPredictor(t, "fixed_uniform", 0.2, seed=i).matrix(
            fast_sim.W1MAX - 1
        )[:DEADLINE]
        for i, t in enumerate(traces)
    ]).astype(np.float32)
    return jobs, prices, avail, preds


def _bench(fn, repeat: int = REPEAT) -> float:
    """Seconds per call at steady state (first call pays compilation)."""
    jax.block_until_ready(fn()["utility"])
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(fn()["utility"])
    return (time.perf_counter() - t0) / repeat


def run():
    from repro.core import fast_sim
    from repro.core.policy_pool import baseline_specs, paper_pool, specs_to_arrays

    pool = paper_pool() + baseline_specs()   # 112 + 3: mixed AHAP/AHANP/baseline
    arrs = specs_to_arrays(pool)
    jobs, prices, avail, preds = _workload(N_JOBS)
    stacked = fast_sim.stack_jobs(jobs)
    n_pol = len(pool)
    work_units = DEADLINE * n_pol * N_JOBS   # slots * policies * jobs per call

    on_tpu = jax.default_backend() == "tpu"
    pallas_backend = "pallas" if on_tpu else "pallas-interpret"

    kind, omega = jnp.asarray(arrs["kind"]), jnp.asarray(arrs["omega"])
    v_, sigma = jnp.asarray(arrs["v"]), jnp.asarray(arrs["sigma"])
    rho = jnp.asarray(arrs["rho"])

    @jax.jit
    def _seed_jobs(jobs_, pr_, av_, pm_):
        # the seed simulate_pool_jobs: double vmap of the monolithic lane
        # (every lane pays the window DP, gather-formulated)
        def per_job(jr, p_, a_, m_):
            fn = lambda k, w, vv, s, r: fast_sim.simulate_one(
                k, w, vv, s, jr, PAPER_TPUT, p_, a_, m_, rho=r,
                backend="xla-gather",
            )
            return jax.vmap(fn)(kind, omega, v_, sigma, rho)

        return jax.vmap(per_job)(jobs_, pr_, av_, pm_)

    def seed_path():
        return _seed_jobs(stacked, prices, avail, preds)

    paths = {
        "seed": seed_path,
        "partitioned": lambda: fast_sim.simulate_pool_jobs(
            arrs, stacked, PAPER_TPUT, prices, avail, preds, backend="xla"
        ),
        "pallas": lambda: fast_sim.simulate_pool_jobs(
            arrs, stacked, PAPER_TPUT, prices, avail, preds,
            backend=pallas_backend,
        ),
    }

    secs, rows = {}, []
    for name, fn in paths.items():
        secs[name] = _bench(fn)
        rate = work_units / secs[name]
        rows.append((f"pool_sim_{name}", secs[name] * 1e6, rate))

    speedup = secs["seed"] / secs["partitioned"]
    rows.append(("pool_sim_partitioned_speedup", 0.0, speedup))
    rows.append((
        "pool_sim_pallas_speedup", 0.0, secs["seed"] / secs["pallas"]
    ))

    payload = {
        "workload": {
            "policies": n_pol, "jobs": N_JOBS, "slots": DEADLINE,
            "pool": "paper_pool(112) + baselines(3)",
        },
        "backend": jax.default_backend(),
        "pallas_mode": pallas_backend,
        "rows": [
            {"name": n, "us_per_call": us, "derived": d} for n, us, d in rows
        ],
        "speedup_partitioned_vs_seed": speedup,
    }
    with open(_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
