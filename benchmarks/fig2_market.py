"""Fig. 2: spot market fluctuation statistics (10-day A100-like trace)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.core.market import TraceStats, vast_like_trace


def run() -> list:
    stats, us = timed(
        lambda: [TraceStats.of(vast_like_trace(seed=s, days=10)) for s in range(8)]
    )
    m = float(np.mean([s.median_over_p90 for s in stats]))
    dn = float(np.mean([s.avail_day_night_ratio for s in stats]))
    am = float(np.mean([s.avail_mean for s in stats]))
    return [
        ("fig2_median_over_p90", us, m),          # paper: ~0.6
        ("fig2_avail_day_night_ratio", us, dn),   # paper: >1 (diurnal)
        ("fig2_avail_mean", us, am),              # capped [0, 16]
    ]
