"""Mamba2 SSD + MoE layer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MoEConfig, get_smoke_config
from repro.kernels.ref import ssd_scan_ref
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.ssm import ssd_chunked, ssd_step


def _ssd_inputs(rng, b=2, s=96, h=4, p=16, g=2, n=8):
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (h,))) * 0.5
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.3
    return x, dt, A, B, C


def _ref(x, dt, A, B, C):
    """Recurrence oracle reshaped to the grouped-head layout."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s)
    Af = jnp.tile(A, b)
    Bf = Bh.transpose(0, 2, 1, 3).reshape(b * h, s, n)
    Cf = Ch.transpose(0, 2, 1, 3).reshape(b * h, s, n)
    y, hf = ssd_scan_ref(xf, dtf, Af, Bf, Cf)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3), hf.reshape(b, h, n, p)


@pytest.mark.parametrize("chunk", [16, 32, 96])
def test_ssd_chunked_matches_recurrence(rng, chunk):
    x, dt, A, B, C = _ssd_inputs(rng)
    y, hfin = ssd_chunked(x, dt, A, B, C, chunk)
    yr, hr = _ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(
        np.asarray(hfin), np.asarray(hr.transpose(0, 1, 3, 2)), atol=2e-4, rtol=2e-4
    )


def test_ssd_chunk_size_invariance(rng):
    x, dt, A, B, C = _ssd_inputs(rng, s=64)
    y1, _ = ssd_chunked(x, dt, A, B, C, 8)
    y2, _ = ssd_chunked(x, dt, A, B, C, 64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=2e-4)


def test_ssd_decode_step_matches_chunked(rng):
    x, dt, A, B, C = _ssd_inputs(rng, b=1, s=12, g=1, n=8)
    y_full, _ = ssd_chunked(x, dt, A, B, C, 256)
    h = jnp.zeros((1, 4, 16, 8))
    for t in range(12):
        h, y = ssd_step(h, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(y_full[:, t]), atol=2e-4, rtol=2e-4
        )


def test_mamba_block_decode_matches_full(rng):
    cfg = get_smoke_config("mamba2-370m")
    p_tree = ssm_lib.init_mamba(rng, cfg, jnp.float32)
    from repro.sharding import split_params

    p, _ = split_params(p_tree)
    x = jax.random.normal(rng, (2, 10, cfg.d_model)) * 0.1
    y_full, cache_after = ssm_lib.apply_mamba(cfg, p, x, return_cache=True)
    cache = ssm_lib.init_mamba_cache(cfg, 2, jnp.float32)
    for t in range(10):
        y_t, cache = ssm_lib.apply_mamba_decode(cfg, p, x[:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(y_t[:, 0]), np.asarray(y_full[:, t]), atol=3e-4, rtol=3e-3
        )
    np.testing.assert_allclose(
        np.asarray(cache["ssd"]), np.asarray(cache_after["ssd"]), atol=3e-4, rtol=3e-3
    )


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_dense_ref(cfg, p, x):
    """No-capacity reference: every token exactly through its top-k experts."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    idx, wts, _ = moe_lib.route(cfg, p["router"], xf)
    outs = []
    for e in range(cfg.moe.num_experts):
        h = jax.nn.silu(xf @ p["w1"][e]) * (xf @ p["w3"][e])
        outs.append(h @ p["w2"][e])
    outs = jnp.stack(outs)  # (E, T, d)
    y = jnp.zeros_like(xf, dtype=jnp.float32)
    for j in range(cfg.moe.top_k):
        y = y + wts[:, j, None].astype(jnp.float32) * outs[
            idx[:, j], jnp.arange(xf.shape[0])
        ].astype(jnp.float32)
    return y.reshape(b, s, d)


def test_moe_matches_dense_reference_with_ample_capacity(rng):
    cfg = get_smoke_config("mixtral-8x7b")
    # capacity factor high enough that nothing drops
    import dataclasses

    cfg = dataclasses.replace(cfg, moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=4.0))
    p_tree = moe_lib.init_moe(rng, cfg, jnp.float32)
    from repro.sharding import split_params

    p, _ = split_params(p_tree)
    x = jax.random.normal(rng, (2, 32, cfg.d_model)) * 0.3
    y, aux = moe_lib.apply_moe(cfg, p, x)
    yr = _moe_dense_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4, rtol=2e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens(rng):
    cfg = get_smoke_config("mixtral-8x7b")
    import dataclasses

    tight = dataclasses.replace(
        cfg, moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=0.3)
    )
    ample = dataclasses.replace(
        cfg, moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=4.0)
    )
    p_tree = moe_lib.init_moe(rng, ample, jnp.float32)
    from repro.sharding import split_params

    p, _ = split_params(p_tree)
    x = jax.random.normal(rng, (1, 64, cfg.d_model)) * 0.3
    y_t, _ = moe_lib.apply_moe(tight, p, x)
    y_a, _ = moe_lib.apply_moe(ample, p, x)
    assert bool(jnp.any(jnp.abs(y_t - y_a) > 1e-5))  # some tokens dropped
    assert bool(jnp.isfinite(y_t).all())


def test_router_weights_normalized(rng):
    cfg = get_smoke_config("mixtral-8x22b")
    p_tree = moe_lib.init_moe(rng, cfg, jnp.float32)
    from repro.sharding import split_params

    p, _ = split_params(p_tree)
    x = jax.random.normal(rng, (8, cfg.d_model))
    idx, wts, aux = moe_lib.route(cfg, p["router"], x)
    np.testing.assert_allclose(np.asarray(wts.sum(-1)), 1.0, atol=1e-5)
    assert idx.shape == (8, cfg.moe.top_k)
