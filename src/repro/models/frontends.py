"""Modality frontend STUBS (the one allowed carve-out, see spec/DESIGN.md).

For [vlm] and [audio] architectures, the vision encoder / conv audio codec is
not implemented; ``make_frontend_embeddings`` fabricates patch/frame
embeddings of the right shape and ``input_specs`` (launch/dryrun.py) emits
matching ShapeDtypeStructs. Positions for M-RoPE get a synthetic image span
whose (t, h, w) streams differ, so the multimodal rotary path is exercised.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def make_frontend_embeddings(rng, cfg, batch: int, seq: int) -> jnp.ndarray:
    """Fabricated patch/frame embeddings (B, S, d_model)."""
    return jax.random.normal(rng, (batch, seq, cfg.d_model), jnp.float32).astype(
        jnp.dtype(cfg.dtype)
    ) * 0.02


def make_mrope_positions(batch: int, seq: int, image_span=None) -> np.ndarray:
    """(B, S, 3) positions: text positions identical across streams; an
    optional image span [start, start+h*w) gets 2-D (h, w) coordinates with a
    constant temporal index — the Qwen2-VL M-RoPE layout."""
    t = np.arange(seq, dtype=np.int32)
    pos = np.stack([t, t, t], axis=-1)  # (S, 3)
    if image_span is not None:
        start, h, w = image_span
        n = h * w
        assert start + n <= seq
        hh, ww = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
        pos[start : start + n, 0] = start  # constant temporal index
        pos[start : start + n, 1] = start + hh.reshape(-1)
        pos[start : start + n, 2] = start + ww.reshape(-1)
        # subsequent text resumes after max position
        nxt = start + max(h, w)
        tail = seq - (start + n)
        if tail > 0:
            cont = nxt + np.arange(tail, dtype=np.int32)
            pos[start + n :, :] = cont[:, None]
    return np.broadcast_to(pos[None], (batch, seq, 3)).copy()


def make_masked_prediction_batch(rng, cfg, batch: int, seq: int, mask_prob=0.08):
    """HuBERT-style batch: frame embeddings + codebook targets + mask."""
    k1, k2, k3 = jax.random.split(rng, 3)
    embeds = make_frontend_embeddings(k1, cfg, batch, seq)
    targets = jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    mask = jax.random.bernoulli(k3, mask_prob, (batch, seq))
    return {"embeds": embeds, "targets": targets, "loss_mask": mask}
