"""Online Policy Selection (Algorithm 2): Exponentiated Gradient over the
policy pool, full-information (every candidate's utility is evaluated per
job — cheap thanks to the vmapped simulator).

Guarantee (Theorem 2): with eta = sqrt(2 ln M / K) and utilities normalized
to [0,1], regret vs the best fixed policy is <= sqrt(2 K ln M).
benchmarks/theorem2.py verifies the bound empirically; test_selector.py
asserts it for adversarial utility streams.

Two implementations share the update rule:

* ``init_selector``/``update`` — the numpy reference, one job at a time
  (the paper's online formulation, and the parity oracle).
* ``eg_init``/``run_eg_scan`` — a jitted ``lax.scan`` over a whole (K, M)
  normalized-utility matrix, producing the final state plus per-job
  max-weight / regret trajectories (and, optionally, the full weight
  history) in ONE device call. Same update order, same clipping, same
  first-max argmax ties as the numpy loop (pinned to float32 tolerance in
  tests/test_selection_engine.py). This is what core.engine chains after
  the sharded pool simulator so the (K, M) matrix never round-trips
  through host numpy.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class SelectorState:
    weights: np.ndarray               # (M,) simplex
    eta: float
    k: int = 0
    cum_expected: float = 0.0         # sum_k E_{w_k}[u_k]
    cum_utils: Optional[np.ndarray] = None  # (M,) per-policy cumulative
    weight_history: List[np.ndarray] = field(default_factory=list)
    # record every history_stride-th update (plus the initial weights): the
    # per-update (M,) copies are O(K*M) host memory at engine scale
    history_stride: int = 1


def default_eta(n_policies: int, horizon: int) -> float:
    """Theorem 2's learning rate: sqrt(2 ln M / K)."""
    return float(np.sqrt(2.0 * np.log(n_policies) / max(horizon, 1)))


def init_selector(n_policies: int, horizon: int, eta: Optional[float] = None,
                  track_history: bool = False,
                  history_stride: int = 1) -> SelectorState:
    eta = default_eta(n_policies, horizon) if eta is None else eta
    if history_stride < 1:
        raise ValueError(f"history_stride must be >= 1, got {history_stride}")
    st = SelectorState(
        weights=np.full(n_policies, 1.0 / n_policies),
        eta=eta,
        cum_utils=np.zeros(n_policies),
        history_stride=history_stride,
    )
    if track_history:
        st.weight_history.append(st.weights.copy())
    return st


def select(state: SelectorState, rng: np.random.Generator) -> int:
    """Sample the policy to run for the incoming job (Line 6)."""
    return int(rng.choice(len(state.weights), p=state.weights))


def update(state: SelectorState, utilities: np.ndarray,
           track_history: bool = False) -> SelectorState:
    """EG / multiplicative-weights update (Lines 7-11). ``utilities`` must be
    normalized to [0, 1] (see repro.core.job.normalize_utility)."""
    u = np.clip(np.asarray(utilities, float), 0.0, 1.0)
    assert u.shape == state.weights.shape
    state.cum_expected += float(np.dot(state.weights, u))
    state.cum_utils += u
    logits = np.log(np.maximum(state.weights, 1e-300)) + state.eta * u
    logits -= logits.max()
    w = np.exp(logits)
    state.weights = w / w.sum()
    state.k += 1
    if track_history and state.k % state.history_stride == 0:
        state.weight_history.append(state.weights.copy())
    return state


def regret(state) -> float:
    """max_m sum_k u_k^m - sum_k E_{w_k}[u_k]  (cumulative, Theorem 2 LHS).
    Accepts SelectorState and EGState alike (same field names)."""
    return float(state.cum_utils.max() - state.cum_expected)


def regret_bound(n_policies: int, k: int) -> float:
    return float(np.sqrt(2.0 * k * np.log(n_policies)))


def best_policy(state) -> int:
    return int(np.argmax(state.weights))


def sample_policies(state_or_weights, n: int,
                    rng: np.random.Generator) -> np.ndarray:
    """``n`` i.i.d. draws from the selector distribution — Line 6 of
    Alg. 2 vectorized for fleet admission (one policy per arriving job).
    Accepts a SelectorState/EGState or a bare weight vector; weights are
    renormalized in f64 (the device state is f32)."""
    w = np.asarray(getattr(state_or_weights, "weights", state_or_weights),
                   np.float64)
    w = np.maximum(w, 0.0)
    w = w / w.sum()
    return rng.choice(len(w), size=int(n), p=w)


# ---------------------------------------------------------------------------
# Device-resident EG: jitted lax.scan over a (K, M) utility matrix
# ---------------------------------------------------------------------------

class EGState(NamedTuple):
    """Selector state as f32 device leaves — field names mirror
    SelectorState so ``regret``/``best_policy`` work on both."""
    weights: jnp.ndarray        # (M,) simplex
    eta: jnp.ndarray            # f32 scalar
    k: jnp.ndarray              # i32 scalar, updates applied so far
    cum_expected: jnp.ndarray   # f32 scalar
    cum_utils: jnp.ndarray      # (M,)


def eg_init(n_policies: int, horizon: int,
            eta: Optional[float] = None) -> EGState:
    """Device twin of :func:`init_selector` (uniform weights, Thm. 2 eta)."""
    eta = default_eta(n_policies, horizon) if eta is None else float(eta)
    return EGState(
        weights=jnp.full((n_policies,), 1.0 / n_policies, jnp.float32),
        eta=jnp.float32(eta),
        k=jnp.int32(0),
        cum_expected=jnp.float32(0.0),
        cum_utils=jnp.zeros((n_policies,), jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("track_history", "collect"))
def run_eg_scan(state: EGState, utilities, track_history: bool = False,
                collect: bool = False):
    """Run the EG update over every row of ``utilities`` ((K, M), normalized
    to [0, 1] — clipped here exactly like the numpy loop) in one
    ``lax.scan``. Returns ``(final_state, traj)`` where ``traj`` holds the
    per-job post-update trajectories:

      max_weight  (K,)  max_m w_k[m] — iters-to-half-weight reads off this
      regret      (K,)  max_m cum_utils - cum_expected after job k
      weights     (K, M) only when ``track_history`` (Fig. 10's heatmap)
      entropy     (K,)  only when ``collect`` — Shannon entropy of w_k,
                        the flight recorder's convergence gauge
      top_policy  (K,)  only when ``collect`` — argmax_m w_k[m] (first-max
                        ties, matching the numpy loop)

    Both static flags only ADD scan outputs, so the default call compiles
    to the identical program. The update order, the clipping, and
    first-max argmax ties match
    :func:`update` (the numpy loop floors weights at 1e-300 before the log;
    in f32 the floor is the smallest normal instead — weights there are
    zero to f32 anyway). Chain calls by passing the returned state back in:
    the scan is associative over concatenated utility chunks, which is what
    core.engine's job-chunked streaming mode relies on — for both the
    single-region and the regional engine path: the scan is agnostic to
    where the (K, M) utilities came from (``simulate_pool_jobs`` or
    ``simulate_pool_regions``), which is why R == 1 engine runs are
    bitwise-identical end to end."""
    u_all = jnp.clip(jnp.asarray(utilities, jnp.float32), 0.0, 1.0)
    tiny = jnp.float32(np.finfo(np.float32).tiny)

    def step(s: EGState, u):
        ce = s.cum_expected + jnp.dot(s.weights, u)
        cu = s.cum_utils + u
        logits = jnp.log(jnp.maximum(s.weights, tiny)) + s.eta * u
        logits = logits - logits.max()
        w = jnp.exp(logits)
        w = w / w.sum()
        ns = EGState(w, s.eta, s.k + 1, ce, cu)
        ys = {"max_weight": w.max(), "regret": cu.max() - ce}
        if track_history:
            ys["weights"] = w
        if collect:
            ys["entropy"] = -jnp.sum(w * jnp.log(jnp.maximum(w, tiny)))
            ys["top_policy"] = jnp.argmax(w).astype(jnp.int32)
        return ns, ys

    return jax.lax.scan(step, state, u_all)


def iters_to_half(max_weight: np.ndarray) -> int:
    """First 1-based update index where the leader's weight exceeds 0.5
    (K if it never does) — Fig. 9's convergence metric, read off the
    ``max_weight`` trajectory of :func:`run_eg_scan`."""
    hit = np.asarray(max_weight) > 0.5
    return int(np.argmax(hit)) + 1 if hit.any() else len(hit)
