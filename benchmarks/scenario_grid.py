"""Scenario-matrix evaluation harness: the full 124-lane pool swept over a
parameterized regime grid, with the regime axis batched through the sharded
simulator instead of looped on host.

The paper's headline claim (Fig. 9/10) is that online selection *adapts*
across market regimes — but the repo's benches measure four hand-picked
regimes. This module turns the claim into a measured winner map over
dozens of market worlds:

  axes      availability level (``avail_mean``) x price volatility
            (``price_sigma``) x deadline tightness (workload scale; the
            deadline stays 10 slots so market tensors stay uniform) x
            restart overhead (``mu1:mu2`` reconfiguration penalties) x
            prediction noise level — 3 x 2 x 2 x 2 x 2 = 48 regimes by
            default (the FrontierCS ``cant_be_late`` sets sweep three of
            these; this grid adds volatility and noise).
  batching  every regime contributes ``SCENARIO_GRID_JOBS`` jobs; regimes
            stack regime-major onto the jobs axis (one vectorized market
            generator — data.synthetic.market_regime_batch — one
            concatenated trace for the batched window gather, one
            noisy_matrix_batch call with per-row noise levels, one
            fast_sim.concat_jobs job stack). ``core.engine.
            simulate_and_select`` then runs the whole stack through
            ``simulate_pool_jobs_sharded`` with ``job_chunk`` streaming.
            The ONE exception to "no host loop": ``tput`` is a static jit
            argument, so the restart-overhead axis cannot ride the jobs
            axis — regimes are mu-major and the sweep issues one batched
            call per distinct throughput config (2 calls for 48 regimes),
            each covering its whole contiguous regime block.
  output    per-regime winner map (argmax lane of the per-regime mean
            utility) + regret table: the globally-best fixed lane's
            per-regime regret vs the per-regime oracle-best, and the EG
            selector's per-regime regret ratio (Thm. 2 bound). Folded into
            BENCH_pool_sim.json via the merge-preserve pattern;
            ``SCENARIO_GRID_JSON`` additionally writes a standalone
            winner-map artifact (the CI upload).

Env knobs: SCENARIO_GRID_JOBS (jobs per regime, default 16),
SCENARIO_GRID_AVAIL / SCENARIO_GRID_SIGMA / SCENARIO_GRID_TIGHT /
SCENARIO_GRID_NOISE (comma-separated values per axis), SCENARIO_GRID_MU
(comma-separated ``mu1:mu2`` pairs), SCENARIO_GRID_CHUNK (job_chunk for
the streamed simulation, 0 = one shot), SCENARIO_GRID_REPEAT,
SCENARIO_GRID_JSON, SCENARIO_GRID_TELEMETRY (path: run an untimed
``collect=True`` flight-recorder pass, write the per-regime telemetry
ledger there, and pin it bitwise against the timed pass's utilities);
POOL_SIM_MESH / POOL_SIM_JSON as everywhere else.

tests/test_scenario_grid.py pins one batched-grid cell bitwise against an
independent single-regime ``simulate_pool_jobs`` run, seed-determinism of
the grid, and directional sanity across axes; tests/test_bench_regression
pins the per-regime winner map under RUN_BENCH_REGRESSION=1.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from benchmarks.common import (PAPER_TPUT, StageTimer, job_stream_arrays,
                               merge_bench_rows)
from benchmarks.pool_sim_bench import _JSON_PATH


def _floats(env: str, default: str) -> Tuple[float, ...]:
    return tuple(float(x) for x in os.environ.get(env, default).split(",") if x)


def _mu_pairs(env: str, default: str) -> Tuple[Tuple[float, float], ...]:
    out = []
    for tok in os.environ.get(env, default).split(","):
        if not tok:
            continue
        m1, m2 = tok.split(":")
        out.append((float(m1), float(m2)))
    return tuple(out)


N_JOBS = int(os.environ.get("SCENARIO_GRID_JOBS", "16"))
CHUNK = int(os.environ.get("SCENARIO_GRID_CHUNK", "0"))
REPEAT = int(os.environ.get("SCENARIO_GRID_REPEAT", "1"))
AVAIL_AXIS = _floats("SCENARIO_GRID_AVAIL", "3.5,5.5,9.0")
SIGMA_AXIS = _floats("SCENARIO_GRID_SIGMA", "0.25,0.5")
TIGHT_AXIS = _floats("SCENARIO_GRID_TIGHT", "0.8,1.15")
MU_AXIS = _mu_pairs("SCENARIO_GRID_MU", "0.9:0.95,0.7:0.85")
NOISE_AXIS = _floats("SCENARIO_GRID_NOISE", "0.0,0.3")
GRID_JSON = os.environ.get("SCENARIO_GRID_JSON", "")
# non-empty: run a second collect=True pass (outside the timed sweep, so
# timings stay clean), write the per-regime telemetry ledger here, and pin
# it bitwise against the timed pass's utilities
TELEMETRY_JSON = os.environ.get("SCENARIO_GRID_TELEMETRY", "")

# every regime shares the market seed (so e.g. the availability axis is a
# pointwise-comparable paired draw) and paper_market's scarce-regime price
# level / diurnal swing; days=4 gives 192 slots of t0 room per regime
MARKET_SEED = 11
GRID_DAYS = 4.0
JOB_SEED = 7
DEADLINE = 10
NOISE_KIND = "fixed_uniform"
MEAN_PRICE = 0.7
AVAIL_SEASON_AMP = 3.0


@dataclass(frozen=True)
class Regime:
    avail_mean: float
    price_sigma: float
    tight: float          # workload scale (deadline tightness)
    mu1: float
    mu2: float
    noise: float          # prediction noise level (fixed_uniform)

    @property
    def key(self) -> str:
        return (f"a{self.avail_mean:g}_s{self.price_sigma:g}"
                f"_t{self.tight:g}_m{self.mu1:g}_n{self.noise:g}")


def grid_regimes(
    avail: Sequence[float] = AVAIL_AXIS,
    sigma: Sequence[float] = SIGMA_AXIS,
    tight: Sequence[float] = TIGHT_AXIS,
    mu: Sequence[Tuple[float, float]] = MU_AXIS,
    noise: Sequence[float] = NOISE_AXIS,
) -> List[Regime]:
    """The full cartesian grid, mu-major: the throughput axis varies
    slowest so each distinct (mu1, mu2) is one contiguous regime block —
    what lets evaluate_grid run one batched call per throughput config."""
    return [
        Regime(a, s, t, m1, m2, nz)
        for (m1, m2) in mu
        for a in avail
        for s in sigma
        for t in tight
        for nz in noise
    ]


def build_grid_inputs(regimes: List[Regime], n_jobs: int = N_JOBS,
                      deadline: int = DEADLINE):
    """Regime-major stacked engine inputs for the whole grid.

    One vectorized market generation (R regimes in one
    ``market_regime_batch`` call), one concatenated trace so the batched
    window gather + noisy forecast stack run as ONE
    ``engine.prepare_noisy_inputs`` call (per-regime noise levels ride the
    per-row ``level`` axis), and one ``concat_jobs`` stack of per-regime
    job blocks. Base job draws, window starts and noise seeds are shared
    across regimes — regimes are matched pairs, so axis comparisons are
    controlled — while each regime's workloads carry its tightness scale.

    Returns ``(jobs (R*K,), prices (R*K, d), avail (R*K, d), preds
    (R*K, d, W1MAX, 2), t0s (K,))``.
    """
    from repro.core import engine, fast_sim
    from repro.core.market import from_arrays
    from repro.data.synthetic import market_regime_batch

    R = len(regimes)
    prices_r, avail_r = market_regime_batch(
        np.full(R, MARKET_SEED, np.int64),
        days=GRID_DAYS,
        mean_price=MEAN_PRICE,
        price_sigma=[r.price_sigma for r in regimes],
        avail_mean=[r.avail_mean for r in regimes],
        avail_season_amp=AVAIL_SEASON_AMP,
    )
    T = prices_r.shape[1]
    # windows never cross a regime boundary (t0 <= T - d - 1 within each
    # regime), so the concatenated trace + offset t0s reuse the engine's
    # batched prep verbatim
    cat = from_arrays(prices_r.reshape(-1), avail_r.reshape(-1))
    t0s = np.random.default_rng(JOB_SEED + 1).integers(
        0, T - deadline - 1, n_jobs
    )
    t0s_all = (np.arange(R)[:, None] * T + t0s[None, :]).reshape(-1)
    seeds = JOB_SEED * 100003 + np.arange(n_jobs)
    prices, avail, preds = engine.prepare_noisy_inputs(
        cat, t0s_all, deadline, NOISE_KIND,
        np.repeat([r.noise for r in regimes], n_jobs),
        np.tile(seeds, R),
    )
    jobs = fast_sim.concat_jobs([
        job_stream_arrays(np.random.default_rng(JOB_SEED), n_jobs, deadline,
                          workload_scale=r.tight)
        for r in regimes
    ])
    return jobs, prices, avail, preds, t0s


def evaluate_grid(pool_arrays: dict, regimes: List[Regime], jobs, prices,
                  avail, preds, n_jobs: int = N_JOBS, *,
                  job_chunk: int = CHUNK, mesh=None,
                  backend: str = "xla", collect: bool = False):
    """Run the stacked grid through the engine: one ``simulate_and_select``
    call per distinct throughput config (contiguous mu-major block), each
    covering every regime in the block on the jobs axis — no per-regime
    host loop over ``simulate_pool_jobs``. Returns (R, K, M) raw utilities
    in regime order; with ``collect=True``, ``(util, sim_out)`` where
    ``sim_out`` is the merged flight-recorder dict ((R*K, M, ...) leaves,
    regime-major) for ``obs.ledger.grid_ledger``."""
    from repro.configs.base import ThroughputConfig
    from repro.core import engine, fast_sim

    R = len(regimes)
    M = int(np.asarray(pool_arrays["kind"]).shape[0])
    util = np.empty((R, n_jobs, M), np.float32)
    sim_chunks = []
    lo = 0
    while lo < R:
        hi = lo + 1
        while hi < R and (regimes[hi].mu1, regimes[hi].mu2) == (
                regimes[lo].mu1, regimes[lo].mu2):
            hi += 1
        tput = ThroughputConfig(alpha=PAPER_TPUT.alpha, beta=PAPER_TPUT.beta,
                                mu1=regimes[lo].mu1, mu2=regimes[lo].mu2)
        a, b = lo * n_jobs, hi * n_jobs
        res = engine.simulate_and_select(
            pool_arrays, fast_sim.slice_jobs(jobs, a, b), tput,
            prices[a:b], avail[a:b], preds[a:b],
            mesh=mesh, backend=backend, job_chunk=job_chunk,
            return_utilities=True, collect=collect,
        )
        util[lo:hi] = res.utilities.reshape(hi - lo, n_jobs, M)
        if collect:
            sim_chunks.append(res.sim_out)
        lo = hi
    if collect:
        sim_out = {k: (np.asarray(sim_chunks[0][k]) if len(sim_chunks) == 1
                       else np.concatenate(
                           [np.asarray(c[k]) for c in sim_chunks]))
                   for k in sim_chunks[0]}
        return util, sim_out
    return util


def analyze_grid(pool, regimes: List[Regime], util: np.ndarray, jobs) -> dict:
    """Winner map + regret table from the (R, K, M) utility tensor.

    Per regime: the winner lane (argmax of the per-regime mean utility),
    the oracle-best mean utility, the globally-best fixed lane's regret
    vs that oracle, and the EG selector's regret ratio (final regret over
    the Thm. 2 bound) from a per-regime selector run over the regime's
    K-job stream."""
    from repro.core import fast_sim, selector
    from repro.core.job import normalize_utility_batch

    R, K, M = util.shape
    mean_u = util.mean(axis=1)                      # (R, M)
    winner_idx = mean_u.argmax(axis=1)
    oracle = mean_u.max(axis=1)                     # per-regime oracle-best
    fixed_best = int(mean_u.mean(axis=0).argmax())  # best single lane overall
    regret_fixed = oracle - mean_u[:, fixed_best]
    per_regime = []
    for r, reg in enumerate(regimes):
        jb = fast_sim.slice_jobs(jobs, r * K, (r + 1) * K)
        st, _ = selector.run_eg_scan(
            selector.eg_init(M, K), normalize_utility_batch(jb, util[r])
        )
        per_regime.append({
            "key": reg.key,
            "avail_mean": reg.avail_mean, "price_sigma": reg.price_sigma,
            "tight": reg.tight, "mu1": reg.mu1, "mu2": reg.mu2,
            "noise": reg.noise,
            "winner": pool[int(winner_idx[r])].name,
            "winner_idx": int(winner_idx[r]),
            "best_mean_utility": float(oracle[r]),
            "fixed_lane_regret": float(regret_fixed[r]),
            "eg_regret_ratio": float(
                selector.regret(st) / selector.regret_bound(M, K)
            ),
            "eg_winner": pool[selector.best_policy(st)].name,
        })
    return {
        "mean_u": mean_u,
        "winner_idx": winner_idx,
        "fixed_best": fixed_best,
        "fixed_best_name": pool[fixed_best].name,
        "regret_fixed": regret_fixed,
        "per_regime": per_regime,
    }


def run():
    import jax

    from repro.core.policy_pool import (
        baseline_specs,
        paper_pool,
        rand_deadline_pool,
        specs_to_arrays,
    )
    from repro.launch.mesh import make_pool_mesh, parse_pool_mesh_shape

    pool = paper_pool() + rand_deadline_pool() + baseline_specs()
    arrs = specs_to_arrays(pool)
    regimes = grid_regimes()
    mesh = make_pool_mesh(
        shape=parse_pool_mesh_shape(os.environ.get("POOL_SIM_MESH", ""))
    )
    st = StageTimer()
    with st.stage("prep"):
        jobs, prices, avail, preds, _ = build_grid_inputs(regimes)

    ev = lambda: evaluate_grid(arrs, regimes, jobs, prices, avail, preds,
                               mesh=mesh)
    with st.stage("compile"):
        util = ev()                 # warm-up call pays compilation
    t0 = time.perf_counter()
    with st.stage("simulate"):
        for _ in range(max(REPEAT, 1)):
            ev()
    secs = (time.perf_counter() - t0) / max(REPEAT, 1)

    with st.stage("analyze"):
        res = analyze_grid(pool, regimes, util, jobs)
    eg_ratios = [p["eg_regret_ratio"] for p in res["per_regime"]]
    units = len(regimes) * util.shape[1] * len(pool) * DEADLINE
    rows = [
        ("scenario_grid_sweep", secs * 1e6, units / secs),
        ("scenario_grid_regimes", 0.0, float(len(regimes))),
        ("scenario_grid_winner_diversity", 0.0,
         float(len(set(res["winner_idx"].tolist())))),
        ("scenario_grid_regret_fixed_mean", 0.0,
         float(np.mean(res["regret_fixed"]))),
        ("scenario_grid_regret_fixed_max", 0.0,
         float(np.max(res["regret_fixed"]))),
        ("scenario_grid_eg_regret_ratio_mean", 0.0,
         float(np.mean(eg_ratios))),
    ]
    # per-regime winner rows: the regression pins read the lane INDEX off
    # the derived column (names live in the extra payload)
    rows += [
        (f"scenario_grid_winner__{p['key']}", 0.0, float(p["winner_idx"]))
        for p in res["per_regime"]
    ]

    telemetry = None
    if TELEMETRY_JSON:
        from repro.configs.base import ThroughputConfig
        from repro.obs import grid_ledger

        # flight-recorder pass OUTSIDE the timed sweep: collect=False above
        # keeps the timings on the exact shipped program, and the bitwise
        # self-check below proves the collect path didn't perturb it
        with st.stage("telemetry"):
            util_t, sim_out = evaluate_grid(
                arrs, regimes, jobs, prices, avail, preds, mesh=mesh,
                collect=True,
            )
            tputs = [ThroughputConfig(alpha=PAPER_TPUT.alpha,
                                      beta=PAPER_TPUT.beta,
                                      mu1=r.mu1, mu2=r.mu2)
                     for r in regimes]
            meta = [{"key": r.key, "avail_mean": r.avail_mean,
                     "price_sigma": r.price_sigma, "tight": r.tight,
                     "mu1": r.mu1, "mu2": r.mu2, "noise": r.noise}
                    for r in regimes]
            telemetry = grid_ledger(meta, util_t, sim_out, jobs, tputs,
                                    util.shape[1],
                                    lane_names=[p.name for p in pool])
        bitwise = bool(np.array_equal(util, util_t))
        rows += [
            ("scenario_grid_tel_bitwise_match", 0.0, float(bitwise)),
            ("scenario_grid_tel_cost_residual", 0.0,
             telemetry["max_abs_cost_residual"]),
            ("scenario_grid_tel_utility_residual", 0.0,
             telemetry["max_abs_utility_residual"]),
        ]
        os.makedirs(os.path.dirname(TELEMETRY_JSON) or ".", exist_ok=True)
        with open(TELEMETRY_JSON, "w") as f:
            json.dump(telemetry, f, indent=2)

    rows += st.rows("scenario_grid")

    extra = {
        "workload": {
            "regimes": len(regimes), "jobs_per_regime": util.shape[1],
            "slots": DEADLINE, "policies": len(pool),
            "noise_kind": NOISE_KIND, "days": GRID_DAYS,
            "pool": "paper_pool(112) + rand_deadline(9) + baselines(3)",
        },
        "axes": {
            "avail_mean": list(AVAIL_AXIS), "price_sigma": list(SIGMA_AXIS),
            "tight": list(TIGHT_AXIS),
            "mu": [f"{m1:g}:{m2:g}" for m1, m2 in MU_AXIS],
            "noise": list(NOISE_AXIS),
        },
        "pool_mesh": "x".join(map(str, mesh.devices.shape)),
        "job_chunk": CHUNK,
        "fixed_best": res["fixed_best_name"],
        "winner_map": {p["key"]: p["winner"] for p in res["per_regime"]},
        "per_regime": res["per_regime"],
        "devices": jax.device_count(),
    }
    merge_bench_rows(_JSON_PATH, "scenario_grid", "scenario_grid", rows,
                     extra)
    if GRID_JSON:
        os.makedirs(os.path.dirname(GRID_JSON) or ".", exist_ok=True)
        with open(GRID_JSON, "w") as f:
            json.dump(extra, f, indent=2)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
