"""Logical-axis sharding: MaxText-style rules with divisibility fallback.

Model code annotates parameters and activations with *logical* axis names.
At launch time a mesh + rule table is installed (``use_sharding``); the
helpers resolve logical names to mesh axes, dropping any mesh axis that does
not evenly divide the corresponding dimension (fallback = replicate). Model
code therefore stays mesh-agnostic and runs unchanged on 1 CPU device (tests)
and on the (pod, data, model) production mesh (dry-run / TPU).
"""
from __future__ import annotations

import contextlib
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

# logical axis -> tuple of mesh axes (tried in order, divisibility permitting)
DEFAULT_RULES = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),
    # KV caches are sequence-parallel over the model axis: kv_heads (1..8)
    # rarely divide a 16-way axis, and replicating a 32k-decode cache costs
    # ~17 GiB/device (dry-run finding, EXPERIMENTS.md §Perf). Sharding the
    # cache length instead costs only tiny softmax-combine collectives.
    "kv_seq": ("model",),
    "window": (),
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ssm_heads": ("model",),
    "ssm_state": (),
    "pos": (),
    # policy-pool simulator (fast_sim.simulate_pool_jobs_sharded): jobs ride
    # the pool mesh's "jobs" axis (or the production data axes when the pool
    # sim runs inside the training mesh). On a 2-D (jobs, lanes) pool mesh
    # (launch.mesh.make_pool_mesh(shape=(a, b))) the policy-lane axis shards
    # over "lanes" — the kind partition isolates AHAP from cheap lanes first,
    # so every lane shard carries a uniform DP-heavy or cheap workload.
    "jobs": ("jobs", "pod", "data"),
    "lanes": ("lanes",),
    # weights
    "fsdp": ("data",),
    "tensor": ("model",),
    "vocab": ("model",),
    "experts": (),
    "layers": (),
    "lora_rank": (),
}


class ShardingCtx(NamedTuple):
    mesh: Mesh
    rules: dict


_CTX: list = []  # stack


def current_ctx() -> Optional[ShardingCtx]:
    return _CTX[-1] if _CTX else None


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Optional[dict] = None):
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _CTX.append(ShardingCtx(mesh, merged))
    try:
        yield
    finally:
        _CTX.pop()


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def resolve_spec(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: dict,
) -> P:
    """Logical axes -> PartitionSpec, dropping non-dividing / reused mesh axes."""
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    used = set()
    out = []
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for name, dim in zip(logical_axes, shape):
        if name is None:
            out.append(None)
            continue
        mesh_axes = rules.get(name, ())
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        picked = []
        extent = 1
        for ax in mesh_axes:
            if ax in used or ax not in axis_sizes:
                continue
            if dim % (extent * axis_sizes[ax]) == 0:
                picked.append(ax)
                extent *= axis_sizes[ax]
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    return P(*out)


def named_sharding(logical_axes, shape, ctx: Optional[ShardingCtx] = None):
    ctx = ctx or current_ctx()
    assert ctx is not None
    return NamedSharding(ctx.mesh, resolve_spec(logical_axes, shape, ctx.mesh, ctx.rules))


def shard(x: jnp.ndarray, *logical_axes: Optional[str]) -> jnp.ndarray:
    """Activation sharding constraint; no-op outside a sharding context."""
    ctx = current_ctx()
    if ctx is None:
        return x
    sh = named_sharding(logical_axes, x.shape, ctx)
    return jax.lax.with_sharding_constraint(x, sh)


# ---------------------------------------------------------------------------
# Param annotation
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class Param:
    """A parameter leaf paired with its logical axes.

    Registered as a pytree node with the axes as *static* metadata, so Param
    trees pass transparently through jit / eval_shape / tree.map (the mapped
    function sees the value; axes are preserved) — this is what lets the
    dry-run get both abstract shapes AND sharding axes from one
    ``jax.eval_shape(init_params)`` without materializing 100B params.
    """

    __slots__ = ("value", "axes")

    def __init__(self, value, axes: Tuple[Optional[str], ...]):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Param(shape={shape}, axes={self.axes})"


def is_param(x) -> bool:
    return isinstance(x, Param)


# axes trees use ','-joined string leaves so they stay tree-mappable
def axes_to_str(axes: Tuple[Optional[str], ...]) -> str:
    return ",".join("" if a is None else a for a in axes)


def str_to_axes(s: str) -> Tuple[Optional[str], ...]:
    if s == "":
        return ()
    return tuple(None if a == "" else a for a in s.split(","))


def split_params(tree):
    """Tree of Param -> (values tree, logical-axes tree with string leaves)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: axes_to_str(p.axes), tree, is_leaf=is_param)
    return values, axes


def tree_shardings(values_tree, axes_tree, mesh: Mesh, rules: Optional[dict] = None):
    """Shardings for pjit in/out_shardings, resolved against concrete shapes."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)

    def _one(v, axes):
        ax = str_to_axes(axes) if isinstance(axes, str) else axes
        if len(ax) == 0:
            ax = (None,) * len(v.shape)
        return NamedSharding(mesh, resolve_spec(ax, v.shape, mesh, merged))

    return jax.tree.map(_one, values_tree, axes_tree)
