"""Fleet-engine parity: core.fleet vs its two references.

``simulate_fleet`` must (a) collapse to the single-job pool simulator
bitwise when there is no contention — the per-job decision rules are the
very same jitted code — and (b) match the numpy ``MultiJobScheduler``
oracle through the demand-then-waterfall contention semantics at the
repo's python-vs-f32-device tolerance (1e-2 on utilities). On top of the
parity pins: capacity conservation, the least-slack-first grant order,
arrival/retirement masking, padded-job inertness, and the EG-weighted
admission helpers. The multi-device half mirrors tests/test_sharded_pool —
a subprocess forces 4 host devices and pins ``simulate_fleet_sharded``
bitwise against the unsharded engine across mesh shapes and padding cases.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

from benchmarks.common import job_stream  # noqa: E402
from repro.configs.base import JobConfig, ThroughputConfig  # noqa: E402
from repro.core import fast_sim, fleet  # noqa: E402
from repro.core.market import vast_like_trace  # noqa: E402
from repro.core.multi_job import MultiJobScheduler  # noqa: E402
from repro.core.policy_pool import (  # noqa: E402
    baseline_specs,
    paper_pool,
    rand_deadline_pool,
    specs_to_arrays,
)
from repro.core.predictor import NoisyPredictor  # noqa: E402

TPUT = ThroughputConfig(mu1=0.9, mu2=0.95)
D = 10


def _small_pool():
    return (paper_pool(omegas=(2,), sigmas=(0.5,))
            + rand_deadline_pool((0.4,)) + baseline_specs())


def _market(T, seed=5, noise_seed=3):
    tr = vast_like_trace(seed=seed, days=2).window(0, T + 1)
    prices = tr.prices[:T].astype(np.float32)
    avail = tr.avail[:T].astype(np.int64)
    pred = NoisyPredictor(tr, "fixed_uniform", 0.2, seed=noise_seed).matrix(
        fast_sim.W1MAX - 1
    )[:T].astype(np.float32)
    return tr, prices, avail, pred


def _rows(arrs, idx):
    return {k: np.asarray(arrs[k])[idx]
            for k in ("kind", "omega", "v", "sigma", "rho", "cfrac")}


# ---------------------------------------------------------------------------
# single job: no contention -> bitwise the pool simulator
# ---------------------------------------------------------------------------

def test_single_job_bitwise_matches_pool_sim():
    pool = _small_pool()
    arrs = specs_to_arrays(pool)
    job = JobConfig(workload=40, deadline=D, n_min=1, n_max=10, value=80.0)
    _, prices, avail, pred = _market(D, seed=1, noise_seed=0)
    stacked1 = fast_sim.stack_jobs([job])
    base = fast_sim.simulate_pool_jobs(
        arrs, stacked1, TPUT, prices[None], avail[None], pred[None]
    )
    for li in range(len(pool)):
        out = fleet.simulate_fleet(
            _rows(arrs, [li]), stacked1, [0], TPUT, prices, avail, pred
        )
        for k in ("utility", "cost", "completion_time", "z_ddl", "completed",
                  "n_od", "n_spot"):
            np.testing.assert_array_equal(
                np.asarray(base[k])[0, li], np.asarray(out[k])[0],
                err_msg=f"{k} lane={pool[li].name}",
            )


# ---------------------------------------------------------------------------
# multi-job: the numpy oracle, conservation, padding, 1-device fallback
# ---------------------------------------------------------------------------

def _contended_fleet(J=12, T=24):
    pool = _small_pool()
    arrs = specs_to_arrays(pool)
    rng = np.random.default_rng(7)
    tr, prices, avail, pred = _market(T)
    jobs = list(job_stream(rng, J, deadline=D))
    arrivals = rng.integers(0, 8, size=J)
    idx = rng.integers(0, len(pool), size=J)
    rows = _rows(arrs, idx)
    out = fleet.simulate_fleet(rows, fast_sim.stack_jobs(jobs), arrivals,
                               TPUT, prices, avail, pred)
    return pool, idx, jobs, arrivals, tr, prices, avail, pred, rows, out


def test_multi_job_matches_numpy_oracle():
    (pool, idx, jobs, arrivals, tr, _, _, pred, _, out) = _contended_fleet()
    T = len(tr.prices) - 1
    sched = MultiJobScheduler(TPUT, tr)
    for i in range(len(jobs)):
        sched.submit(int(arrivals[i]), jobs[i], pool[int(idx[i])].build(),
                     pred=pred)
    res = {r.job_id: r for r in sched.run(T)}
    for i in range(len(jobs)):
        for field, key in (("utility", "utility"), ("cost", "cost"),
                           ("completion_time", "completion_time")):
            np.testing.assert_allclose(
                float(np.asarray(out[key])[i]), getattr(res[i], field),
                atol=1e-2, err_msg=f"job {i} ({pool[int(idx[i])].name}) {key}",
            )


def test_spot_grants_conserve_supply():
    (*_, avail, _, _, out) = _contended_fleet()
    granted = np.asarray(out["n_spot"]).sum(axis=0)
    assert np.all(granted <= avail), (granted, avail)


def test_padded_jobs_are_inert():
    (pool, idx, jobs, arrivals, tr, prices, avail, pred, rows, out) = \
        _contended_fleet()
    T = len(prices)
    J = len(jobs)
    jobs_p = jobs + [jobs[0]]
    rows_p = {k: np.concatenate([v, v[:1]]) for k, v in rows.items()}
    arr_p = np.concatenate([arrivals, [T]])  # arrival = T: never live
    out_p = fleet.simulate_fleet(rows_p, fast_sim.stack_jobs(jobs_p), arr_p,
                                 TPUT, prices, avail, pred)
    for k in out:
        np.testing.assert_array_equal(
            np.asarray(out[k]), np.asarray(out_p[k])[:J], err_msg=k
        )


def test_sharded_single_device_fallback_bitwise():
    import jax

    assert jax.device_count() == 1
    (_, _, jobs, arrivals, _, prices, avail, pred, rows, out) = \
        _contended_fleet()
    sh = fleet.simulate_fleet_sharded(rows, fast_sim.stack_jobs(jobs),
                                      arrivals, TPUT, prices, avail, pred)
    for k in out:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(sh[k]),
                                      err_msg=k)


# ---------------------------------------------------------------------------
# waterfall order + masking semantics, pinned on a hand-checkable scenario
# ---------------------------------------------------------------------------

def test_least_slack_first_and_completion_release():
    """Two all-spot (MSU) jobs against a constant 8-unit pool: the tight
    deadline drains first (6 of 8), the slack one rides the residual (2)
    until it completes at slot 2 — after which it stops demanding and the
    supply it held is NOT granted to anyone (sum drops), while the tight
    job keeps its full grant through its deadline and nothing is allocated
    outside either job's live window."""
    T = 8
    prices = np.full(T, 0.5, np.float32)
    avail = np.full(T, 8, np.int64)
    tight = JobConfig(workload=60, deadline=5, n_min=1, n_max=6, value=80.0)
    slackj = JobConfig(workload=4, deadline=10, n_min=1, n_max=6, value=80.0)
    from repro.core.policy_pool import KIND_MSU

    rows = {"kind": np.array([KIND_MSU, KIND_MSU])}
    out = fleet.simulate_fleet(rows, fast_sim.stack_jobs([tight, slackj]),
                               [0, 0], TPUT, prices, avail, None)
    ns = np.asarray(out["n_spot"])
    # tight job: full 6-unit grant on every live slot, nothing after d=5
    np.testing.assert_array_equal(ns[0], [6, 6, 6, 6, 6, 0, 0, 0])
    # slack job: residual 2 until it completes during slot 2, then retired
    np.testing.assert_array_equal(ns[1], [2, 2, 2, 0, 0, 0, 0, 0])
    assert bool(np.asarray(out["completed"])[1])
    # slot-2 progress: 1.8 (ramp-up mu1) + 2.0 + 2.0 covers workload 4
    np.testing.assert_allclose(float(np.asarray(out["completion_time"])[1]),
                               2.1, atol=1e-6)
    assert not bool(np.asarray(out["completed"])[0])


def test_arrival_masks_allocations():
    """A job arriving at t=a never holds capacity outside [a, a+d)."""
    (_, _, jobs, arrivals, _, _, _, _, _, out) = _contended_fleet()
    ns = np.asarray(out["n_spot"])
    no = np.asarray(out["n_od"])
    T = ns.shape[1]
    ts = np.arange(T)[None, :]
    a = np.asarray(arrivals)[:, None]
    d = np.asarray([j.deadline for j in jobs])[:, None]
    outside = (ts < a) | (ts >= a + d)
    assert not np.any(ns[outside]), "spot allocated outside live window"
    assert not np.any(no[outside]), "on-demand allocated outside live window"


# ---------------------------------------------------------------------------
# EG-weighted admission
# ---------------------------------------------------------------------------

def test_policy_rows_from_weights():
    import jax.numpy as jnp

    from repro.core import engine, selector

    pool = _small_pool()
    arrs = specs_to_arrays(pool)
    w = np.zeros(len(pool))
    w[3], w[5] = 2.0, 1.0

    rows, idx = fleet.policy_rows_from_weights(arrs, w, 8, greedy=True)
    assert np.all(idx == 3)
    for k in rows:
        np.testing.assert_array_equal(np.asarray(rows[k]),
                                      np.asarray(arrs[k])[idx], err_msg=k)

    rows2, idx2 = fleet.policy_rows_from_weights(
        arrs, w, 256, rng=np.random.default_rng(0)
    )
    assert set(np.unique(idx2)) <= {3, 5}
    assert 0.5 < float(np.mean(idx2 == 3)) < 0.85  # ~2/3 from the 2:1 weights
    for k in rows2:
        np.testing.assert_array_equal(np.asarray(rows2[k]),
                                      np.asarray(arrs[k])[idx2], err_msg=k)

    # rng=None must be deterministic (fixed seed), not time-dependent
    _, ia = fleet.policy_rows_from_weights(arrs, w, 16)
    _, ib = fleet.policy_rows_from_weights(arrs, w, 16)
    np.testing.assert_array_equal(ia, ib)

    # the engine-side hook delegates here with the selector's final weights
    st = selector.eg_init(len(pool), 16)._replace(
        weights=jnp.asarray(w / w.sum(), jnp.float32)
    )
    res = engine.SelectionResult(
        state=st, mean_utility=np.zeros(len(pool)),
        max_weight=np.zeros(1), regret=np.zeros(1), n_jobs=0,
    )
    _, idx3 = res.admission_rows(arrs, 8, greedy=True)
    np.testing.assert_array_equal(idx3, idx)


# ---------------------------------------------------------------------------
# sharded engine == unsharded engine, bitwise, on 4 forced host devices
# ---------------------------------------------------------------------------

# Job counts 3/5/9 exercise the under-, padding- and non-dividing layouts of
# the interleaved [AHAP | cheap] per-device blocks; the mesh list covers the
# default 1-D jobs mesh, the 2-D (jobs, lanes) mesh (fleet replicates over
# "lanes"), lanes-only (jobs axis size 1 -> unsharded fallback) and an
# explicit 1-D shape.
_CHILD = r"""
import numpy as np
import jax

assert jax.device_count() == 4, jax.devices()

from benchmarks.common import job_stream
from repro.configs.base import ThroughputConfig
from repro.core import fast_sim, fleet
from repro.core.market import vast_like_trace
from repro.core.policy_pool import (
    baseline_specs, paper_pool, rand_deadline_pool, specs_to_arrays,
)
from repro.core.predictor import NoisyPredictor
from repro.launch.mesh import make_pool_mesh

TPUT = ThroughputConfig(mu1=0.9, mu2=0.95)
d = 10
T = 20
pool = (paper_pool(omegas=(2,), sigmas=(0.5,))
        + rand_deadline_pool((0.4,)) + baseline_specs())
arrs = specs_to_arrays(pool)
tr = vast_like_trace(seed=5, days=1).window(0, T + 1)
prices = tr.prices[:T].astype(np.float32)
avail = tr.avail[:T].astype(np.int64)
pred = NoisyPredictor(tr, "fixed_uniform", 0.2, seed=3).matrix(
    fast_sim.W1MAX - 1)[:T].astype(np.float32)
rng = np.random.default_rng(11)
MESHES = [None, (2, 2), (1, 4), (4,)]
for J in (3, 5, 9):
    jobs = list(job_stream(rng, J, deadline=d))
    arrivals = rng.integers(0, 8, size=J)
    idx = rng.integers(0, len(pool), size=J)
    rows = {k: np.asarray(arrs[k])[idx] for k in
            ("kind", "omega", "v", "sigma", "rho", "cfrac")}
    stacked = fast_sim.stack_jobs(jobs)
    base = fleet.simulate_fleet(rows, stacked, arrivals, TPUT,
                                prices, avail, pred)
    for shape in MESHES:
        sh = fleet.simulate_fleet_sharded(
            rows, stacked, arrivals, TPUT, prices, avail, pred,
            mesh=None if shape is None else make_pool_mesh(shape=shape))
        for k in base:
            np.testing.assert_array_equal(
                np.asarray(base[k]), np.asarray(sh[k]),
                err_msg=f"{k} J={J} mesh={shape}")

# collect=True: the telemetry-carrying fleet (including the all-gathered
# waterfall rank series) shards bitwise too, and its shared keys match the
# collect=False run (one config — J=9 inputs left in scope by the loop)
tel = fleet.simulate_fleet(rows, stacked, arrivals, TPUT, prices, avail,
                           pred, collect=True)
tel_sh = fleet.simulate_fleet_sharded(
    rows, stacked, arrivals, TPUT, prices, avail, pred,
    mesh=make_pool_mesh(shape=(4,)), collect=True)
assert set(tel) == set(tel_sh) and len(tel) == len(base) + 12, sorted(tel)
for k in tel:
    np.testing.assert_array_equal(
        np.asarray(tel[k]), np.asarray(tel_sh[k]), err_msg=f"collect {k}")
for k in base:
    np.testing.assert_array_equal(
        np.asarray(base[k]), np.asarray(tel[k]),
        err_msg=f"collect-vs-base {k}")

# fallback: fallback=None rides the same compiled program as the default
# (bitwise vs base), and the ARMED prediction-failure monitor shards
# bitwise too — on storm-faulted inputs that actually trigger it
# (collect + fallback adds the 12 fleet keys + 2 fallback keys)
from repro.chaos import FallbackConfig, inject, storm_schedule
none = fleet.simulate_fleet(rows, stacked, arrivals, TPUT, prices, avail,
                            pred, fallback=None)
for k in base:
    np.testing.assert_array_equal(
        np.asarray(base[k]), np.asarray(none[k]), err_msg=f"fb-none {k}")
pf, af, prf = inject(prices, avail, pred,
                     storm_schedule(1, T, n_storms=2, storm_len=5,
                                    pred_fault="stale"))
cfg = FallbackConfig(threshold=0.5, lam=0.5)
fb = fleet.simulate_fleet(rows, stacked, arrivals, TPUT, pf, af, prf,
                          collect=True, fallback=cfg)
assert len(fb) == len(base) + 14, sorted(fb)
assert np.asarray(fb["tel_fallback"]).any(), "monitor never armed"
for shape in MESHES:
    fb_sh = fleet.simulate_fleet_sharded(
        rows, stacked, arrivals, TPUT, pf, af, prf,
        mesh=None if shape is None else make_pool_mesh(shape=shape),
        collect=True, fallback=cfg)
    assert set(fb_sh) == set(fb)
    for k in fb:
        np.testing.assert_array_equal(
            np.asarray(fb[k]), np.asarray(fb_sh[k]),
            err_msg=f"fleet fallback {k} mesh={shape}")
print("FLEET-SHARDED-OK")
"""


def test_fleet_sharded_matches_unsharded_4dev_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [SRC, os.path.dirname(SRC)] + env.get("PYTHONPATH", "").split(os.pathsep)
    ).rstrip(os.pathsep)
    out = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "FLEET-SHARDED-OK" in out.stdout
