import os

# Tests must see the single real CPU device — the 512-device forcing is
# strictly dry-run-only (python -m repro.launch.dryrun in a subprocess).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    import jax

    return jax.random.PRNGKey(0)
