"""Re-run the loop-aware HLO accounting over saved compiled modules
(experiments/hlo/*.hlo.zst) and refresh the dry-run JSON records — analyzer
improvements then don't require recompiling 80 combos.

    PYTHONPATH=src python -m repro.launch.reanalyze
"""
from __future__ import annotations

import argparse
import json
import os

import zstandard

from repro.launch.hlo_analysis import analyze


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo-dir", default="experiments/hlo")
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    args = ap.parse_args()

    n = 0
    for f in sorted(os.listdir(args.hlo_dir)):
        if not f.endswith(".hlo.zst"):
            continue
        base = f[: -len(".hlo.zst")]
        jpath = os.path.join(args.dryrun_dir, base + ".json")
        if not os.path.exists(jpath):
            print(f"skip {base}: no JSON record")
            continue
        txt = zstandard.ZstdDecompressor().decompress(
            open(os.path.join(args.hlo_dir, f), "rb").read()
        ).decode()
        acc = analyze(txt)
        rec = json.load(open(jpath))
        rec.update(
            flops_per_device=float(acc["dot_flops"]),
            bytes_per_device=float(acc["traffic_bytes"]),
            bytes_per_device_bf16eq=float(acc["traffic_bytes_bf16eq"]),
            collectives=acc["collectives"],
            collective_bytes=float(acc["collective_bytes_total"]),
            collective_bytes_bf16eq=float(acc["collective_bytes_bf16eq"]),
            while_trips=acc["while_trips"],
            unknown_trip_whiles=acc["unknown_trip_whiles"],
        )
        with open(jpath, "w") as fo:
            json.dump(rec, fo, indent=2)
        n += 1
    print(f"re-analyzed {n} records")


if __name__ == "__main__":
    main()
