"""Regional selection engine (core.engine's ``delta_mig`` mode + the
streamed device-side prediction prep).

Pins, per the engine's contracts:
  * R == 1 regional runs are BITWISE-identical to the single-region
    engine on the squeezed inputs (weights, trajectories, mean utility);
  * region ``job_chunk`` streaming is bitwise-equal to the unchunked run
    across chunk sizes 1 / dividing / == K / non-dividing / > K;
  * a ``prep=`` callable produces the exact run the pre-built arrays do
    (the double-buffered staging changes scheduling, not values);
  * ``prepare_noisy_inputs_regions``'s numpy path is bitwise-equal to the
    per-job ``RegionalPredictor`` constructions it replaces (seed
    convention ``seeds[k] * 1009 + r``);
  * ``prep_backend="jax"`` (the jitted batched-PRNG device prep) agrees
    with the numpy oracle on the WINNER and on the regret ratio — the
    draws come from a different PRNG, so parity is decision-level, not
    bitwise — and collapses to the exact true future at level 0;
  * ``collect=True`` regional engine runs carry ``tel_region`` /
    ``tel_migration`` whose ledger reconciliation holds, and an armed
    never-firing fallback monitor leaves every shared leaf bitwise.
"""
import numpy as np

from benchmarks.common import PAPER_TPUT, job_stream_arrays
from repro.chaos import FallbackConfig
from repro.core import engine, fast_sim
from repro.core import selector as sel
from repro.core.policy_pool import (
    baseline_specs,
    paper_pool,
    rand_deadline_pool,
    region_pool,
    specs_to_arrays,
)
from repro.core.predictor import NoisyPredictor, RegionalPredictor
from repro.core.region_market import vast_like_regions
from repro.obs import ledger

DEADLINE = 10
KIND, LEVEL, SEED = "fixed_uniform", 0.2, 7


def _workload(n_jobs: int, n_regions: int = 3, days: float = 2.0):
    market = vast_like_regions(n_regions, seed=13, days=days, delta_mig=1)
    rng = np.random.default_rng(SEED)
    jobs = job_stream_arrays(rng, n_jobs, DEADLINE)
    t0s = rng.integers(0, len(market) - DEADLINE - 1, size=n_jobs)
    seeds = SEED * 100003 + np.arange(n_jobs)
    return market, jobs, t0s, seeds


def _region_run(market, jobs, t0s, seeds, arrs, **kw):
    rp, ra, rpm = engine.prepare_noisy_inputs_regions(
        market, t0s, DEADLINE, KIND, LEVEL, seeds
    )
    return engine.simulate_and_select(
        arrs, jobs, PAPER_TPUT, rp, ra, rpm,
        delta_mig=market.delta_mig, **kw,
    )


def _assert_results_equal(a, b, bitwise_mean: bool = True):
    np.testing.assert_array_equal(np.asarray(a.state.weights),
                                  np.asarray(b.state.weights))
    np.testing.assert_array_equal(np.asarray(a.max_weight),
                                  np.asarray(b.max_weight))
    np.testing.assert_array_equal(np.asarray(a.regret), np.asarray(b.regret))
    if bitwise_mean:
        np.testing.assert_array_equal(a.mean_utility, b.mean_utility)
    else:
        np.testing.assert_allclose(a.mean_utility, b.mean_utility,
                                   rtol=1e-5, atol=1e-3)


def test_r1_engine_bitwise_matches_single_region():
    """The acceptance pin: with one region, the regional engine path
    (region scans + the shared normalize/EG legs) lands bitwise on the
    single-region engine's result. The regional prep seeds region 0 with
    ``seeds[k] * 1009``, so the single-region run uses those seeds and the
    forecast stacks are identical by construction."""
    market, jobs, t0s, seeds = _workload(10, n_regions=1)
    arrs = specs_to_arrays(paper_pool(omegas=(1, 3), sigmas=(0.3,))
                           + rand_deadline_pool((0.2,)) + baseline_specs())
    p, a, m = engine.prepare_noisy_inputs(
        market.region(0), t0s, DEADLINE, KIND, LEVEL, seeds * 1009
    )
    rp, ra, rpm = engine.prepare_noisy_inputs_regions(
        market, t0s, DEADLINE, KIND, LEVEL, seeds
    )
    np.testing.assert_array_equal(rp[:, 0], p)
    np.testing.assert_array_equal(ra[:, 0], a)
    np.testing.assert_array_equal(rpm[:, 0], m)
    single = engine.simulate_and_select(arrs, jobs, PAPER_TPUT, p, a, m)
    regional = engine.simulate_and_select(
        arrs, jobs, PAPER_TPUT, rp, ra, rpm, delta_mig=market.delta_mig
    )
    _assert_results_equal(single, regional)
    assert single.best_policy() == regional.best_policy()


def test_region_engine_chunked_equals_unchunked():
    """Streaming the job axis through the region path must not change the
    selection: trajectories and final weights bitwise, for chunk sizes
    1 / dividing / == K / non-dividing / > K."""
    market, jobs, t0s, seeds = _workload(12)
    arrs = specs_to_arrays(region_pool())
    base = _region_run(market, jobs, t0s, seeds, arrs)
    for chunk in (1, 3, 4, 5, 12, 20):
        out = _region_run(market, jobs, t0s, seeds, arrs, job_chunk=chunk)
        _assert_results_equal(base, out, bitwise_mean=False)


def test_region_engine_prep_callable_matches_arrays():
    """``prep=`` streaming (the double-buffered path) must produce the
    same chunk inputs the pre-built arrays slice to — results bitwise."""
    market, jobs, t0s, seeds = _workload(12)
    arrs = specs_to_arrays(region_pool())
    base = _region_run(market, jobs, t0s, seeds, arrs, job_chunk=5)
    prep = lambda lo, hi: engine.prepare_noisy_inputs_regions(
        market, t0s[lo:hi], DEADLINE, KIND, LEVEL, seeds[lo:hi]
    )
    streamed = engine.simulate_and_select(
        arrs, jobs, PAPER_TPUT, None, None, None,
        delta_mig=market.delta_mig, job_chunk=5, prep=prep,
    )
    _assert_results_equal(base, streamed)


def test_prepare_noisy_inputs_regions_matches_per_job_constructions():
    """The batched numpy prep row (k, r) is bitwise the per-job
    ``RegionalPredictor(market.window(t0), lambda tr, r:
    NoisyPredictor(tr, ..., seed=seeds[k]*1009+r))`` construction it
    replaced in the host loop."""
    market, _, t0s, seeds = _workload(6)
    rp, ra, rpm = engine.prepare_noisy_inputs_regions(
        market, t0s, DEADLINE, KIND, LEVEL, seeds
    )
    for k, (t0, s) in enumerate(zip(t0s, seeds)):
        w = market.window(int(t0), DEADLINE + 1)
        np.testing.assert_array_equal(
            rp[k], w.prices[:, :DEADLINE].astype(np.float32))
        np.testing.assert_array_equal(
            ra[k], w.avail[:, :DEADLINE].astype(np.int64))
        want = RegionalPredictor(
            w, lambda tr, r, s=s: NoisyPredictor(
                tr, KIND, LEVEL, seed=int(s) * 1009 + r)
        ).matrix(fast_sim.W1MAX - 1)[:, :DEADLINE].astype(np.float32)
        np.testing.assert_array_equal(rpm[k], want, err_msg=f"job {k}")


def test_jax_prep_zero_level_is_exact_truth():
    """At level 0 the jitted device prep has nothing to draw: its stack
    must equal the numpy oracle's (the edge-padded true future) exactly."""
    market, _, t0s, seeds = _workload(4)
    np_prep = engine.prepare_noisy_inputs_regions(
        market, t0s, DEADLINE, KIND, 0.0, seeds, prep_backend="numpy"
    )
    jx_prep = engine.prepare_noisy_inputs_regions(
        market, t0s, DEADLINE, KIND, 0.0, seeds, prep_backend="jax"
    )
    for a, b in zip(np_prep, jx_prep):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_jax_prep_winner_and_regret_parity():
    """``prep_backend="jax"`` draws from JAX's counter-based PRNG — not
    bitwise vs the numpy Philox oracle — so the pin is decision-level:
    same winning lane, regret ratio within a tight band, both under the
    Theorem 2 bound."""
    market, jobs, t0s, seeds = _workload(12)
    arrs = specs_to_arrays(region_pool())
    results = {}
    for backend in ("numpy", "jax"):
        rp, ra, rpm = engine.prepare_noisy_inputs_regions(
            market, t0s, DEADLINE, KIND, LEVEL, seeds, prep_backend=backend
        )
        results[backend] = engine.simulate_and_select(
            arrs, jobs, PAPER_TPUT, rp, ra, rpm, delta_mig=market.delta_mig
        )
    assert results["numpy"].best_policy() == results["jax"].best_policy()
    rr_np = results["numpy"].regret_ratio()
    rr_jx = results["jax"].regret_ratio()
    assert abs(rr_np - rr_jx) < 0.05, (rr_np, rr_jx)
    assert rr_np < 1.0 and rr_jx < 1.0


def test_region_engine_collect_reconciles_and_fallback_is_inert():
    """``collect=True`` through the regional engine: the chunk-concatenated
    ``sim_out`` carries the migration series, whose ledger reconciliation
    (slot sums == ``migrations`` leaves, ``tel_region`` == ``region``)
    must hold across chunk boundaries; an armed monitor whose threshold is
    never crossed leaves every shared leaf bitwise-identical and adds the
    all-quiet ``tel_fallback`` series."""
    market, jobs, t0s, seeds = _workload(8)
    arrs = specs_to_arrays(region_pool())
    base = _region_run(market, jobs, t0s, seeds, arrs, job_chunk=3)
    res = _region_run(market, jobs, t0s, seeds, arrs, job_chunk=3,
                      collect=True)
    _assert_results_equal(base, res)
    assert base.sim_out is None and res.sim_out is not None
    assert res.entropy is not None and res.top_policy is not None
    recon = ledger.migration_reconciliation(res.sim_out)
    assert recon["events_reconciled"], recon
    assert recon["series_matches_leaf"], recon
    # huge threshold: the monitor is armed but never trips — the AHANP
    # override is never selected, so the program's outputs are unchanged
    quiet = _region_run(market, jobs, t0s, seeds, arrs, job_chunk=3,
                        collect=True, fallback=FallbackConfig(threshold=1e9))
    _assert_results_equal(res, quiet)
    assert "tel_fallback" in quiet.sim_out
    assert not np.asarray(quiet.sim_out["tel_fallback"]).any()
    for k in res.sim_out:
        np.testing.assert_array_equal(
            np.asarray(res.sim_out[k]), np.asarray(quiet.sim_out[k]),
            err_msg=k,
        )
