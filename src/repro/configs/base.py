"""Config system: model, input-shape, training and scheduling configs.

Every assigned architecture gets a module in this package exporting
``config()`` (the full, paper-exact configuration) and ``smoke_config()``
(a reduced variant of the same family: <=2 layers, d_model<=512, <=4 experts)
used by CPU smoke tests. Full configs are only ever exercised through the
dry-run (ShapeDtypeStruct; no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

ARCH_TYPES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.02


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""

    state_size: int = 128        # N
    head_dim: int = 64           # P
    num_heads: int = 0           # derived if 0: d_inner // head_dim
    expand: int = 2              # d_inner = expand * d_model
    n_groups: int = 1            # B/C groups (like GQA for SSM)
    conv_width: int = 4
    chunk_size: int = 256        # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def heads(self, d_model: int) -> int:
        return self.num_heads or self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 32.0
    targets: Tuple[str, ...] = ("q", "v")  # subset of {"q","k","v","o","mlp"}
    dropout: float = 0.0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # one of ARCH_TYPES
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    num_kv_heads: int = 0               # 0 -> num_heads (MHA)
    head_dim: int = 0                   # 0 -> d_model // num_heads
    # --- attention details ---
    rope_theta: float = 10000.0
    m_rope: bool = False                # Qwen2-VL multimodal RoPE
    m_rope_sections: Tuple[int, int, int] = (16, 24, 24)  # t,h,w halves of head_dim/2
    qkv_bias: bool = False
    o_bias: bool = False
    sliding_window: Optional[int] = None  # SWA window (tokens); None = full attn
    causal: bool = True                 # False for encoder-only
    # --- norm / mlp ---
    norm_type: str = "rmsnorm"          # rmsnorm | layernorm | layernorm_np (OLMo non-parametric)
    norm_eps: float = 1e-5
    mlp_act: str = "silu"               # silu (SwiGLU) | gelu (plain 2-matrix MLP)
    mlp_bias: bool = False
    # --- embeddings ---
    tie_embeddings: bool = False
    # --- family-specific ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2-style): every `hybrid_period` SSM layers, apply the single
    # *shared* attention block. 0 = not hybrid.
    hybrid_period: int = 0
    # encoder-only (audio): no decode path, bidirectional attention
    encoder_only: bool = False
    # modality frontend stub: inputs are precomputed embeddings, not token ids
    embed_inputs: bool = False          # True -> input_specs gives (B,S,d_model) floats
    # --- fine-tuning ---
    lora: LoRAConfig = field(default_factory=LoRAConfig)
    # scan granularity: number of layers grouped per scan step (1 = plain scan)
    dtype: str = "bfloat16"
    # citation for the assigned config
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        assert self.arch_type in ARCH_TYPES, self.arch_type
        if self.num_kv_heads == 0:
            object.__setattr__(self, "num_kv_heads", self.num_heads)
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.hybrid_period:
            assert self.ssm is not None, "hybrid needs an SSMConfig"
            assert self.num_layers % self.hybrid_period == 0

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def is_sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context without a full-seq KV cache?"""
        return (
            self.arch_type in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    # --- parameter counting (used for checkpoint bytes / switching cost) ---
    def param_count(self) -> int:
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.embed_inputs:
            emb = V * d  # output head only; frontend is a stub
        attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        if self.qkv_bias:
            attn += (h + 2 * kv) * hd
        if self.mlp_act == "silu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        norm = 0 if self.norm_type == "layernorm_np" else 2 * d
        per_layer = 0
        if self.arch_type == "moe":
            assert self.moe is not None
            per_layer = attn + self.moe.num_experts * mlp + d * self.moe.num_experts + 2 * norm
            return emb + L * per_layer + norm
        if self.arch_type == "ssm":
            per_layer = self._ssm_params() + norm
            return emb + L * per_layer + norm
        if self.arch_type == "hybrid":
            n_shared = L // self.hybrid_period
            shared_attn = attn + 2 * norm + mlp  # one shared transformer block
            per_layer = self._ssm_params() + norm
            return emb + L * per_layer + shared_attn + norm
        per_layer = attn + mlp + 2 * norm
        return emb + L * per_layer + norm

    def _ssm_params(self) -> int:
        assert self.ssm is not None
        s, d = self.ssm, self.d_model
        di = s.d_inner(d)
        H = s.heads(d)
        conv_dim = di + 2 * s.n_groups * s.state_size
        in_proj = d * (2 * di + 2 * s.n_groups * s.state_size + H)
        return in_proj + conv_dim * s.conv_width + H * 2 + di + di * d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts experts)."""
        if self.arch_type != "moe":
            return self.param_count()
        assert self.moe is not None
        d, f, L = self.d_model, self.d_ff, self.num_layers
        mlp = 3 * d * f if self.mlp_act == "silu" else 2 * d * f
        dead = (self.moe.num_experts - self.moe.top_k) * mlp * L
        return self.param_count() - dead

    def flops_per_token(self) -> float:
        """Forward-pass matmul FLOPs per token (2*active_params, ignoring attn score term)."""
        return 2.0 * self.active_param_count()

    def lora_param_count(self) -> int:
        r = self.lora.rank
        d, hd = self.d_model, self.head_dim
        h, kv = self.num_heads, self.num_kv_heads
        n = 0
        per = {
            "q": d * r + r * h * hd,
            "k": d * r + r * kv * hd,
            "v": d * r + r * kv * hd,
            "o": h * hd * r + r * d,
        }
        for t in self.lora.targets:
            if t in per:
                n += per[t]
        L = self.num_layers
        if self.arch_type == "hybrid":
            L = self.num_layers // self.hybrid_period  # LoRA on the shared attn block
        if self.arch_type == "ssm":
            # no attention: LoRA applied to in/out projections instead
            assert self.ssm is not None
            di = self.ssm.d_inner(d)
            return self.num_layers * (d * r + r * di + di * r + r * d)
        return L * n

    def reduced(self, **overrides) -> "ModelConfig":
        """A reduced same-family variant for CPU smoke tests."""
        small = dict(
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=0,
            head_dim=0,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            name=self.name + "-smoke",
            dtype="float32",  # exact CPU numerics for smoke tests
        )
        if self.num_kv_heads < self.num_heads:
            small["num_kv_heads"] = max(1, min(self.num_kv_heads, small["num_heads"] // 2))
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 4)
            )
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(
                self.ssm,
                state_size=min(self.ssm.state_size, 16),
                head_dim=min(self.ssm.head_dim, 32),
                chunk_size=32,
            )
        if self.hybrid_period:
            small["num_layers"] = 2
            small["hybrid_period"] = 1
        if self.sliding_window is not None:
            small["sliding_window"] = min(self.sliding_window, 64)
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Spec'd skip rules. Returns (applicable, reason-if-not)."""
    if shape.mode == "decode" and not model.supports_decode:
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k" and not model.is_sub_quadratic:
        return False, "full-attention arch without SWA/block-sparse variant (see DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# Training / scheduling configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 1024
    global_batch: int = 32
    lr: float = 2e-4
    weight_decay: float = 0.0
    warmup_steps: int = 20
    total_steps: int = 200
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    remat: str = "none"  # none | full | dots  (activation checkpoint policy)
    # gradient accumulation: scan over microbatches inside train_step. Keeps
    # layer-scan carries (the dominant HBM term at 80 layers) ~1/microbatches
    # and is the same mechanism the elastic trainer uses to hold the global
    # batch fixed while the scheduler varies the instance count (paper §III-B).
    microbatches: int = 1


@dataclass(frozen=True)
class JobConfig:
    """The paper's four-tuple {L, d, N^min, N^max} plus value-function params."""

    workload: float = 80.0          # L
    deadline: int = 10              # d (slots)
    n_min: int = 1
    n_max: int = 12
    value: float = 40.0             # v
    gamma: float = 2.0              # hard deadline = gamma * d
    on_demand_price: float = 1.0    # p^o per instance-slot


@dataclass(frozen=True)
class ThroughputConfig:
    alpha: float = 1.0              # H(n) = alpha*n + beta (n>0)
    beta: float = 0.0
    mu1: float = 0.9                # scale-up effective fraction
    mu2: float = 0.95               # scale-down effective fraction
