"""The power of prediction (paper Sec. II-C), quantified end-to-end:
the SAME AHAP policy driven by perfect / ARIMA / noisy / garbage forecasts,
vs the offline optimum and the non-predictive AHANP.

This closes the paper's motivating loop: forecast quality (Fig. 3) ->
scheduling utility (Fig. 4/5). Derived values are mean utilities; the
interesting number is how much of the (OPT - AHANP) gap ARIMA recovers.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_JOB, PAPER_TPUT, paper_market, timed
from repro.core.offline_opt import solve_offline
from repro.core.policies import AHANP, AHANPParams, AHAP, AHAPParams
from repro.core.predictor import ARIMAPredictor, NoisyPredictor, PerfectPredictor
from repro.core.simulator import simulate

N_WINDOWS = 32


def run() -> list:
    market = paper_market(seed=19, days=24)
    rng = np.random.default_rng(5)
    warm = 10 * 48  # ARIMA history
    t0s = [int(rng.integers(warm, len(market) - 12)) for _ in range(N_WINDOWS)]

    def eval_pred(make_matrix) -> float:
        us = []
        for i, t0 in enumerate(t0s):
            w = market.window(t0, PAPER_JOB.deadline + 1)
            pred = make_matrix(i, t0, w)
            pol = AHAP(AHAPParams(3, 1, 0.7)) if pred is not None else AHANP(AHANPParams(0.7))
            us.append(simulate(pol, PAPER_JOB, PAPER_TPUT, w, pred).utility)
        return float(np.mean(us))

    rows = []
    u_perfect, us = timed(eval_pred, lambda i, t0, w: PerfectPredictor(w).matrix(5))
    rows.append(("predval_perfect", us, u_perfect))

    def arima_matrix(i, t0, w):
        hist = market.window(0, t0 + PAPER_JOB.deadline + 1)
        return ARIMAPredictor(hist).matrix(5)[t0 : t0 + PAPER_JOB.deadline]

    u_arima, us = timed(eval_pred, arima_matrix)
    rows.append(("predval_arima", us, u_arima))
    u_noisy, us = timed(
        eval_pred, lambda i, t0, w: NoisyPredictor(w, "fixed_uniform", 0.3, seed=i).matrix(5)
    )
    rows.append(("predval_noisy30", us, u_noisy))
    u_garbage, us = timed(
        eval_pred, lambda i, t0, w: NoisyPredictor(w, "fixed_heavytail", 2.0, seed=i).matrix(5)
    )
    rows.append(("predval_garbage200", us, u_garbage))
    u_ahanp, us = timed(eval_pred, lambda i, t0, w: None)
    rows.append(("predval_ahanp_nopred", us, u_ahanp))

    u_opt = float(np.mean([
        solve_offline(PAPER_JOB, PAPER_TPUT, market.window(t0, PAPER_JOB.deadline + 1)).utility
        for t0 in t0s
    ]))
    rows.append(("predval_offline_opt", 0.0, u_opt))

    # how much of the (OPT - AHANP) headroom does each forecast recover?
    denom = max(u_opt - u_ahanp, 1e-9)
    for name, u in [("perfect", u_perfect), ("arima", u_arima),
                    ("noisy30", u_noisy), ("garbage200", u_garbage)]:
        rows.append((f"predval_{name}_headroom_recovered", 0.0,
                     (u - u_ahanp) / denom))
    rows.append(("predval_ordering_ok", 0.0, float(
        u_opt + 1e-6 >= u_perfect >= u_arima - 1.0 and u_perfect >= u_garbage - 1e-9
    )))
    return rows
