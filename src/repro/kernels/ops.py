"""jit'd public wrappers around the Pallas kernels.

On TPU these dispatch the compiled kernels; on CPU (this container) they run
interpret=True so tests exercise the real kernel bodies. The XLA model path
(repro.models.*) is the default in the dry-run because Pallas TPU kernels
cannot lower on the CPU backend (DESIGN.md §4); on real hardware the model
can route its hot spots here via ``KernelConfig``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.lora_matmul import lora_matmul as _lora
from repro.kernels.ssd_scan import ssd_scan as _ssd


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclass(frozen=True)
class KernelConfig:
    use_pallas: bool = True
    interpret: bool = False      # forced True off-TPU

    def resolved_interpret(self) -> bool:
        return self.interpret or not on_tpu()


DEFAULT = KernelConfig()


@functools.partial(jax.jit, static_argnames=("scale", "kcfg"))
def lora_matmul(x, w, a, b, scale: float, kcfg: KernelConfig = DEFAULT):
    """y = x @ W + scale * (x@A)@B. x:(..., K) flattened to 2-D internally."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if kcfg.use_pallas:
        y = _lora(x2, w, a, b, scale, interpret=kcfg.resolved_interpret())
    else:
        y = ref.lora_matmul_ref(x2, w, a, b, scale)
    return y.reshape(*lead, w.shape[-1])


@functools.partial(jax.jit, static_argnames=("causal", "window", "kcfg"))
def attention(q, k, v, *, causal=True, window=None, kcfg: KernelConfig = DEFAULT):
    """q:(B,Sq,H,D), k/v:(B,Sk,KV,D) with GQA -> (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    if kcfg.use_pallas:
        o = _flash(qt, kt, vt, causal=causal, window=window,
                   interpret=kcfg.resolved_interpret())
    else:
        sk = kt.shape[1]
        o = ref.flash_attention_ref(
            qt.reshape(b, h, sq, d), kt.reshape(b, h, sk, d),
            vt.reshape(b, h, sk, d), causal=causal, window=window,
        ).reshape(b * h, sq, d)
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "kcfg"))
def ssd(x, dt, A, B, C, *, chunk=128, kcfg: KernelConfig = DEFAULT):
    """Grouped-head SSD: x:(B,S,H,P), dt:(B,S,H), A:(H,), B/C:(B,S,G,N).

    Returns (y:(B,S,H,P), state:(B,H,N,P))."""
    bsz, s, hh, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = hh // g
    Bh = jnp.repeat(B, rep, axis=2) if g != hh else B
    Ch = jnp.repeat(C, rep, axis=2) if g != hh else C
    xf = x.transpose(0, 2, 1, 3).reshape(bsz * hh, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * hh, s)
    Af = jnp.tile(A, bsz)
    Bf = Bh.transpose(0, 2, 1, 3).reshape(bsz * hh, s, n)
    Cf = Ch.transpose(0, 2, 1, 3).reshape(bsz * hh, s, n)
    if kcfg.use_pallas:
        y, hf = _ssd(xf, dtf, Af, Bf, Cf, chunk=chunk,
                     interpret=kcfg.resolved_interpret())
    else:
        y, hf = ref.ssd_scan_ref(xf, dtf, Af, Bf, Cf)
    y = y.reshape(bsz, hh, s, p).transpose(0, 2, 1, 3)
    return y, hf.reshape(bsz, hh, n, p)
