"""HLO accounting unit tests + subprocess mini dry-run (8 forced devices).

The full 512-device production dry-run is exercised via
``python -m repro.launch.dryrun`` (EXPERIMENTS.md §Dry-run); here we prove
the machinery end-to-end at test-friendly scale.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, type_bytes

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_simple_matmul():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    acc = analyze(_hlo(lambda x, y: x @ y, a, b))
    assert acc["dot_flops"] == pytest.approx(2 * 128 * 256 * 64, rel=0.01)


def test_scan_trip_count_multiplies():
    a = jnp.zeros((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ c * 0.5, None

        out, _ = jax.lax.scan(body, x, None, length=9)
        return out

    acc = analyze(_hlo(f, a))
    assert 9 in acc["while_trips"]
    assert acc["dot_flops"] == pytest.approx(9 * 2 * 64**3, rel=0.05)


def test_type_bytes():
    assert type_bytes("f32[4,8]{1,0}") == 128
    assert type_bytes("bf16[10]") == 20
    assert type_bytes("(f32[2]{0}, s32[3]{0})") == 20
    assert type_bytes("f32[4,8]{1,0}", f32_as=2) == 64
    assert type_bytes("pred[]") == 1


def test_traffic_counts_something():
    a = jnp.zeros((256, 256), jnp.float32)
    acc = analyze(_hlo(lambda x: jax.nn.relu(x @ x), a))
    assert acc["traffic_bytes"] >= 3 * 256 * 256 * 4  # two reads + one write


@pytest.mark.slow
def test_subprocess_mini_dryrun(tmp_path):
    """Real dry-run flow on a 2x2(x2) mesh with 8 forced host devices."""
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = str(tmp_path / "dryrun")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", "mixtral-8x7b", "--shape", "train_4k", "--mesh", "both",
         "--out", out],
        env=env, capture_output=True, text=True, timeout=420,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    recs = [json.load(open(os.path.join(out, f))) for f in sorted(os.listdir(out))]
    assert len(recs) == 2
    for r in recs:
        assert r["status"] == "ok", r
        assert r["flops_per_device"] > 0
        assert r["collective_bytes"] > 0
        assert r["while_trips"], r


@pytest.mark.slow
def test_subprocess_mini_dryrun_decode_and_skip(tmp_path):
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = str(tmp_path / "dryrun2")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", "hubert-xlarge", "--shape", "all", "--mesh", "single",
         "--out", out],
        env=env, capture_output=True, text=True, timeout=420,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    recs = {f: json.load(open(os.path.join(out, f))) for f in os.listdir(out)}
    by_shape = {r["shape"]: r for r in recs.values()}
    assert by_shape["train_4k"]["status"] == "ok"
    assert by_shape["decode_32k"]["status"] == "skipped"   # encoder-only
    assert by_shape["long_500k"]["status"] == "skipped"
