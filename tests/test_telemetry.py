"""Flight recorder (repro.obs) pins.

Two families of guarantees:

* **collect=False is the shipped program.** The telemetry flag is static
  and every if-collect branch only ADDS scan outputs, so a default run
  must be BITWISE-equal to the pre-telemetry build — pinned here for
  ``simulate_pool`` / ``simulate_pool_jobs`` / ``simulate_fleet`` /
  ``simulate_and_select`` (the 4-device sharded twins are pinned in
  tests/test_sharded_pool.py and tests/test_fleet.py subprocesses).

* **collect=True telemetry is self-consistent.** The per-slot cost split
  reconciles with the engine's reported cost/utility totals (f32
  tolerance, residuals carried in the ledger); reconfiguration events
  replay exactly from the allocation histories on host; waterfall grants
  never oversubscribe the supply and the demander rank is a valid
  permutation prefix; the EG entropy/top-policy traces match a host
  recomputation from the weight history. Ledgers JSON-round-trip and the
  report renders every kind.
"""
import json

import numpy as np
import pytest
from _hyp_compat import given, settings, st

from benchmarks.common import (PAPER_TPUT, job_stream, job_stream_arrays,
                               paper_market)
from repro.configs.base import ThroughputConfig
from repro.core import engine, fast_sim, fleet
from repro.core import selector as sel
from repro.core.market import vast_like_trace
from repro.core.policy_pool import (
    baseline_specs,
    paper_pool,
    rand_deadline_pool,
    specs_to_arrays,
)
from repro.core.predictor import NoisyPredictor
from repro.obs import (
    SLOT_KEYS,
    fleet_ledger,
    frame_from_out,
    grid_ledger,
    has_telemetry,
    pool_ledger,
    render,
    selection_ledger,
)

TPUT = ThroughputConfig(mu1=0.9, mu2=0.95)
D = 10


def _pool_setup(n_jobs=5, seed=3):
    pool = (paper_pool(omegas=(2,), sigmas=(0.5,))
            + rand_deadline_pool((0.4,)) + baseline_specs())
    arrs = specs_to_arrays(pool)
    rng = np.random.default_rng(seed)
    jobs = fast_sim.stack_jobs(list(job_stream(rng, n_jobs, deadline=D)))
    traces = [vast_like_trace(seed=60 + i, days=1).window(0, D + 1)
              for i in range(n_jobs)]
    prices = np.stack([t.prices[:D] for t in traces]).astype(np.float32)
    avail = np.stack([t.avail[:D] for t in traces]).astype(np.int64)
    preds = np.stack([
        NoisyPredictor(t, "fixed_uniform", 0.2, seed=i).matrix(
            fast_sim.W1MAX - 1)[:D]
        for i, t in enumerate(traces)
    ]).astype(np.float32)
    return pool, arrs, jobs, prices, avail, preds


def _fleet_setup(J=12, T=24, seed=7):
    pool = (paper_pool(omegas=(2,), sigmas=(0.5,))
            + rand_deadline_pool((0.4,)) + baseline_specs())
    arrs = specs_to_arrays(pool)
    rng = np.random.default_rng(seed)
    tr = vast_like_trace(seed=5, days=2).window(0, T + 1)
    prices = tr.prices[:T].astype(np.float32)
    avail = tr.avail[:T].astype(np.int64)
    pred = NoisyPredictor(tr, "fixed_uniform", 0.2, seed=3).matrix(
        fast_sim.W1MAX - 1)[:T].astype(np.float32)
    jobs = fast_sim.stack_jobs(list(job_stream(rng, J, deadline=D)))
    arrivals = rng.integers(0, 8, size=J)
    idx = rng.integers(0, len(pool), size=J)
    rows = {k: np.asarray(arrs[k])[idx]
            for k in ("kind", "omega", "v", "sigma", "rho", "cfrac")}
    return jobs, arrivals, rows, prices, avail, pred


def _replay_events(n_od, n_spot, active, grant=None):
    """Host oracle for the reconfiguration-event series: replay the
    ``n_prev`` carry of ``fast_sim._execute`` (updates only on active
    slots, starts at 0) over the recorded allocation histories."""
    n_od = np.asarray(n_od)
    T = n_od.shape[-1]
    n_prev = np.zeros(n_od.shape[:-1], np.int64)
    up = np.zeros_like(n_od, bool)
    down = np.zeros_like(n_od, bool)
    preempt = np.zeros_like(n_od, bool)
    for t in range(T):
        act = np.asarray(active[..., t], bool)
        n = np.asarray(n_od[..., t] + n_spot[..., t], np.int64)
        up[..., t] = act & (n > n_prev)
        down[..., t] = act & (n < n_prev)
        if grant is not None:
            preempt[..., t] = down[..., t] & (
                np.asarray(grant[..., t], np.int64) < n_prev)
        n_prev = np.where(act, n, n_prev)
    return up, down, preempt


# ---------------------------------------------------------------------------
# collect=False is bitwise the shipped program; collect=True only adds keys
# ---------------------------------------------------------------------------

def test_pool_jobs_collect_false_bitwise():
    _, arrs, jobs, prices, avail, preds = _pool_setup()
    base = fast_sim.simulate_pool_jobs(arrs, jobs, TPUT, prices, avail, preds)
    tel = fast_sim.simulate_pool_jobs(arrs, jobs, TPUT, prices, avail, preds,
                                      collect=True)
    assert not has_telemetry(base)
    assert not any(k.startswith("tel_") for k in base)
    assert has_telemetry(tel)
    assert set(tel) - set(base) == set(SLOT_KEYS)
    for k in base:
        np.testing.assert_array_equal(np.asarray(base[k]), np.asarray(tel[k]),
                                      err_msg=k)


def test_pool_single_job_collect_false_bitwise():
    _, arrs, jobs, prices, avail, preds = _pool_setup(n_jobs=1)
    j1 = fast_sim.slice_jobs(jobs, 0, 1)
    base = fast_sim.simulate_pool_jobs(arrs, j1, TPUT, prices, avail, preds)
    tel = fast_sim.simulate_pool_jobs(arrs, j1, TPUT, prices, avail, preds,
                                      collect=True)
    for k in base:
        np.testing.assert_array_equal(np.asarray(base[k]), np.asarray(tel[k]),
                                      err_msg=k)
    fr = frame_from_out(tel)
    assert fr.spot_cost.shape == fr.active.shape
    assert fr.demand is None  # waterfall series are fleet-only


def test_fleet_collect_false_bitwise():
    jobs, arrivals, rows, prices, avail, pred = _fleet_setup()
    base = fleet.simulate_fleet(rows, jobs, arrivals, TPUT, prices, avail,
                                pred)
    tel = fleet.simulate_fleet(rows, jobs, arrivals, TPUT, prices, avail,
                               pred, collect=True)
    assert not any(k.startswith("tel_") for k in base)
    assert set(tel) - set(base) == set(SLOT_KEYS) | {
        "tel_demand", "tel_grant", "tel_slack", "tel_rank", "tel_starved"}
    for k in base:
        np.testing.assert_array_equal(np.asarray(base[k]), np.asarray(tel[k]),
                                      err_msg=k)


def test_engine_collect_false_bitwise_and_chunked():
    _, arrs, jobs, prices, avail, preds = _pool_setup(n_jobs=6)
    kw = dict(sharded=False)
    base = engine.simulate_and_select(arrs, jobs, PAPER_TPUT, prices, avail,
                                      preds, **kw)
    tel = engine.simulate_and_select(arrs, jobs, PAPER_TPUT, prices, avail,
                                     preds, collect=True, **kw)
    np.testing.assert_array_equal(base.max_weight, tel.max_weight)
    np.testing.assert_array_equal(base.regret, tel.regret)
    np.testing.assert_array_equal(np.asarray(base.state.weights),
                                  np.asarray(tel.state.weights))
    assert base.entropy is None and base.sim_out is None
    assert tel.entropy.shape == tel.top_policy.shape == (6,)
    assert has_telemetry(tel.sim_out)
    # chunked collect: sim_out concatenates along jobs, trajectories bitwise
    tel_c = engine.simulate_and_select(arrs, jobs, PAPER_TPUT, prices, avail,
                                       preds, collect=True, job_chunk=2, **kw)
    np.testing.assert_array_equal(tel.entropy, tel_c.entropy)
    np.testing.assert_array_equal(tel.top_policy, tel_c.top_policy)
    for k in tel.sim_out:
        np.testing.assert_array_equal(np.asarray(tel.sim_out[k]),
                                      np.asarray(tel_c.sim_out[k]),
                                      err_msg=k)


def test_eg_scan_collect_parity_and_entropy():
    rng = np.random.default_rng(2)
    u = rng.uniform(0, 1, (40, 8)).astype(np.float32)
    st0 = sel.eg_init(8, 40)
    stA, trajA = sel.run_eg_scan(st0, u)
    stB, trajB = sel.run_eg_scan(st0, u, collect=True, track_history=True)
    np.testing.assert_array_equal(np.asarray(trajA["max_weight"]),
                                  np.asarray(trajB["max_weight"]))
    np.testing.assert_array_equal(np.asarray(trajA["regret"]),
                                  np.asarray(trajB["regret"]))
    np.testing.assert_array_equal(np.asarray(stA.weights),
                                  np.asarray(stB.weights))
    w = np.asarray(trajB["weights"], np.float64)           # (K, M)
    ent_ref = -(w * np.log(np.maximum(w, 1e-300))).sum(axis=1)
    np.testing.assert_allclose(np.asarray(trajB["entropy"]), ent_ref,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(trajB["top_policy"]),
                                  w.argmax(axis=1))


# ---------------------------------------------------------------------------
# collect=True invariants
# ---------------------------------------------------------------------------

def test_pool_telemetry_invariants():
    _, arrs, jobs, prices, avail, preds = _pool_setup()
    tel = fast_sim.simulate_pool_jobs(arrs, jobs, TPUT, prices, avail, preds,
                                      collect=True)
    fr = frame_from_out(tel)
    act = fr.active.astype(bool)
    # cost split: per-slot billing on active slots only, prices broadcast
    # (J, 1, T) over lanes
    np.testing.assert_allclose(
        fr.spot_cost,
        np.where(act, fr.n_spot * prices[:, None, :], 0.0), rtol=1e-6)
    p_o = np.asarray(jobs.p_o)[:, None, None]
    np.testing.assert_allclose(
        fr.od_cost, np.where(act, fr.n_od * p_o, 0.0), rtol=1e-6)
    # events replay exactly from the allocation histories
    up, down, _ = _replay_events(fr.n_od, fr.n_spot, act)
    np.testing.assert_array_equal(fr.reconfig_up.astype(bool), up)
    np.testing.assert_array_equal(fr.reconfig_down.astype(bool), down)
    # preempt is a supply-forced shrink: a subset of down, never on up
    pre = fr.preempted.astype(bool)
    assert not np.any(pre & ~down)
    # progress (cumulative work) is monotone and ends at z_ddl
    assert np.all(np.diff(fr.progress, axis=-1) >= -1e-5)
    np.testing.assert_allclose(fr.progress[..., -1],
                               np.asarray(tel["z_ddl"]), atol=1e-5)


def test_fleet_telemetry_invariants():
    jobs, arrivals, rows, prices, avail, pred = _fleet_setup()
    T = prices.shape[0]
    tel = fleet.simulate_fleet(rows, jobs, arrivals, TPUT, prices, avail,
                               pred, collect=True)
    fr = frame_from_out(tel)
    # waterfall conservation: per-slot total grants never exceed supply
    assert np.all(fr.grant.sum(axis=0) <= avail)
    # grants only to demanders; starved implies demanded-but-shorted
    assert np.all((fr.grant > 0) <= (fr.demand > 0))
    starved = fr.starved.astype(bool)
    assert not np.any(starved & ~((fr.demand > 0) & (fr.grant < fr.demand)))
    # demander rank: a valid permutation prefix each slot, -1 elsewhere
    for t in range(T):
        d = fr.demand[:, t] > 0
        r = fr.waterfall_rank[:, t]
        assert np.all(r[~d] == -1)
        assert sorted(r[d]) == list(range(int(d.sum())))
    # events replay exactly, including grant-forced preemptions
    act = fr.active.astype(bool)
    up, down, pre = _replay_events(fr.n_od, fr.n_spot, act, grant=fr.grant)
    np.testing.assert_array_equal(fr.reconfig_up.astype(bool), up)
    np.testing.assert_array_equal(fr.reconfig_down.astype(bool), down)
    np.testing.assert_array_equal(fr.preempted.astype(bool), pre)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), mu1=st.floats(0.5, 1.0),
       mu2=st.floats(0.5, 1.0))
def test_cost_reconciliation_property(seed, mu1, mu2):
    """The ledger's cost decomposition (spot + od + termination) reconciles
    with the engine's reported cost and utility to f32 tolerance, across
    random jobs, markets and reconfiguration penalties."""
    tput = ThroughputConfig(mu1=min(mu1, mu2), mu2=max(mu1, mu2))
    pool = (paper_pool(omegas=(2,), sigmas=(0.5,)) + baseline_specs())
    arrs = specs_to_arrays(pool)
    rng = np.random.default_rng(seed)
    jobs = job_stream_arrays(rng, 4, deadline=D)
    traces = [vast_like_trace(seed=seed + i, days=1).window(0, D + 1)
              for i in range(4)]
    prices = np.stack([t.prices[:D] for t in traces]).astype(np.float32)
    avail = np.stack([t.avail[:D] for t in traces]).astype(np.int64)
    preds = np.stack([
        NoisyPredictor(t, "fixed_uniform", 0.2, seed=i).matrix(
            fast_sim.W1MAX - 1)[:D]
        for i, t in enumerate(traces)
    ]).astype(np.float32)
    tel = fast_sim.simulate_pool_jobs(arrs, jobs, tput, prices, avail, preds,
                                      collect=True)
    led = pool_ledger(tel, jobs, tput)
    rc = led["cost_reconciliation"]
    assert rc["max_abs_cost_residual"] < 1e-3, rc
    assert rc["max_abs_utility_residual"] < 1e-3, rc


# ---------------------------------------------------------------------------
# ledgers + report
# ---------------------------------------------------------------------------

def test_ledgers_json_roundtrip_and_render():
    pool, arrs, jobs, prices, avail, preds = _pool_setup(n_jobs=4)
    res = engine.simulate_and_select(arrs, jobs, PAPER_TPUT, prices, avail,
                                     preds, sharded=False, collect=True,
                                     return_utilities=True)
    names = [p.name for p in pool]

    pl = pool_ledger(res.sim_out, jobs, PAPER_TPUT, lane_names=names)
    slc = selection_ledger(res)
    meta = [{"key": "r0", "avail_mean": 5.5, "noise": 0.2}]
    gl = grid_ledger(meta, np.asarray(res.utilities)[None], res.sim_out,
                     jobs, [PAPER_TPUT], 4, lane_names=names)

    fjobs, arrivals, rows, fprices, favail, fpred = _fleet_setup(J=6, T=16)
    ftel = fleet.simulate_fleet(rows, fjobs, arrivals, TPUT, fprices, favail,
                                fpred, collect=True)
    fl = fleet_ledger(ftel, fjobs, TPUT, supply=favail)

    for led in (pl, slc, gl, fl):
        back = json.loads(json.dumps(led))
        assert back == led
        text = render(led)
        assert text.count("\n") >= 2 and led["kind"] in ("pool", "fleet",
                                                         "selection",
                                                         "scenario_grid")
    assert fl["waterfall"]["max_oversubscription"] <= 0
    assert slc["entropy_final"] <= slc["entropy_uniform"] + 1e-6
    with pytest.raises(ValueError):
        render({"kind": "nope"})
    with pytest.raises(KeyError):
        frame_from_out({"n_od": np.zeros((1, 1))})
