"""Fig. 9: convergence of online policy selection under the four prediction
noise regimes, plus the fixed-hyperparameter ablation pools (fixed v=1 /
fixed sigma=0.9).

1000 jobs per setting (paper's count), workloads U[70,120], deadline 10,
Nmin in [1,4], Nmax in [12,16]. Each setting is ONE
``engine.simulate_and_select`` call: batched prep (vectorized window gather
+ one noisy forecast stack), the sharded pool simulation of the whole
112-policy x 1000-job grid, and the jitted EG scan — the (K, M) utility
matrix never visits host numpy (pre-engine, prep + normalization + the
selector update ran as per-job python loops)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_TPUT, job_stream_arrays, paper_market, timed
from repro.core import engine
from repro.core.policy_pool import paper_pool, specs_to_arrays

N_JOBS = 1000


def _engine_inputs(kind: str, level: float, n_jobs: int, seed: int):
    """The Fig. 9 workload, fully batched: vectorized job draws, one
    window-gather over the market, one noisy forecast stack (per-job
    predictor seeds stay ``seed * 100003 + k``)."""
    rng = np.random.default_rng(seed)
    trace = paper_market(seed=21, days=40)
    jobs = job_stream_arrays(rng, n_jobs)
    d = int(np.asarray(jobs.deadline)[0])
    t0s = rng.integers(0, len(trace) - d - 1, size=n_jobs)
    seeds = seed * 100003 + np.arange(n_jobs)
    prices, avail, preds = engine.prepare_noisy_inputs(
        trace, t0s, d, kind, level, seeds
    )
    return jobs, prices, avail, preds


def _run_setting(pool_specs, kind: str, level: float, n_jobs: int, seed: int,
                 **engine_kw) -> engine.SelectionResult:
    jobs, prices, avail, preds = _engine_inputs(kind, level, n_jobs, seed)
    return engine.simulate_and_select(
        specs_to_arrays(pool_specs), jobs, PAPER_TPUT, prices, avail, preds,
        **engine_kw,
    )


def run() -> list:
    rows = []
    settings = [
        ("magdep_uniform", 0.1),
        ("fixed_uniform", 0.1),
        ("magdep_heavytail", 0.3),
        ("fixed_heavytail", 0.3),
    ]
    pool = paper_pool()
    winners = {}
    for kind, level in settings:
        res, us = timed(_run_setting, pool, kind, level, N_JOBS, seed=7)
        best, t_half = res.best_policy(), res.iters_to_half()
        winners[(kind, level)] = best
        rows.append((f"fig9_{kind}_{level:g}_best_policy_idx", us, best))
        rows.append((f"fig9_{kind}_{level:g}_iters_to_half_weight", us, t_half))
        rows.append((f"fig9_{kind}_{level:g}_regret_over_bound", us,
                     res.regret_ratio()))
        rows.append((f"fig9_{kind}_{level:g}_best_is_ahap", 0.0,
                     float(pool[best].kind == 0)))
    # noise regime changes the winning policy (the paper's point)
    rows.append(("fig9_distinct_winners", 0.0, float(len(set(winners.values())))))

    # hyperparameter ablations (fixed v=1 / fixed sigma=0.9), Fig. 9 bottom
    for name, pool_fn in [
        ("fixed_v1", lambda: paper_pool(fixed_v=1)),
        ("fixed_sigma09", lambda: paper_pool(fixed_sigma=0.9)),
    ]:
        sub = pool_fn()
        res, us = timed(_run_setting, sub, "fixed_uniform", 0.1, 400, seed=9)
        # restricting the pool lowers the achievable utility ceiling
        rows.append((f"fig9_{name}_pool_size", us, len(sub)))
        rows.append((f"fig9_{name}_best_mean_utility", us,
                     float(res.mean_utility.max())))
    res_full, _ = timed(_run_setting, pool, "fixed_uniform", 0.1, 400, seed=9)
    rows.append(("fig9_full_pool_best_mean_utility", 0.0,
                 float(res_full.mean_utility.max())))
    return rows
