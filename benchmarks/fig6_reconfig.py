"""Fig. 6: impact of reconfiguration overhead (network bandwidth 100-800 Mbps).

mu1/mu2 are derived from the real checkpoint size of the paper's LLaMA2-7B
job via the switching-cost model (repro.checkpoint). The paper's finding:
every policy degrades as bandwidth shrinks EXCEPT AHANP, whose
allocation-stability design keeps it flat.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import PAPER_JOB, best_of_family_utilities, paper_market, timed, windows
from repro.configs import get_config
from repro.configs.base import ThroughputConfig
from repro.core.throughput import calibrate

N_JOBS = 64


def run() -> list:
    rng = np.random.default_rng(1)
    trace = paper_market(seed=12)
    cfg = get_config("llama2-7b")
    rows = []
    utils = {}
    for bw_mbps in (100, 200, 400, 800):
        t = calibrate(cfg, bandwidth_bps=bw_mbps * 1e6)
        jobs = [PAPER_JOB] * N_JOBS
        trs = windows(trace, N_JOBS, PAPER_JOB.deadline, rng)
        u, us = timed(best_of_family_utilities, jobs, trs, t)
        utils[bw_mbps] = u
        rows.append((f"fig6_bw{bw_mbps}_mu1", 0.0, t.mu1))
        for i, n in enumerate(("ahap", "ahanp", "od", "msu", "up")):
            rows.append((f"fig6_bw{bw_mbps}_{n}_utility", us, u[i]))
    # AHANP stability: utility drop from 800 -> 100 Mbps, vs AHAP's drop
    drop_ahanp = utils[800][1] - utils[100][1]
    drop_ahap = utils[800][0] - utils[100][0]
    rows.append(("fig6_ahanp_drop", 0.0, drop_ahanp))
    rows.append(("fig6_ahap_drop", 0.0, drop_ahap))
    rows.append(("fig6_ahanp_more_stable", 0.0, float(drop_ahanp <= drop_ahap + 1e-9)))
    return rows
