"""End-to-end online-selection throughput: the engine vs the host-loop
pipeline it replaced.

The workload is the Fig. 9 convergence setting at paper scale (1000 jobs x
the 124-lane mixed pool x 10 slots, fixed-magnitude uniform 10% noise).
Two pipelines produce the same selection decision:

  engine   core.engine.simulate_and_select — batched prep (one window
           gather + one vectorized forecast stack), sharded pool
           simulation, and the fused normalize + EG lax.scan; the (K, M)
           utility matrix stays on device end to end. Recorded as the
           prep / simulate / select split plus the total.
  loop     the pre-engine pipeline: per-job ``trace.window`` +
           ``NoisyPredictor(...).matrix`` constructions, the same pool
           simulation, then per-job ``normalize_utility`` calls and a
           K-iteration numpy ``selector.update`` loop.

The headline ``selection_e2e_engine_vs_loop`` row is loop-seconds over
engine-seconds (>= 1.0 means the engine pays for itself); the opt-in
regression guard (tests/test_bench_regression.py, RUN_BENCH_REGRESSION=1)
pins it at the Fig. 9 scale. Rows are folded into BENCH_pool_sim.json
(selection rows replaced in place, the rest untouched).

Env knobs: SEL_E2E_JOBS (default 1000), SEL_E2E_REPEAT (default 2);
POOL_SIM_MESH picks the pool mesh for the engine's sharded simulation
(single device falls back bitwise to the unsharded path); POOL_SIM_JSON
redirects the JSON artifact.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import (
    PAPER_TPUT,
    job_stream_arrays,
    merge_bench_rows,
    paper_market,
)
from benchmarks.pool_sim_bench import _JSON_PATH

N_JOBS = int(os.environ.get("SEL_E2E_JOBS", "1000"))
REPEAT = int(os.environ.get("SEL_E2E_REPEAT", "2"))
DEADLINE = 10
KIND, LEVEL, SEED = "fixed_uniform", 0.1, 7


def _workload():
    rng = np.random.default_rng(SEED)
    trace = paper_market(seed=21, days=40)
    jobs = job_stream_arrays(rng, N_JOBS, DEADLINE)
    t0s = rng.integers(0, len(trace) - DEADLINE - 1, size=N_JOBS)
    seeds = SEED * 100003 + np.arange(N_JOBS)
    return trace, jobs, t0s, seeds


def _timeit(fn, repeat: int = REPEAT):
    """(warm-up result, seconds per call at steady state) — the first call
    pays compilation and its result is returned so callers never re-run the
    pipeline untimed just to read the output."""
    out = fn()
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return out, (time.perf_counter() - t0) / repeat


def _loop_pipeline(trace, jobs_cfg, t0s, seeds, arrs, n_pol: int):
    """The pre-engine Fig. 9 host pipeline, end to end (returns the final
    numpy SelectorState)."""
    from repro.core import fast_sim, selector
    from repro.core.job import normalize_utility
    from repro.core.predictor import NoisyPredictor

    trs, preds = [], []
    for t0, s in zip(t0s, seeds):
        w = trace.window(int(t0), DEADLINE + 1)
        trs.append(w)
        preds.append(NoisyPredictor(w, KIND, LEVEL, seed=int(s)).matrix(
            fast_sim.W1MAX - 1
        )[:DEADLINE])
    out = fast_sim.simulate_pool_jobs(
        arrs, fast_sim.stack_jobs(jobs_cfg), PAPER_TPUT,
        np.stack([t.prices[:DEADLINE] for t in trs]).astype(np.float32),
        np.stack([t.avail[:DEADLINE] for t in trs]),
        np.stack(preds).astype(np.float32),
    )
    u = np.asarray(out["utility"])
    st = selector.init_selector(n_pol, len(jobs_cfg))
    for k in range(len(jobs_cfg)):
        st = selector.update(
            st, np.asarray(normalize_utility(jobs_cfg[k], u[k]))
        )
    return st


def _update_bench_json(rows, extra):
    """Fold the selection rows into BENCH_pool_sim.json without disturbing
    the pool_sim / region_sim trajectory rows (shared merge in
    benchmarks.common)."""
    merge_bench_rows(_JSON_PATH, "selection_e2e", "selection", rows, extra)


def run():
    from repro.core import engine, fast_sim, selector
    from repro.core.policy_pool import (
        baseline_specs,
        paper_pool,
        rand_deadline_pool,
        specs_to_arrays,
    )
    from repro.launch.mesh import make_pool_mesh, parse_pool_mesh_shape

    pool = paper_pool() + rand_deadline_pool() + baseline_specs()
    arrs = specs_to_arrays(pool)
    n_pol = len(pool)
    mesh = make_pool_mesh(
        shape=parse_pool_mesh_shape(os.environ.get("POOL_SIM_MESH", ""))
    )
    trace, jobs, t0s, seeds = _workload()
    jobs_cfg = fast_sim.unstack_jobs(jobs)
    units = DEADLINE * n_pol * N_JOBS      # slots * policies * jobs per call

    # --- engine split: prep (host) / simulate (device) / select (device) ---
    prep = lambda: engine.prepare_noisy_inputs(
        trace, t0s, DEADLINE, KIND, LEVEL, seeds
    )
    prices, avail, preds = prep()
    sim = lambda: fast_sim.simulate_pool_jobs_sharded(
        arrs, jobs, PAPER_TPUT, prices, avail, preds, mesh=mesh
    )
    u_dev = sim()["utility"]
    sel_stage = lambda: jax.block_until_ready(engine.select_from_utilities(
        jobs, u_dev, selector.eg_init(n_pol, N_JOBS)
    )[0].weights)
    total = lambda: engine.simulate_and_select(
        arrs, jobs, PAPER_TPUT, *prep(), mesh=mesh
    )

    secs = {
        "prep": _timeit(prep)[1],
        "simulate": _timeit(
            lambda: jax.block_until_ready(sim()["utility"])
        )[1],
        "select": _timeit(sel_stage)[1],
    }
    res, secs["total"] = _timeit(total)

    # --- the replaced host-loop pipeline, same draws, measured whole ---
    st_loop, secs["loop"] = _timeit(
        lambda: _loop_pipeline(trace, jobs_cfg, t0s, seeds, arrs, n_pol)
    )

    rows = [
        (f"selection_e2e_{name}", s * 1e6, units / s)
        for name, s in secs.items()
    ]
    ratio = secs["loop"] / secs["total"]
    rows.append(("selection_e2e_engine_vs_loop", 0.0, ratio))
    # both pipelines must land on the same winning policy (f32 vs f64 EG)
    same = float(res.best_policy() == selector.best_policy(st_loop))
    rows.append(("selection_e2e_same_winner", 0.0, same))

    _update_bench_json(rows, {
        "workload": {
            "jobs": N_JOBS, "slots": DEADLINE, "policies": n_pol,
            "noise": f"{KIND}@{LEVEL:g}",
            "pool": "paper_pool(112) + rand_deadline(9) + baselines(3)",
        },
        "pool_mesh": "x".join(map(str, mesh.devices.shape)),
        "engine_vs_loop": ratio,
        "winner": pool[res.best_policy()].name,
    })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
