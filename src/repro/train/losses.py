"""Losses: causal LM cross-entropy and masked prediction (HuBERT-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, targets, loss_mask=None, z_loss: float = 0.0):
    """logits (B,S,V) f32, targets (B,S) int32. Mean over unmasked tokens."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(logz)
    if loss_mask is not None:
        w = loss_mask.astype(jnp.float32)
        return (nll * w).sum() / jnp.maximum(w.sum(), 1.0)
    return nll.mean()


def lm_loss(cfg, logits, batch):
    """Next-token prediction: shift inside unless explicit targets given."""
    if "targets" in batch:
        return cross_entropy(logits, batch["targets"], batch.get("loss_mask"))
    toks = batch["tokens"]
    return cross_entropy(logits[:, :-1], toks[:, 1:])


def masked_prediction_loss(cfg, logits, batch):
    """Encoder masked-prediction (audio): CE only on masked frames."""
    return cross_entropy(logits, batch["targets"], batch["loss_mask"])


def task_loss(cfg, logits, batch):
    if cfg.encoder_only:
        return masked_prediction_loss(cfg, logits, batch)
    return lm_loss(cfg, logits, batch)
