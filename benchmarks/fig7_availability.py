"""Fig. 7: impact of average spot availability."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_JOB, PAPER_TPUT, mean_utilities, paper_market, timed, windows

N_JOBS = 64


def run() -> list:
    rng = np.random.default_rng(2)
    rows = []
    for mean_av in (2.0, 4.0, 8.0, 12.0):
        trace = paper_market(
            seed=13, avail_mean=mean_av,
            avail_season_amp=min(3.0, mean_av * 0.45),
        )
        jobs = [PAPER_JOB] * N_JOBS
        trs = windows(trace, N_JOBS, PAPER_JOB.deadline, rng)
        u, us = timed(mean_utilities, jobs, trs, PAPER_TPUT)
        for i, n in enumerate(("ahap", "ahanp", "od", "msu", "up")):
            rows.append((f"fig7_avail{mean_av:g}_{n}_utility", us, u[i]))
    return rows
