"""Fold telemetry frames into structured, JSON-serializable metric ledgers.

Every builder returns a plain-python dict (``json.dumps`` round-trips it)
with a shared envelope: ``schema_version``, ``kind``, a ``shape`` block,
and a ``cost_reconciliation`` block proving the per-slot cost split sums
back to the engine's reported totals:

    cost == sum_t tel_spot_cost + sum_t tel_od_cost + termination_cost
    utility == value_fn(completion_time) - cost

where ``termination_cost = p_o * n_max * dt`` with ``dt = max(L - z_ddl,
0) / (alpha * n_max + beta)`` — the f32-exact mirror of
``fast_sim._finalize``. Residuals are carried in the ledger (f32
accumulation on device vs f64 sums here), so a consumer can see the
tolerance instead of trusting it.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.obs import frame as _frame

SCHEMA_VERSION = 1

# downsample cap for curves stored in the ledger (full traces stay in the
# arrays the caller holds; the ledger is the summary artifact)
CURVE_POINTS = 64


def _py(x):
    """numpy scalar/array -> plain python (json-serializable)."""
    x = np.asarray(x)
    if x.ndim == 0:
        return x.item()
    return x.tolist()


def _job_bcast(x, like: np.ndarray) -> np.ndarray:
    """Broadcast a per-job (J,) field against a result leaf whose leading
    axis is jobs ((J, P) pool / (J,) fleet / () single)."""
    x = np.asarray(x, np.float64)
    return x.reshape(x.shape + (1,) * (like.ndim - x.ndim))


def _curve(values, n_points: int = CURVE_POINTS):
    """Downsample a 1-D trace to <= n_points (index, value) pairs, always
    keeping the final point."""
    v = np.asarray(values, np.float64)
    k = v.shape[0]
    if k == 0:
        return {"index": [], "value": []}
    idx = np.unique(np.concatenate([
        np.linspace(0, k - 1, min(n_points, k)).astype(np.int64), [k - 1]
    ]))
    return {"index": idx.tolist(), "value": v[idx].tolist()}


def cost_reconciliation(out: dict, jobs, tput) -> dict:
    """Reconcile the telemetry cost split against the engine's totals.

    ``out`` — a ``collect=True`` result dict; ``jobs`` — the stacked
    JobArrays the run used (leading jobs axis matching ``out``); ``tput`` —
    its ThroughputConfig. Residuals are max-abs over every (job, lane)
    cell, in utility units."""
    cost = np.asarray(out["cost"], np.float64)
    spot = np.asarray(out["tel_spot_cost"], np.float64).sum(axis=-1)
    od = np.asarray(out["tel_od_cost"], np.float64).sum(axis=-1)
    z = np.asarray(out["z_ddl"], np.float64)
    done = np.asarray(out["completed"], bool)
    wl = _job_bcast(jobs.workload, cost)
    n_max = _job_bcast(jobs.n_max, cost)
    p_o = _job_bcast(jobs.p_o, cost)
    h_max = float(tput.alpha) * n_max + float(tput.beta)
    term = np.where(done, 0.0, p_o * n_max * np.maximum(wl - z, 0.0) / h_max)
    cost_resid = cost - (spot + od + term)
    util_resid = (np.asarray(out["value"], np.float64) - cost
                  - np.asarray(out["utility"], np.float64))
    return {
        "total_cost": float(cost.sum()),
        "spot_cost": float(spot.sum()),
        "od_cost": float(od.sum()),
        "termination_cost": float(term.sum()),
        "spot_share": float(spot.sum() / max(cost.sum(), 1e-12)),
        "max_abs_cost_residual": float(np.abs(cost_resid).max()),
        "max_abs_utility_residual": float(np.abs(util_resid).max()),
    }


def _event_aggregates(fr: _frame.TelemetryFrame, axis) -> dict:
    """Event/cost aggregates reduced over ``axis`` (per-lane or per-job)."""
    slots = fr.active.sum(axis=-1)
    return {
        "mean_active_slots": _py(slots.mean(axis=axis)),
        "preemptions_mean": _py(
            fr.preempted.sum(axis=-1).mean(axis=axis).astype(np.float64)),
        "reconfig_up_mean": _py(
            fr.reconfig_up.sum(axis=-1).mean(axis=axis).astype(np.float64)),
        "reconfig_down_mean": _py(
            fr.reconfig_down.sum(axis=-1).mean(axis=axis).astype(np.float64)),
    }


def fallback_events(active) -> dict:
    """Trigger/recovery accounting over a ``tel_fallback`` series (any
    leading axes, trailing time axis). A *trigger* is the monitor arming
    (rising edge, plus rows already armed at slot 0); a *recovery* is the
    monitor standing down (falling edge). The reconciliation invariant —
    every trigger is matched by a recovery or is still open at the end —
    is carried as ``events_reconciled`` so a consumer can check it held."""
    act = np.asarray(active, bool)
    if act.size == 0:
        return {"triggers": 0, "recoveries": 0, "open_at_end": 0,
                "active_fraction": 0.0, "events_reconciled": True}
    d = np.diff(act.astype(np.int8), axis=-1)
    triggers = int((d > 0).sum() + act[..., 0].sum())
    recoveries = int((d < 0).sum())
    open_at_end = int(act[..., -1].sum())
    return {
        "triggers": triggers,
        "recoveries": recoveries,
        "open_at_end": open_at_end,
        "active_fraction": float(act.mean()),
        "events_reconciled": triggers == recoveries + open_at_end,
    }


def migration_reconciliation(out: dict) -> dict:
    """Reconcile the per-slot migration series against the region engine's
    summary leaves (a ``simulate_pool_regions[_sharded]`` ``collect=True``
    run).

    Two invariants are checked, not trusted:

    * ``events_reconciled`` — per (job, lane), ``tel_migration`` slot sums
      equal the ``migrations`` result leaf exactly (every committed switch
      the scan counted shows up as exactly one telemetry event);
    * ``series_matches_leaf`` — ``tel_region`` is bitwise the ``region``
      occupancy leaf (the telemetry path and the result path sampled the
      same post-step region).

    Also summarizes occupancy: fraction of slot-samples spent in each
    region, and the mean committed switches per (job, lane)."""
    mig_series = np.asarray(out["tel_migration"], bool)
    mig_leaf = np.asarray(out["migrations"], np.int64)
    reg_series = np.asarray(out["tel_region"], np.int64)
    reg_leaf = np.asarray(out["region"], np.int64)
    per_cell = mig_series.sum(axis=-1).astype(np.int64)
    n_regions = int(reg_series.max()) + 1 if reg_series.size else 0
    occupancy = [float((reg_series == r).mean()) for r in range(n_regions)]
    return {
        "total_migrations": int(mig_leaf.sum()),
        "migrations_mean": float(mig_leaf.mean()) if mig_leaf.size else 0.0,
        "events_reconciled": bool(np.array_equal(per_cell, mig_leaf)),
        "series_matches_leaf": bool(np.array_equal(reg_series, reg_leaf)),
        "region_occupancy": occupancy,
    }


def _migration_block(out: dict) -> Optional[dict]:
    if "tel_migration" not in out or "migrations" not in out:
        return None
    return migration_reconciliation(out)


def _fallback_block(fr: _frame.TelemetryFrame) -> Optional[dict]:
    if fr.fallback_active is None:
        return None
    block = fallback_events(fr.fallback_active)
    block["pred_err_max"] = float(np.asarray(fr.pred_err).max())
    block["pred_err_final_mean"] = float(
        np.asarray(fr.pred_err, np.float64)[..., -1].mean())
    return block


def pool_ledger(out: dict, jobs, tput, lane_names: Optional[Sequence[str]] =
                None) -> dict:
    """Ledger for a ``simulate_pool_jobs[_sharded]`` collect run.

    ``out`` leaves are (J, P[, T]); per-lane aggregations reduce over the
    jobs axis. ``lane_names`` (length P) labels the per-lane block. Region
    runs (``simulate_pool_regions[_sharded]``) get a ``migration`` block —
    :func:`migration_reconciliation` over their ``tel_region`` /
    ``tel_migration`` series."""
    fr = _frame.frame_from_out(out)
    util = np.asarray(out["utility"], np.float64)     # (J, P)
    cost = np.asarray(out["cost"], np.float64)
    spot = fr.spot_cost.sum(axis=-1).astype(np.float64)
    od = fr.od_cost.sum(axis=-1).astype(np.float64)
    n_jobs, n_lanes = util.shape
    per_lane = {
        "mean_utility": _py(util.mean(axis=0)),
        "mean_cost": _py(cost.mean(axis=0)),
        "mean_spot_cost": _py(spot.mean(axis=0)),
        "mean_od_cost": _py(od.mean(axis=0)),
        "completion_rate": _py(
            np.asarray(out["completed"]).mean(axis=0).astype(np.float64)),
        **_event_aggregates(fr, axis=0),
    }
    if lane_names is not None:
        per_lane["name"] = list(lane_names)
    ledger = {
        "schema_version": SCHEMA_VERSION,
        "kind": "pool",
        "shape": {"n_jobs": n_jobs, "n_lanes": n_lanes,
                  "n_slots": int(fr.active.shape[-1])},
        "cost_reconciliation": cost_reconciliation(out, jobs, tput),
        "per_lane": per_lane,
    }
    fb = _fallback_block(fr)
    if fb is not None:
        ledger["fallback"] = fb
    mig = _migration_block(out)
    if mig is not None:
        ledger["migration"] = mig
    return ledger


def fleet_ledger(out: dict, jobs, tput, supply=None) -> dict:
    """Ledger for a ``simulate_fleet[_sharded]`` collect run.

    ``out`` leaves are (J[, T]). Adds the waterfall block: per-job demand
    vs grant totals, starvation incidence (fraction of jobs with at least
    one live slot granted strictly less than demanded), and — when the
    supply trace is passed — the per-slot oversubscription check
    (sum of grants minus supply, must never exceed 0)."""
    fr = _frame.frame_from_out(out)
    util = np.asarray(out["utility"], np.float64)     # (J,)
    demand = fr.demand.astype(np.int64)
    grant = fr.grant.astype(np.int64)
    starved_slots = fr.starved.sum(axis=-1).astype(np.int64)
    ledger = {
        "schema_version": SCHEMA_VERSION,
        "kind": "fleet",
        "shape": {"n_jobs": int(util.shape[0]),
                  "n_slots": int(fr.active.shape[-1])},
        "cost_reconciliation": cost_reconciliation(out, jobs, tput),
        "waterfall": {
            "total_demand": int(demand.sum()),
            "total_granted": int(grant.sum()),
            "grant_ratio": float(grant.sum() / max(demand.sum(), 1)),
            "starvation_incidence": float((starved_slots > 0).mean()),
            "starved_slots_total": int(starved_slots.sum()),
        },
        "per_job": {
            "utility": _py(util),
            "cost": _py(np.asarray(out["cost"], np.float64)),
            "spot_cost": _py(fr.spot_cost.sum(axis=-1).astype(np.float64)),
            "od_cost": _py(fr.od_cost.sum(axis=-1).astype(np.float64)),
            "demand": _py(demand.sum(axis=-1)),
            "granted": _py(grant.sum(axis=-1)),
            "starved_slots": _py(starved_slots),
            **_event_aggregates(fr, axis=()),
        },
    }
    if supply is not None:
        over = grant.sum(axis=0) - np.asarray(supply, np.int64)
        ledger["waterfall"]["max_oversubscription"] = int(over.max())
    fb = _fallback_block(fr)
    if fb is not None:
        ledger["fallback"] = fb
    return ledger


def selection_ledger(result) -> dict:
    """Ledger for an ``engine.simulate_and_select`` run (a SelectionResult).

    Always carries the convergence curve (leader weight + cumulative
    regret per job, downsampled); the entropy curve and top-policy switch
    trace appear when the run collected (``collect=True``)."""
    m = int(np.shape(result.state.weights)[0])
    ledger = {
        "schema_version": SCHEMA_VERSION,
        "kind": "selection",
        "shape": {"n_jobs": int(result.n_jobs), "n_policies": m},
        "best_policy": int(result.best_policy()),
        "iters_to_half": int(result.iters_to_half()),
        "regret_ratio": float(result.regret_ratio()),
        "convergence": {
            "max_weight": _curve(result.max_weight),
            "regret": _curve(result.regret),
        },
    }
    if result.entropy is not None:
        ledger["convergence"]["entropy"] = _curve(result.entropy)
        ledger["entropy_final"] = float(np.asarray(result.entropy)[-1])
        ledger["entropy_uniform"] = float(np.log(m))
    if result.top_policy is not None:
        top = np.asarray(result.top_policy, np.int64)
        switch = np.flatnonzero(np.diff(top)) + 1
        ledger["top_policy"] = {
            # run-length encoding: the leader after job 0, then every switch
            "policy": [int(top[0])] + [int(top[s]) for s in switch],
            "since_job": [0] + switch.tolist(),
            "n_switches": int(switch.shape[0]),
        }
    return ledger


def grid_ledger(regimes: List[dict], util: np.ndarray, sim_out: dict, jobs,
                tputs: Sequence, n_jobs: int,
                lane_names: Optional[Sequence[str]] = None) -> dict:
    """Per-regime telemetry ledger for the scenario grid.

    ``regimes`` — one metadata dict per regime (must carry ``key``);
    ``util`` — the (R, K, M) raw-utility tensor; ``sim_out`` — the merged
    collect dict from ``evaluate_grid(..., collect=True)`` ((R*K, M, ...)
    leaves, regime-major); ``jobs`` — the stacked (R*K,) JobArrays;
    ``tputs`` — the per-regime ThroughputConfig (the mu axis). Each
    regime's entry reconciles its own cost decomposition and summarizes
    the winner lane's flight record — the *evidence* behind the winner
    map."""
    from repro.core import fast_sim

    R, K, M = util.shape
    assert len(regimes) == R and len(tputs) == R
    per_regime = []
    worst_cost = worst_util = 0.0
    for r, meta in enumerate(regimes):
        sl = {k: np.asarray(v)[r * K:(r + 1) * K] for k, v in sim_out.items()}
        jb = fast_sim.slice_jobs(jobs, r * K, (r + 1) * K)
        recon = cost_reconciliation(sl, jb, tputs[r])
        worst_cost = max(worst_cost, recon["max_abs_cost_residual"])
        worst_util = max(worst_util, recon["max_abs_utility_residual"])
        fr = _frame.frame_from_out(sl)
        mean_u = util[r].mean(axis=0)                 # (M,)
        w = int(mean_u.argmax())
        lane = lambda a: _py(np.asarray(a, np.float64)[:, w].mean())
        entry = {
            **meta,
            "winner_idx": w,
            "winner_mean_utility": float(mean_u[w]),
            "cost_reconciliation": recon,
            "winner_lane": {
                "mean_cost": lane(np.asarray(sl["cost"])),
                "mean_spot_cost": lane(fr.spot_cost.sum(axis=-1)),
                "mean_od_cost": lane(fr.od_cost.sum(axis=-1)),
                "completion_rate": lane(np.asarray(sl["completed"])),
                "preemptions_mean": lane(fr.preempted.sum(axis=-1)),
                "reconfig_mean": lane((fr.reconfig_up
                                       | fr.reconfig_down).sum(axis=-1)),
            },
            "pool": {
                "spot_share": recon["spot_share"],
                "preempt_rate": float(fr.preempted.mean()),
                "completion_rate": float(np.asarray(sl["completed"]).mean()),
            },
        }
        if lane_names is not None:
            entry["winner"] = str(lane_names[w])
        per_regime.append(entry)
    return {
        "schema_version": SCHEMA_VERSION,
        "kind": "scenario_grid",
        "shape": {"n_regimes": R, "jobs_per_regime": K, "n_lanes": M},
        "max_abs_cost_residual": worst_cost,
        "max_abs_utility_residual": worst_util,
        "per_regime": per_regime,
    }
