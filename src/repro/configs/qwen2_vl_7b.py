"""Qwen2-VL-7B language backbone [arXiv:2409.12191].

VLM: the ViT/SigLIP-style vision encoder + projector is a STUB per spec —
``input_specs()`` supplies precomputed patch/text embeddings of shape
(B, S, d_model). M-RoPE (multimodal rotary with t/h/w sections) is implemented
in the backbone. Qwen2 family uses QKV bias.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        arch_type="vlm",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        rope_theta=1_000_000.0,
        m_rope=True,
        m_rope_sections=(16, 24, 24),
        qkv_bias=True,
        norm_type="rmsnorm",
        mlp_act="silu",
        embed_inputs=True,  # vision/text frontend stubbed -> embeddings in
        source="arXiv:2409.12191",
    )


def smoke_config() -> ModelConfig:
    return config().reduced(m_rope_sections=(8, 12, 12))
