"""Chaos harness pins: fault-injection invariants, the prediction-failure
fallback's static-flag discipline, and the trigger/recovery telemetry.

Three families:

* **Fault transforms are safe.** Hypothesis properties over random fault
  schedules: injected traces keep ``avail >= 0`` / ``prices >= 0`` /
  dtypes, faults are the identity outside their windows, an empty
  schedule is a bitwise no-op, and forecast faults never touch the
  observed-present column.

* **fallback=None is the shipped program.** Same bitwise pin as
  ``collect=False`` (the 4-device sharded twins are pinned in
  tests/test_sharded_pool.py and tests/test_fleet.py subprocesses); an
  armed monitor whose threshold is never crossed also reproduces the
  baseline decisions exactly.

* **The monitor works.** Under an injected preemption storm with stale
  forecasts the lanes trigger (``tel_fallback`` goes high, decisions
  change), the collect pass rides bitwise on the non-collect one, and
  the ledger's trigger/recovery accounting reconciles.
"""
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from benchmarks.common import PAPER_TPUT, job_stream_arrays, paper_market
from repro.chaos import (
    FAULT_KINDS,
    FallbackConfig,
    FaultSpec,
    blackout_schedule,
    inject,
    inject_forecasts,
    inject_market,
    storm_schedule,
    window_mask,
)
from repro.core import engine, fast_sim, fleet
from repro.core.market import require_finite, vast_like_trace
from repro.core.policy_pool import (
    KIND_AHAP,
    baseline_specs,
    paper_pool,
    rand_deadline_pool,
    specs_to_arrays,
)
from repro.core.predictor import NoisyPredictor
from repro.obs import FALLBACK_KEYS, SLOT_KEYS, fallback_events, pool_ledger

TPUT = PAPER_TPUT
D = 10


def _pool_setup(n_jobs=4, seed=3, fault_seed=None):
    """Small pool + per-job windows; ``fault_seed`` injects a storm+stale
    schedule over the windows."""
    pool = (paper_pool(omegas=(2,), sigmas=(0.5,))
            + rand_deadline_pool((0.4,)) + baseline_specs())
    arrs = specs_to_arrays(pool)
    rng = np.random.default_rng(seed)
    jobs = job_stream_arrays(rng, n_jobs, deadline=D, workload_scale=1.4)
    trace = paper_market(11, days=4, avail_mean=9.0, mean_price=0.4,
                         price_sigma=0.3)
    t0s = np.random.default_rng(seed + 1).integers(
        0, len(trace) - D - 1, n_jobs)
    prices, avail, preds = engine.prepare_noisy_inputs(
        trace, t0s, D, "magdep_uniform", 0.1, np.arange(n_jobs))
    if fault_seed is not None:
        sched = storm_schedule(fault_seed, D, n_storms=2, storm_len=4,
                               pred_fault="stale", spike_mag=2.5)
        prices, avail, preds = inject(prices, avail, preds, sched)
    return arrs, jobs, prices, avail, preds


def _fleet_setup(J=8, T=24, seed=7, fault_seed=None):
    pool = (paper_pool(omegas=(2,), sigmas=(0.5,))
            + rand_deadline_pool((0.4,)) + baseline_specs())
    arrs = specs_to_arrays(pool)
    rng = np.random.default_rng(seed)
    tr = vast_like_trace(seed=5, days=2).window(0, T + 1)
    prices = tr.prices[:T].astype(np.float32)
    avail = tr.avail[:T].astype(np.int64)
    pred = NoisyPredictor(tr, "fixed_uniform", 0.2, seed=3).matrix(
        fast_sim.W1MAX - 1)[:T].astype(np.float32)
    if fault_seed is not None:
        sched = storm_schedule(fault_seed, T, n_storms=2, storm_len=5,
                               pred_fault="stale")
        prices, avail, pred = inject(prices, avail, pred, sched)
    jobs = job_stream_arrays(rng, J, deadline=D)
    arrivals = rng.integers(0, 8, size=J)
    idx = rng.integers(0, len(pool), size=J)
    rows = {k: np.asarray(arrs[k])[idx]
            for k in ("kind", "omega", "v", "sigma", "rho", "cfrac")}
    return jobs, arrivals, rows, prices, avail, pred


# ---------------------------------------------------------------------------
# fault transforms
# ---------------------------------------------------------------------------

fault_strategy = st.builds(
    FaultSpec,
    kind=st.sampled_from(FAULT_KINDS),
    start=st.integers(0, 30),
    length=st.integers(0, 12),
    magnitude=st.floats(0.0, 5.0),
    region=st.just(-1),
)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), f1=fault_strategy, f2=fault_strategy)
def test_market_fault_invariants(seed, f1, f2):
    rng = np.random.default_rng(seed)
    prices = rng.uniform(0.05, 2.0, (3, 24))
    avail = rng.integers(0, 16, (3, 24))
    p, a = inject_market(prices, avail, (f1, f2))
    assert p.dtype == prices.dtype and a.dtype == avail.dtype
    assert (p >= 0).all() and (a >= 0).all()
    # identity outside the union of windows
    m = np.zeros(24, bool)
    for f in (f1, f2):
        if f.kind in ("preempt_storm", "blackout", "price_spike"):
            m |= window_mask(24, f)
    np.testing.assert_array_equal(p[:, ~m], prices[:, ~m])
    np.testing.assert_array_equal(a[:, ~m], avail[:, ~m])
    # inputs untouched
    assert (avail >= 0).all() and prices.min() >= 0.05


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), f=fault_strategy)
def test_forecast_fault_invariants(seed, f):
    rng = np.random.default_rng(seed)
    preds = rng.uniform(0.0, 8.0, (2, 24, 6, 2)).astype(np.float32)
    out = inject_forecasts(preds, (f,))
    assert out.dtype == preds.dtype
    # the observed-present column is never a predictor's to corrupt
    np.testing.assert_array_equal(out[..., 0, :], preds[..., 0, :])
    m = window_mask(24, f) if f.kind.startswith("pred_") else np.zeros(24, bool)
    np.testing.assert_array_equal(out[:, ~m], preds[:, ~m])
    if f.kind == "pred_outage" and m.any():
        assert (out[:, m, 1:, :] == 0).all()
    if f.kind == "pred_stale" and m.any():
        t_freeze = max(min(f.start, 24) - 1, 0)
        for t in np.flatnonzero(m):
            np.testing.assert_array_equal(out[:, t, 1:, :],
                                          preds[:, t_freeze, 1:, :])


def test_empty_schedule_is_identity():
    rng = np.random.default_rng(0)
    prices = rng.uniform(0.1, 1.0, (4, 16)).astype(np.float32)
    avail = rng.integers(0, 16, (4, 16))
    preds = rng.uniform(0, 8, (4, 16, 6, 2)).astype(np.float32)
    p, a, pr = inject(prices, avail, preds, ())
    np.testing.assert_array_equal(p, prices)
    np.testing.assert_array_equal(a, avail)
    # inject re-syncs the present column even with no faults: already true
    np.testing.assert_array_equal(pr[..., 1:, :], preds[..., 1:, :])
    assert storm_schedule(0, 48, n_storms=0) == ()


def test_storm_and_spike_semantics():
    prices = np.full((2, 20), 0.5)
    avail = np.full((2, 20), 7)
    sched = (FaultSpec("preempt_storm", 4, 3),
             FaultSpec("price_spike", 10, 2, magnitude=3.0))
    p, a = inject_market(prices, avail, sched)
    assert (a[:, 4:7] == 0).all() and (a[:, :4] == 7).all()
    np.testing.assert_allclose(p[:, 10:12], 1.5)
    np.testing.assert_allclose(p[:, 12:], 0.5)


def test_regional_blackout():
    avail = np.full((3, 20), 5)          # (R=3 regions, T)
    prices = np.full(20, 0.5)
    p, a = inject_market(prices, avail,
                         (FaultSpec("blackout", 2, 4, region=1),))
    assert (a[1, 2:6] == 0).all()
    assert (a[0] == 5).all() and (a[2] == 5).all()
    with pytest.raises(ValueError, match="region"):
        inject_market(np.ones(8), np.ones(8),
                      (FaultSpec("blackout", 0, 2, region=1),))
    sched = blackout_schedule(3, 40, 4, n_events=2)
    assert len(sched) == 2 and all(0 <= f.region < 4 for f in sched)
    assert sched == blackout_schedule(3, 40, 4, n_events=2)


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("meteor", 0, 1)
    with pytest.raises(ValueError, match=">= 0"):
        FaultSpec("preempt_storm", -1, 1)
    with pytest.raises(ValueError, match="magnitude"):
        FaultSpec("price_spike", 0, 1, magnitude=-2.0)
    with pytest.raises(ValueError, match="pred_fault"):
        storm_schedule(0, 48, pred_fault="bogus")
    sched = storm_schedule(7, 48, n_storms=3, storm_len=4, spike_mag=2.0)
    assert sched == storm_schedule(7, 48, n_storms=3, storm_len=4,
                                   spike_mag=2.0)
    storms = [f for f in sched if f.kind == "preempt_storm"]
    assert len(storms) == 3
    for f in storms:                     # storms stay inside the horizon
        assert 0 <= f.start and f.start + f.length <= 48


def test_fallback_config_validation():
    with pytest.raises(ValueError, match="threshold"):
        FallbackConfig(threshold=0.0)
    with pytest.raises(ValueError, match="lam"):
        FallbackConfig(lam=1.5)
    with pytest.raises(ValueError, match="price_weight"):
        FallbackConfig(price_weight=-0.1)
    assert hash(FallbackConfig()) == hash(FallbackConfig())


def test_market_regime_fault_batch():
    from repro.data.synthetic import (market_regime_batch,
                                      market_regime_fault_batch)

    seeds = np.arange(3)
    fs = np.arange(3) + 100
    p0, a0 = market_regime_batch(seeds, days=1.0)
    p, a, sched = market_regime_fault_batch(seeds, fs, days=1.0,
                                            n_storms=[0, 1, 2])
    assert len(sched) == 3 and sched[0] == ()
    np.testing.assert_array_equal(p[0], p0[0])   # 0 storms = clean regime
    np.testing.assert_array_equal(a[0], a0[0])
    for r in (1, 2):
        storms = [f for f in sched[r] if f.kind == "preempt_storm"]
        assert len(storms) == r
        for f in storms:
            assert (a[r][window_mask(p.shape[1], f)] == 0).all()
    with pytest.raises(ValueError, match="fault_seeds"):
        market_regime_fault_batch(seeds, fs[:2], days=1.0)


# ---------------------------------------------------------------------------
# fallback=None is the shipped program; armed-but-quiet reproduces it
# ---------------------------------------------------------------------------

def test_pool_fallback_none_bitwise():
    arrs, jobs, prices, avail, preds = _pool_setup()
    base = fast_sim.simulate_pool_jobs(arrs, jobs, TPUT, prices, avail, preds)
    fb = fast_sim.simulate_pool_jobs(arrs, jobs, TPUT, prices, avail, preds,
                                     fallback=None)
    assert set(fb) == set(base)
    for k in base:
        np.testing.assert_array_equal(np.asarray(base[k]), np.asarray(fb[k]),
                                      err_msg=k)


def test_pool_fallback_quiet_monitor_matches_baseline():
    # threshold far above any realizable EWMA: the monitor is armed but
    # never fires, so every decision must equal the shipped program's
    arrs, jobs, prices, avail, preds = _pool_setup(fault_seed=0)
    base = fast_sim.simulate_pool_jobs(arrs, jobs, TPUT, prices, avail, preds)
    fb = fast_sim.simulate_pool_jobs(arrs, jobs, TPUT, prices, avail, preds,
                                     fallback=FallbackConfig(threshold=1e9))
    for k in base:
        np.testing.assert_array_equal(np.asarray(base[k]), np.asarray(fb[k]),
                                      err_msg=k)


def test_pool_fallback_triggers_under_storm():
    arrs, jobs, prices, avail, preds = _pool_setup(fault_seed=0)
    kind = np.asarray(arrs["kind"])
    base = fast_sim.simulate_pool_jobs(arrs, jobs, TPUT, prices, avail, preds)
    cfg = FallbackConfig(threshold=0.5, lam=0.5)
    on = fast_sim.simulate_pool_jobs(arrs, jobs, TPUT, prices, avail, preds,
                                     collect=True, fallback=cfg)
    assert set(on) - set(base) == set(SLOT_KEYS) | set(FALLBACK_KEYS)
    fb_series = np.asarray(on["tel_fallback"])          # (J, P, T)
    err = np.asarray(on["tel_pred_err"])
    assert fb_series[:, kind == KIND_AHAP].any()
    # cheap lanes carry no monitor: all-zero placeholder rows
    assert not fb_series[:, kind != KIND_AHAP].any()
    assert not err[:, kind != KIND_AHAP].any()
    assert (err >= 0).all()
    # the override actually changes decisions somewhere
    assert not np.array_equal(np.asarray(on["utility"]),
                              np.asarray(base["utility"]))
    # ... and only on AHAP lanes
    cheap = kind != KIND_AHAP
    np.testing.assert_array_equal(np.asarray(on["utility"])[:, cheap],
                                  np.asarray(base["utility"])[:, cheap])


def test_pool_fallback_collect_parity():
    # collect only ADDS keys to a fallback run: shared keys are bitwise
    arrs, jobs, prices, avail, preds = _pool_setup(fault_seed=0)
    cfg = FallbackConfig(threshold=0.5, lam=0.5)
    plain = fast_sim.simulate_pool_jobs(arrs, jobs, TPUT, prices, avail,
                                        preds, fallback=cfg)
    tel = fast_sim.simulate_pool_jobs(arrs, jobs, TPUT, prices, avail, preds,
                                      collect=True, fallback=cfg)
    for k in plain:
        np.testing.assert_array_equal(np.asarray(plain[k]),
                                      np.asarray(tel[k]), err_msg=k)


def test_fleet_fallback_none_bitwise_and_trigger():
    jobs, arrivals, rows, prices, avail, pred = _fleet_setup()
    base = fleet.simulate_fleet(rows, jobs, arrivals, TPUT, prices, avail,
                                pred)
    none = fleet.simulate_fleet(rows, jobs, arrivals, TPUT, prices, avail,
                                pred, fallback=None)
    for k in base:
        np.testing.assert_array_equal(np.asarray(base[k]),
                                      np.asarray(none[k]), err_msg=k)
    quiet = fleet.simulate_fleet(rows, jobs, arrivals, TPUT, prices, avail,
                                 pred, fallback=FallbackConfig(threshold=1e9))
    for k in base:
        np.testing.assert_array_equal(np.asarray(base[k]),
                                      np.asarray(quiet[k]), err_msg=k)

    jobs, arrivals, rows, prices, avail, pred = _fleet_setup(fault_seed=1)
    cfg = FallbackConfig(threshold=0.5, lam=0.5)
    on = fleet.simulate_fleet(rows, jobs, arrivals, TPUT, prices, avail,
                              pred, collect=True, fallback=cfg)
    assert set(FALLBACK_KEYS) <= set(on)
    fb_series = np.asarray(on["tel_fallback"])          # (J, T)
    kind_j = np.asarray(rows["kind"])
    assert fb_series[kind_j == KIND_AHAP].any()
    assert not fb_series[kind_j != KIND_AHAP].any()
    # collect parity with the monitor armed
    plain = fleet.simulate_fleet(rows, jobs, arrivals, TPUT, prices, avail,
                                 pred, fallback=cfg)
    for k in plain:
        np.testing.assert_array_equal(np.asarray(plain[k]),
                                      np.asarray(on[k]), err_msg=k)


def test_engine_fallback_roundtrip_and_ledger():
    arrs, jobs, prices, avail, preds = _pool_setup(fault_seed=0)
    cfg = FallbackConfig(threshold=0.5, lam=0.5)
    off = engine.simulate_and_select(arrs, jobs, TPUT, prices, avail, preds,
                                     sharded=False)
    on = engine.simulate_and_select(arrs, jobs, TPUT, prices, avail, preds,
                                    sharded=False, fallback=cfg,
                                    collect=True)
    assert not np.array_equal(off.mean_utility, on.mean_utility)
    led = pool_ledger(on.sim_out, jobs, TPUT)
    fb = led["fallback"]
    assert fb["triggers"] > 0
    assert fb["events_reconciled"]
    assert 0.0 < fb["active_fraction"] < 1.0
    assert fb["pred_err_max"] > 0.5
    # off-run ledger has no fallback block
    off_tel = engine.simulate_and_select(arrs, jobs, TPUT, prices, avail,
                                         preds, sharded=False, collect=True)
    assert "fallback" not in pool_ledger(off_tel.sim_out, jobs, TPUT)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_fallback_events_reconciliation(seed):
    rng = np.random.default_rng(seed)
    act = rng.random((3, 5, 16)) < 0.4
    ev = fallback_events(act)
    assert ev["events_reconciled"]
    assert ev["triggers"] >= ev["open_at_end"]
    assert 0.0 <= ev["active_fraction"] <= 1.0
    # hand-checked edge cases
    assert fallback_events(np.zeros((2, 4), bool))["triggers"] == 0
    always = fallback_events(np.ones((2, 4), bool))
    assert always["triggers"] == 2 and always["open_at_end"] == 2
    assert always["recoveries"] == 0 and always["events_reconciled"]


# ---------------------------------------------------------------------------
# input validation
# ---------------------------------------------------------------------------

def test_require_finite():
    require_finite("ok", np.ones(4))
    require_finite("ints are exempt", np.array([1, 2]))
    with pytest.raises(ValueError, match=r"bad.*2 non-finite.*index \(1,\)"):
        require_finite("bad", np.array([0.0, np.nan, np.inf]))


def test_gather_windows_rejects_nan():
    trace = paper_market(11, days=1)
    trace.prices[5] = np.nan
    with pytest.raises(ValueError, match="trace.prices"):
        engine.prepare_noisy_inputs(trace, np.zeros(2, np.int64), D,
                                    "magdep_uniform", 0.1, np.arange(2))


def test_prepare_noisy_inputs_rejects_nonfinite_level():
    trace = paper_market(11, days=1)
    with pytest.raises(ValueError, match="level"):
        engine.prepare_noisy_inputs(trace, np.zeros(2, np.int64), D,
                                    "magdep_uniform", np.nan, np.arange(2))
    with pytest.raises(ValueError, match="avail"):
        from repro.core.predictor import noisy_matrix_batch
        noisy_matrix_batch(np.ones((2, 8)),
                           np.array([[1.0, np.inf] + [1.0] * 6] * 2),
                           "magdep_uniform", 0.1, np.arange(2), 5)
