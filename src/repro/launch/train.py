"""Training launcher: elastic scheduler-driven LoRA fine-tuning.

    PYTHONPATH=src python -m repro.launch.train --arch tiny-100m --smoke \
        --policy ahap --steps-per-unit 2 --deadline 6

On a real cluster this process runs per-host under the production mesh
(launch/mesh.py); on CPU it runs the full loop with the smoke-sized model.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import TrainConfig, get_config, get_smoke_config
from repro.configs.base import JobConfig
from repro.core.market import vast_like_trace
from repro.core.policies import AHANP, AHANPParams, AHAP, AHAPParams, MSU, ODOnly, UP
from repro.core.predictor import ARIMAPredictor, NoisyPredictor, PerfectPredictor
from repro.core.throughput import calibrate
from repro.train.elastic import ElasticTrainer

POLICIES = {
    "ahap": lambda a: AHAP(AHAPParams(a.omega, a.commit, a.sigma)),
    "ahanp": lambda a: AHANP(AHANPParams(a.sigma)),
    "od": lambda a: ODOnly(),
    "msu": lambda a: MSU(),
    "up": lambda a: UP(),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-100m")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--policy", default="ahap", choices=sorted(POLICIES))
    ap.add_argument("--predictor", default="arima",
                    choices=["perfect", "arima", "noisy"])
    ap.add_argument("--noise", type=float, default=0.2)
    ap.add_argument("--omega", type=int, default=3)
    ap.add_argument("--commit", type=int, default=1)
    ap.add_argument("--sigma", type=float, default=0.7)
    ap.add_argument("--workload", type=float, default=16.0)
    ap.add_argument("--deadline", type=int, default=6)
    ap.add_argument("--n-max", type=int, default=8)
    ap.add_argument("--value", type=float, default=40.0)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--steps-per-unit", type=float, default=2.0)
    ap.add_argument("--bandwidth-mbps", type=float, default=800.0)
    ap.add_argument("--market-seed", type=int, default=0)
    ap.add_argument("--report", default="")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                       lr=args.lr, total_steps=10_000)
    job = JobConfig(workload=args.workload, deadline=args.deadline,
                    n_min=1, n_max=args.n_max, value=args.value)
    tput = calibrate(cfg, bandwidth_bps=args.bandwidth_mbps * 1e6)
    trace = vast_like_trace(seed=args.market_seed, days=2)
    pred = None
    if args.policy == "ahap":
        predictor = {
            "perfect": lambda: PerfectPredictor(trace),
            "arima": lambda: ARIMAPredictor(trace),
            "noisy": lambda: NoisyPredictor(trace, "fixed_uniform", args.noise),
        }[args.predictor]()
        pred = predictor.matrix(5)

    policy = POLICIES[args.policy](args)
    trainer = ElasticTrainer(
        cfg, tcfg, job, tput, policy, trace, pred,
        steps_per_unit=args.steps_per_unit,
        bandwidth_bps=args.bandwidth_mbps * 1e6,
    )
    rep = trainer.run()
    print(f"[train] {cfg.name} policy={args.policy} "
          f"utility={rep.utility:.2f} cost={rep.cost:.2f} "
          f"T={rep.completion_time:.2f}/{job.deadline} steps={rep.total_steps} "
          f"loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f}")
    for s in rep.slots:
        print(f"  slot {s.t}: od={s.n_od} spot={s.n_spot} price={s.price:.2f} "
              f"mu={s.mu:.2f} steps={s.steps} loss={s.mean_loss:.3f} "
              f"reconfig={s.reconfig_s:.1f}s")
    if args.report:
        with open(args.report, "w") as f:
            json.dump({
                "utility": rep.utility, "cost": rep.cost,
                "completion_time": rep.completion_time,
                "total_steps": rep.total_steps, "losses": rep.losses,
            }, f)


if __name__ == "__main__":
    main()
