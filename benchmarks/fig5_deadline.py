"""Fig. 5: utility vs deadline — the paper's headline comparison.

At the representative deadline=10 the paper reports AHAP improving utility by
49.0% / 54.8% / 33.4% / 23.2% over OD-Only / MSU / UP / AHANP. We sweep
deadlines {7, 8, 10, 12, 14} over many (job, trace-window) pairs with 10%
fixed-magnitude uniform prediction noise and report the measured
improvements at d=10.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import PAPER_JOB, PAPER_TPUT, best_of_family_utilities, paper_market, timed, windows

N_JOBS = 96


def run() -> list:
    rng = np.random.default_rng(0)
    trace = paper_market(seed=11)
    rows = []
    at10 = None
    for d in (7, 8, 10, 12, 14):
        jobs = [dataclasses.replace(PAPER_JOB, deadline=d) for _ in range(N_JOBS)]
        trs = windows(trace, N_JOBS, d, rng)
        u, us = timed(best_of_family_utilities, jobs, trs, PAPER_TPUT)
        rows.append((f"fig5_d{d}_ahap_utility", us, u[0]))
        rows.append((f"fig5_d{d}_ahanp_utility", us, u[1]))
        rows.append((f"fig5_d{d}_od_utility", us, u[2]))
        rows.append((f"fig5_d{d}_msu_utility", us, u[3]))
        rows.append((f"fig5_d{d}_up_utility", us, u[4]))
        if d == 10:
            at10 = u
    # headline improvements at deadline = 10 (paper: 49.0/54.8/33.4/23.2 %)
    ahap = at10[0]
    for i, name in [(2, "od"), (3, "msu"), (4, "up"), (1, "ahanp")]:
        base = at10[i]
        imp = 100.0 * (ahap - base) / abs(base) if abs(base) > 1e-9 else np.inf
        rows.append((f"fig5_improvement_over_{name}_pct", 0.0, imp))

    # the paper's literal (mu-blind, zero-margin) MSU variant at d=10: this
    # is the baseline its -54.8% headline punishes; our default MSU adds a
    # one-slot safety margin and is far stronger (EXPERIMENTS.md)
    from repro.core.policies import MSUWeak
    from repro.core.simulator import simulate

    jobs = [dataclasses.replace(PAPER_JOB, deadline=10) for _ in range(N_JOBS)]
    trs = windows(trace, N_JOBS, 10, np.random.default_rng(0))
    uw = float(np.mean([
        simulate(MSUWeak(), j, PAPER_TPUT, t).utility for j, t in zip(jobs, trs)
    ]))
    rows.append(("fig5_d10_msu_weak_utility", 0.0, uw))
    rows.append((
        "fig5_improvement_over_msu_weak_pct", 0.0,
        100.0 * (ahap - uw) / abs(uw) if abs(uw) > 1e-9 else np.inf,
    ))
    return rows
