"""Substrate tests: optimizer, schedules, checkpoint, data, sharding, losses."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    checkpoint_bytes,
    restore,
    save,
    serialize,
    deserialize,
    transfer_seconds,
)
from repro.configs import get_config
from repro.data import ShardedLMLoader, lm_batches
from repro.optim import adamw, warmup_cosine
from repro.sharding import (
    Param,
    axes_to_str,
    resolve_spec,
    split_params,
    str_to_axes,
    tree_shardings,
)
from repro.train.losses import cross_entropy


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def test_adamw_first_step_is_lr_sized():
    p = [jnp.array([1.0, -2.0])]
    g = [jnp.array([0.5, -0.5])]
    st = adamw.init(p)
    p2, st2 = adamw.update(g, st, p, lr=0.1)
    # bias-corrected first step ~= lr * sign(g)
    np.testing.assert_allclose(np.asarray(p2[0]), [0.9, -1.9], atol=1e-4)
    assert int(st2.step) == 1


def test_adamw_converges_quadratic():
    p = [jnp.array(5.0)]
    st = adamw.init(p)
    for _ in range(300):
        g = [2.0 * p[0]]
        p, st = adamw.update(g, st, p, lr=0.05)
    assert abs(float(p[0])) < 0.05


def test_clip_by_global_norm():
    t = [jnp.full((4,), 3.0)]
    clipped, norm = adamw.clip_by_global_norm(t, 1.0)
    assert float(norm) == pytest.approx(6.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.asarray(s), base_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert max(lrs) <= 1.0
    assert lrs[-1] < 0.2
    assert lrs[-1] >= 0.1 * 0.99  # final_frac floor


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_exact():
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.bfloat16) * 1.5, "d": jnp.int32(7)},
    }
    blob = serialize(tree, {"k": 1})
    back, meta = deserialize(blob, tree)
    assert meta == {"k": 1}
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_checkpoint_file_roundtrip(tmp_path):
    tree = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    p = str(tmp_path / "x.ckpt")
    n = save(p, tree)
    assert n > 0 and os.path.exists(p)
    back, _ = restore(p, tree)
    np.testing.assert_array_equal(
        np.asarray(back["w"], np.float32), np.ones((8, 8), np.float32)
    )


def test_switching_cost_matches_paper_numbers():
    """Paper Sec. II-A: LLaMA2-7B checkpoint = 0.58 s @ 200 Gbps RDMA and
    1152 s @ 100 Mbps."""
    cfg = get_config("llama2-7b")
    assert transfer_seconds(cfg, 200e9) == pytest.approx(0.58, rel=0.15)
    assert transfer_seconds(cfg, 100e6) == pytest.approx(1152.0, rel=0.15)
    assert checkpoint_bytes(cfg) == pytest.approx(14.0e9, rel=0.15)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_loader_deterministic_restart():
    l1 = ShardedLMLoader(512, 4, 32, seed=1)
    l2 = ShardedLMLoader(512, 4, 32, seed=1)
    b7a = l1.batch_at(7)
    b7b = l2.batch_at(7)  # fresh instance, same step -> same batch
    np.testing.assert_array_equal(b7a["tokens"], b7b["tokens"])
    assert not np.array_equal(l1.batch_at(8)["tokens"], b7a["tokens"])


def test_loader_host_slice():
    l = ShardedLMLoader(512, 8, 16, seed=0)
    b = l.batch_at(0)
    s0 = l.host_slice(b, 0, 4)["tokens"]
    s3 = l.host_slice(b, 3, 4)["tokens"]
    np.testing.assert_array_equal(s0, b["tokens"][:2])
    np.testing.assert_array_equal(s3, b["tokens"][6:])


def test_lm_batches_shapes():
    it = lm_batches(100, 2, 16, num_batches=3)
    bs = list(it)
    assert len(bs) == 3
    for b in bs:
        assert b["tokens"].shape == (2, 16)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


# ---------------------------------------------------------------------------
# Sharding
# ---------------------------------------------------------------------------

def test_resolve_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"heads": ("model",), "fsdp": ("data",), "batch": ("pod", "data")}
    # all extents are 1 -> everything resolves (divides trivially)
    spec = resolve_spec(("fsdp", "heads"), (64, 28), mesh, rules)
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_resolve_spec_drops_nondividing():
    import jax.sharding as js

    devs = np.array(jax.devices() * 1)  # 1 device: fake a bigger mesh check via math
    # use abstract mesh via jax.make_mesh on 1 device won't give 16; test the
    # arithmetic with a mesh of shape (1,1) but simulated sizes via rules:
    # instead directly exercise the helper with a real multi-extent mesh is
    # impossible on 1 CPU device, so check the no-reuse rule:
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = resolve_spec(("batch", "fsdp"), (8, 8), mesh,
                        {"batch": ("data",), "fsdp": ("data",)})
    # 'data' must not be used twice
    assert spec[0] == "data" and spec[1] is None


def test_axes_string_roundtrip():
    # named axes roundtrip exactly
    for axes in [("vocab", "fsdp"), ("layers", None, "tensor"), (None, "model")]:
        assert str_to_axes(axes_to_str(axes)) == tuple(axes)
    # all-None collapses to () — both mean "replicate" (tree_shardings pads)
    assert str_to_axes(axes_to_str(())) == ()
    assert str_to_axes(axes_to_str((None,))) == ()


def test_param_survives_eval_shape():
    def init():
        return {"w": Param(jnp.zeros((4, 8)), ("fsdp", "tensor"))}

    abs_tree = jax.eval_shape(init)
    vals, axes = split_params(abs_tree)
    assert vals["w"].shape == (4, 8)
    assert axes["w"] == "fsdp,tensor"


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def test_cross_entropy_perfect_prediction():
    logits = jnp.full((1, 3, 5), -20.0).at[0, jnp.arange(3), jnp.array([1, 2, 3])].set(20.0)
    loss = cross_entropy(logits, jnp.array([[1, 2, 3]]))
    assert float(loss) < 1e-3


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 5))
    targets = jnp.array([[0, 1, 2, 3]])
    mask = jnp.array([[True, True, False, False]])
    full = cross_entropy(logits, targets)
    masked = cross_entropy(logits, targets, mask)
    assert float(full) == pytest.approx(float(masked))  # uniform logits
    # degenerate all-masked -> finite
    none = cross_entropy(logits, targets, jnp.zeros_like(mask))
    assert np.isfinite(float(none))
