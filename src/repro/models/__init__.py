from repro.models.transformer import (
    cache_axes,
    decode_step,
    forward,
    init_cache,
    init_model,
    init_params,
    prefill,
)
