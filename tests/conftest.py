import os

# Tests must see the single real CPU device — the 512-device forcing is
# strictly dry-run-only (python -m repro.launch.dryrun in a subprocess), and
# the multi-device sharded-pool parity tests force their own device count in
# a subprocess too (tests/test_sharded_pool.py).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")

# Pin the platform before jax initializes: on machines with accelerators the
# suite would otherwise compile for GPU/TPU and drift from the CPU-pinned
# parity/bitwise expectations (setdefault: an explicit caller override wins).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

import jax

# The suite's bitwise pins assume f32/i32 leaves; make the x64 default
# explicit rather than inherited from the environment (JAX_ENABLE_X64 etc).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
