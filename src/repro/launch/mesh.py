"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run forces 512 host devices *before* first jax init).

Production topology (TPU v5e):
  single-pod:  (data=16, model=16)            = 256 chips
  multi-pod:   (pod=2, data=16, model=16)     = 512 chips
One scheduler "instance" (paper's n_t) maps to one data-axis shard; the
16-way model axis is the intra-instance tensor parallelism held fixed.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh():
    """1-device mesh with the production axis names (tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_pool_mesh(devices=None):
    """1-D mesh for the policy-pool simulator: jobs ride the single mesh
    axis (``"jobs"``), lanes stay whole per device — the kind-partitioned
    lane split already balances DP-heavy vs cheap work within each device.
    Defaults to every visible device; works unchanged on 1 CPU device
    (tests), a forced-multi-device host, and a TPU slice."""
    from jax.sharding import Mesh
    import numpy as np

    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.asarray(devices), ("jobs",))
