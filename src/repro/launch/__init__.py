from repro.launch.mesh import make_cpu_mesh, make_production_mesh
