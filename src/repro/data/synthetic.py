"""Synthetic fine-tuning data: a deterministic token stream with enough
structure that LM loss visibly decreases (bigram-ish Markov source), plus
instruction-style (prompt, completion) pairs with loss masks.

Real deployments would swap this for a tokenized corpus reader; everything
downstream (packing, sharding, elastic trainer) is source-agnostic.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np


class MarkovLM:
    """Order-1 Markov chain over the vocab with a few latent 'topics'."""

    def __init__(self, vocab_size: int, seed: int = 0, n_topics: int = 4):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        self.n_topics = n_topics
        # sparse-ish transition structure: each token has ~16 likely successors
        self.succ = rng.integers(0, vocab_size, size=(n_topics, vocab_size, 16))
        self.topic_stick = 0.995

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, np.int64)
        tok = int(rng.integers(self.vocab))
        topic = int(rng.integers(self.n_topics))
        for i in range(length):
            out[i] = tok
            if rng.random() > self.topic_stick:
                topic = int(rng.integers(self.n_topics))
            if rng.random() < 0.9:
                tok = int(self.succ[topic, tok, rng.integers(16)])
            else:
                tok = int(rng.integers(self.vocab))
        return out


def token_stream(
    vocab_size: int, seq_len: int, seed: int = 0, doc_len: int = 512
) -> Iterator[np.ndarray]:
    """Infinite stream of (seq_len,) int32 sequences (packed docs)."""
    src = MarkovLM(vocab_size, seed)
    rng = np.random.default_rng(seed + 1)
    buf = np.empty(0, np.int64)
    while True:
        while len(buf) < seq_len:
            buf = np.concatenate([buf, src.sample(rng, doc_len)])
        yield buf[:seq_len].astype(np.int32)
        buf = buf[seq_len:]


def lm_batches(
    vocab_size: int,
    global_batch: int,
    seq_len: int,
    seed: int = 0,
    num_batches: Optional[int] = None,
) -> Iterator[dict]:
    """Batches {'tokens': (B, S) int32} for next-token training."""
    stream = token_stream(vocab_size, seq_len, seed)
    i = 0
    while num_batches is None or i < num_batches:
        yield {"tokens": np.stack([next(stream) for _ in range(global_batch)])}
        i += 1
