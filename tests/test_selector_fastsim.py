"""Online policy selection (Alg. 2 / Thm. 2) + fast-sim parity + Thm. 1 trend."""
import numpy as np
import pytest

from _hyp_compat import given, settings, st
from repro.configs.base import JobConfig, ThroughputConfig
from repro.core import fast_sim
from repro.core.job import normalize_utility
from repro.core.market import constant_trace, vast_like_trace
from repro.core.offline_opt import solve_offline
from repro.core.policies import AHAP, AHAPParams, RandDeadline, RandDeadlineParams
from repro.core.policies import uniform_commit_frac
from repro.core.policy_pool import (
    baseline_specs,
    paper_pool,
    rand_deadline_pool,
    robust_pool,
    specs_to_arrays,
    uniform_rand_deadline_pool,
)
from repro.core.predictor import NoisyPredictor, PerfectPredictor
from repro.core.selector import (
    best_policy,
    init_selector,
    regret,
    regret_bound,
    select,
    update,
)
from repro.core.simulator import simulate

JOB = JobConfig(workload=80, deadline=10, n_min=1, n_max=12, value=120.0)
TPUT = ThroughputConfig(mu1=0.9, mu2=0.95)


# ---------------------------------------------------------------------------
# Theorem 2: regret bound
# ---------------------------------------------------------------------------

def test_regret_bound_random_utilities():
    rng = np.random.default_rng(0)
    M, K = 20, 400
    st = init_selector(M, K)
    means = rng.uniform(0.2, 0.8, M)
    for _ in range(K):
        u = np.clip(rng.normal(means, 0.1), 0, 1)
        st = update(st, u)
    assert regret(st) <= regret_bound(M, K), (regret(st), regret_bound(M, K))
    assert best_policy(st) == int(np.argmax(means))


def test_regret_bound_adversarial_switching():
    """Alternating adversary: bound must still hold."""
    M, K = 8, 300
    st = init_selector(M, K)
    for k in range(K):
        u = np.zeros(M)
        u[k % M] = 1.0
        st = update(st, u)
    assert regret(st) <= regret_bound(M, K) + 1e-9


def test_selector_converges_to_best():
    M, K = 10, 600
    st = init_selector(M, K)
    for _ in range(K):
        u = np.full(M, 0.4)
        u[3] = 0.6
        st = update(st, u)
    assert best_policy(st) == 3
    assert st.weights[3] > 0.9


def test_select_samples_from_weights():
    st = init_selector(4, 10)
    st.weights = np.array([0.0, 0.0, 1.0, 0.0])
    rng = np.random.default_rng(0)
    assert select(st, rng) == 2


# ---------------------------------------------------------------------------
# fast_sim parity with the reference simulator
# ---------------------------------------------------------------------------

def test_fast_sim_matches_reference():
    pool = paper_pool(omegas=(1, 3, 5), sigmas=(0.3, 0.7)) + baseline_specs()
    arrs = specs_to_arrays(pool)
    for seed in range(2):
        tr = vast_like_trace(seed=seed, days=1).window(0, 10)
        pred = NoisyPredictor(tr, "fixed_uniform", 0.2, seed=seed).matrix(
            fast_sim.W1MAX - 1
        )
        prices, avail, pm = fast_sim.prepare_inputs(tr, pred, JOB.deadline)
        out = fast_sim.simulate_pool(
            arrs, fast_sim.JobArrays.of(JOB), TPUT, prices, avail, pm
        )
        uj = np.asarray(out["utility"])
        for i, spec in enumerate(pool):
            r = simulate(spec.build(), JOB, TPUT, tr,
                         pred if spec.kind == 0 else None)
            assert abs(r.utility - uj[i]) < 1e-2, (spec.name, r.utility, uj[i])


def test_fast_sim_robust_ahap_matches_reference():
    """Robust-AHAP (rho < 1.0): the availability-discounted AHAP lanes must
    match the python AHAP policy (rho passes through AHAPParams) exactly —
    only the rho == 1.0 paths were cross-checked before."""
    pool = robust_pool(rhos=(0.5, 0.85), omegas=(3,), sigmas=(0.5, 0.9))
    assert all(s.rho < 1.0 for s in pool)
    arrs = specs_to_arrays(pool)
    for seed in range(2):
        tr = vast_like_trace(seed=10 + seed, days=1).window(0, 10)
        pred = NoisyPredictor(tr, "fixed_uniform", 0.3, seed=seed).matrix(
            fast_sim.W1MAX - 1
        )
        prices, avail, pm = fast_sim.prepare_inputs(tr, pred, JOB.deadline)
        out = fast_sim.simulate_pool(
            arrs, fast_sim.JobArrays.of(JOB), TPUT, prices, avail, pm
        )
        uj = np.asarray(out["utility"])
        for i, spec in enumerate(pool):
            r = simulate(spec.build(), JOB, TPUT, tr, pred)
            assert abs(r.utility - uj[i]) < 1e-2, (spec.name, r.utility, uj[i])


def test_fast_sim_rand_deadline_matches_reference():
    """RAND_DEADLINE lanes (randomized commitment thresholds,
    arXiv:2601.14612) must match the python RandDeadline policy on the cheap
    scan — including the f32 tau = floor(cfrac * d) commitment slot."""
    pool = rand_deadline_pool((0.1, 0.3, 0.55, 0.8, 0.95))
    arrs = specs_to_arrays(pool)
    for seed in range(3):
        tr = vast_like_trace(seed=20 + seed, days=1).window(0, 10)
        prices, avail, pm = fast_sim.prepare_inputs(tr, None, JOB.deadline)
        out = fast_sim.simulate_pool(
            arrs, fast_sim.JobArrays.of(JOB), TPUT, prices, avail, pm
        )
        uj = np.asarray(out["utility"])
        for i, spec in enumerate(pool):
            r = simulate(spec.build(), JOB, TPUT, tr)
            assert abs(r.utility - uj[i]) < 1e-2, (spec.name, r.utility, uj[i])


def test_fast_sim_uniform_rand_deadline_matches_reference():
    """The non-ski-rental RAND_DEADLINE family: quantile function F^{-1}(q)=q
    rides the pool's cfrac hook (rand_deadline_pool(qs, qfn=...)). The fast
    lanes must match the python RandDeadline built with the same explicit
    commitment fraction — and the encoding must be the identity, distinct
    from the ski-rental family's log1p curve."""
    qs = (0.1, 0.35, 0.6, 0.85)
    pool = uniform_rand_deadline_pool(qs)
    arrs = specs_to_arrays(pool)
    np.testing.assert_allclose(arrs["cfrac"], np.float32(qs))
    ski = specs_to_arrays(rand_deadline_pool(qs))["cfrac"]
    assert np.all(np.abs(arrs["cfrac"] - ski) > 1e-3)  # genuinely different
    assert [uniform_commit_frac(q) for q in qs] == list(qs)
    with pytest.raises(ValueError):  # a negative fraction would silently
        rand_deadline_pool((0.5,), qfn=lambda q: q - 1.0)  # hit the sentinel
    for seed in range(3):
        tr = vast_like_trace(seed=40 + seed, days=1).window(0, 10)
        prices, avail, pm = fast_sim.prepare_inputs(tr, None, JOB.deadline)
        out = fast_sim.simulate_pool(
            arrs, fast_sim.JobArrays.of(JOB), TPUT, prices, avail, pm
        )
        uj = np.asarray(out["utility"])
        for i, spec in enumerate(pool):
            r = simulate(spec.build(), JOB, TPUT, tr)
            assert abs(r.utility - uj[i]) < 1e-2, (spec.name, r.utility, uj[i])


@settings(max_examples=15, deadline=None)
@given(q=st.floats(0.02, 0.98), seed=st.integers(0, 500))
def test_rand_deadline_utility_and_feasibility(q, seed):
    """Properties of the randomized-commitment strategy: utility can never
    exceed the job value (cost >= 0), and every slot's decision respects the
    N^max / availability envelope on arbitrary markets."""
    rng = np.random.default_rng(seed)
    tr = vast_like_trace(seed=int(rng.integers(0, 10_000)), days=1).window(0, 10)
    r = simulate(RandDeadline(RandDeadlineParams(q)), JOB, TPUT, tr)
    assert r.utility <= JOB.value + 1e-6
    assert np.all(r.n_total <= JOB.n_max)
    assert np.all(r.n_spot <= np.asarray(tr.avail[: JOB.deadline], int))
    assert np.all(r.n_od >= 0) and np.all(r.n_spot >= 0)


@settings(max_examples=15, deadline=None)
@given(q=st.floats(0.02, 0.98))
def test_rand_deadline_feasible_market_meets_deadline(q):
    """Deadline feasibility: on a market with plentiful cheap spot the
    commitment strategy must finish by the deadline for EVERY quantile —
    pre-commitment it rides N^max spot, post-commitment it sizes on-demand
    to the remaining workload, and the capacity envelope
    (mu1 + (d-1)) * alpha * N^max covers L with a wide margin."""
    tr = constant_trace(price=0.3, avail=JOB.n_max, length=JOB.deadline + 1)
    r = simulate(RandDeadline(RandDeadlineParams(q)), JOB, TPUT, tr)
    assert r.completed_by_deadline, (q, r.completion_time)
    assert r.completion_time <= JOB.deadline
    assert r.utility <= JOB.value + 1e-6


def test_fast_sim_batched_lanes_match_vmap_oracle():
    """The lane-batched AHAP scan (one solve_window_batch call per slot)
    is bitwise-pinned to vmapping the per-lane scan (_simulate_one_ahap,
    the pre-batching formulation kept as the equivalence oracle), on the
    XLA and Pallas-interpret backends."""
    import jax
    import jax.numpy as jnp

    pool = [s for s in paper_pool(omegas=(1, 3, 5), sigmas=(0.3, 0.7))
            if s.kind == 0]
    arrs = specs_to_arrays(pool)
    w, v = jnp.asarray(arrs["omega"]), jnp.asarray(arrs["v"])
    sg, rho = jnp.asarray(arrs["sigma"]), jnp.asarray(arrs["rho"])
    tr = vast_like_trace(seed=8, days=1).window(0, 10)
    pred = NoisyPredictor(tr, "fixed_uniform", 0.2, seed=8).matrix(
        fast_sim.W1MAX - 1
    )
    prices, avail, pm = fast_sim.prepare_inputs(tr, pred, JOB.deadline)
    j = fast_sim.JobArrays.of(JOB)
    oracle = jax.vmap(
        lambda a, b, c, d: fast_sim._simulate_one_ahap(
            a, b, c, d, j, TPUT, prices, avail, pm, "xla"
        )
    )(w, v, sg, rho)
    for backend in ("xla", "pallas-interpret"):
        batched = fast_sim._simulate_lanes_ahap(
            w, v, sg, rho, j, TPUT, prices, avail, pm, backend
        )
        for k in oracle:
            np.testing.assert_array_equal(
                np.asarray(batched[k]), np.asarray(oracle[k]),
                err_msg=f"{k} [{backend}]",
            )


def test_fast_sim_partitioned_matches_monolithic():
    """The kind-partitioned pool path is bitwise-pinned to the seed
    monolithic path (same lanes, same order, same leaves) — RAND_DEADLINE
    lanes included."""
    pool = (paper_pool(omegas=(2, 4), sigmas=(0.4, 0.8))
            + rand_deadline_pool((0.2, 0.6)) + baseline_specs())
    arrs = specs_to_arrays(pool)
    tr = vast_like_trace(seed=5, days=1).window(0, 10)
    pred = NoisyPredictor(tr, "fixed_uniform", 0.2, seed=5).matrix(
        fast_sim.W1MAX - 1
    )
    prices, avail, pm = fast_sim.prepare_inputs(tr, pred, JOB.deadline)
    j = fast_sim.JobArrays.of(JOB)
    mono = fast_sim.simulate_pool_monolithic(arrs, j, TPUT, prices, avail, pm)
    part = fast_sim.simulate_pool(arrs, j, TPUT, prices, avail, pm)
    for k in mono:
        np.testing.assert_array_equal(
            np.asarray(mono[k]), np.asarray(part[k]), err_msg=k
        )


def test_pool_sizes_match_paper():
    assert len(paper_pool()) == 112          # 105 AHAP + 7 AHANP (unchanged)
    assert len(paper_pool(include_ahanp=False)) == 105
    assert len(paper_pool(fixed_v=1, include_ahanp=False)) == 35  # 5 omegas x 7 sigmas
    assert len(rand_deadline_pool()) == 9    # opt-in expansion: one per quantile
    assert len(paper_pool(rand_qs=(0.2, 0.5, 0.8))) == 115
    assert all(s.kind == 5 for s in rand_deadline_pool())


# ---------------------------------------------------------------------------
# Theorem 1 (empirical): gap to OPT shrinks with prediction error
# ---------------------------------------------------------------------------

def test_theorem1_gap_decreases_with_accuracy():
    gaps = {}
    for level in [0.0, 0.6]:
        g = []
        for seed in range(6):
            tr = vast_like_trace(seed=100 + seed, days=1).window(0, 10)
            opt = solve_offline(JOB, TPUT, tr)
            if level == 0.0:
                pred = PerfectPredictor(tr).matrix(5)
            else:
                pred = NoisyPredictor(tr, "magdep_heavytail", level, seed=seed).matrix(5)
            r = simulate(AHAP(AHAPParams(3, 1, 0.7)), JOB, TPUT, tr, pred)
            g.append(opt.utility - r.utility)
        gaps[level] = float(np.mean(g))
    assert gaps[0.0] <= gaps[0.6] + 1e-6, gaps
    assert gaps[0.0] >= -0.35  # OPT really is (near-)optimal


def test_normalized_utilities_feed_selector():
    tr = vast_like_trace(seed=0, days=1).window(0, 10)
    pool = paper_pool(omegas=(2,), sigmas=(0.5,))
    arrs = specs_to_arrays(pool)
    pred = PerfectPredictor(tr).matrix(fast_sim.W1MAX - 1)
    prices, avail, pm = fast_sim.prepare_inputs(tr, pred, JOB.deadline)
    out = fast_sim.simulate_pool(
        arrs, fast_sim.JobArrays.of(JOB), TPUT, prices, avail, pm
    )
    u = np.asarray(normalize_utility(JOB, out["utility"]))
    assert np.all((u >= 0) & (u <= 1))
