"""Zamba2-2.7B [arXiv:2411.15242] — hybrid: Mamba2 backbone + shared attention block.

54 Mamba2 layers; one *shared* (weight-tied) transformer block is applied every
``hybrid_period`` layers (9 applications). We scan over 9 super-blocks of
6 Mamba2 layers each, with the shared block's params closed over (not scanned).
"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        arch_type="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        rope_theta=10000.0,
        norm_type="rmsnorm",
        mlp_act="silu",
        ssm=SSMConfig(
            state_size=64,
            head_dim=64,
            expand=2,          # d_inner = 5120 -> 80 SSD heads
            n_groups=1,
            conv_width=4,
            chunk_size=256,
        ),
        hybrid_period=6,
        source="arXiv:2411.15242",
    )


def smoke_config() -> ModelConfig:
    return config().reduced()
