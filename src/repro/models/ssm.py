"""Mamba2 block — SSD (state-space duality) with chunked computation
[arXiv:2405.21060].

Recurrence per head h (A scalar-per-head, state (P, N)):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t  (outer) x_t
    y_t = C_t . h_t + D * x_t

The chunked algorithm computes, per chunk of length ``cs``:
  * intra-chunk (quadratic in cs): mask L_ij = exp(cum_i - cum_j), i >= j
  * chunk-end states + an inter-chunk lax.scan (linear in #chunks)
matching the reference recurrence exactly (test_ssm.py checks vs a step-by-
step scan oracle). The chunk-state stage is the TPU Pallas kernel target
(`repro/kernels/ssd_scan.py`).

Decode keeps O(1) state: depthwise-conv ring (width-1 frames) + (H, P, N)
SSD state — this is why SSM/hybrid archs run `long_500k` natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lora as lora_lib
from repro.models.common import normal_param, ones_param, zeros_param
from repro.sharding import Param, shard


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_mamba(key, cfg, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.heads(d)
    G, N, wc = s.n_groups, s.state_size, s.conv_width
    ks = jax.random.split(key, 10)
    p = {
        "wz": normal_param(ks[0], (d, di), ("fsdp", "tensor"), dtype),
        "wx": normal_param(ks[1], (d, di), ("fsdp", "tensor"), dtype),
        "wB": normal_param(ks[2], (d, G, N), ("fsdp", None, None), dtype),
        "wC": normal_param(ks[3], (d, G, N), ("fsdp", None, None), dtype),
        "wdt": normal_param(ks[4], (d, H), ("fsdp", "ssm_heads"), dtype),
        "conv_w": normal_param(ks[5], (di + 2 * G * N, wc), ("tensor", None), dtype, stddev=0.3),
        "conv_b": zeros_param((di + 2 * G * N,), ("tensor",), dtype),
        # A in (-inf, 0): A = -exp(A_log); init A in [-1, -e]
        "A_log": Param(
            jnp.log(jnp.linspace(1.0, jnp.e, H, dtype=jnp.float32)), ("ssm_heads",)
        ),
        "D": ones_param((H,), ("ssm_heads",), jnp.float32),
        "dt_bias": Param(
            jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))), ("ssm_heads",)
        ),
        "norm_scale": ones_param((di,), ("tensor",), dtype),
        "out_proj": normal_param(ks[6], (di, d), ("tensor", "fsdp"), dtype),
    }
    # LoRA on the in/out projections (attention-free arch; DESIGN.md §3)
    r = cfg.lora.rank
    p["lora"] = {
        "in": lora_lib.init_lora_pair(ks[7], d, (di,), r),
        "out": lora_lib.init_lora_pair(ks[8], di, (d,), r),
    }
    return p


# ---------------------------------------------------------------------------
# Depthwise causal conv
# ---------------------------------------------------------------------------

def _causal_conv(xbc, w, b):
    """xbc:(B,S,C), w:(C,wc) depthwise causal conv + silu."""
    wc = w.shape[1]
    pad = jnp.pad(xbc, ((0, 0), (wc - 1, 0), (0, 0)))
    # stack the wc shifted views: (B,S,C,wc)
    views = jnp.stack([pad[:, i : i + xbc.shape[1]] for i in range(wc)], axis=-1)
    y = jnp.einsum("bscw,cw->bsc", views, w.astype(views.dtype)) + b
    return jax.nn.silu(y)


def _conv_step(state, xbc_t, w, b):
    """state:(B,wc-1,C), xbc_t:(B,1,C) -> (new_state, y:(B,1,C))."""
    window = jnp.concatenate([state, xbc_t], axis=1)  # (B, wc, C)
    y = jnp.einsum("bwc,cw->bc", window, w.astype(window.dtype)) + b
    return window[:, 1:], jax.nn.silu(y)[:, None]


# ---------------------------------------------------------------------------
# SSD core (chunked)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """SSD scan.

    x: (B,S,H,P) inputs, dt: (B,S,H) positive step sizes, A: (H,) negative,
    B, C: (B,S,G,N); returns y:(B,S,H,P) and final state (B,H,P,N).
    """
    b, s, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    cs = min(chunk, s)
    orig_s = s
    if s % cs:
        # zero-pad the tail: dt=0 steps are identities (decay=1, no input)
        pad = cs - s % cs
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // cs

    xf = x.astype(jnp.float32).reshape(b, nc, cs, H, P)
    dtf = dt.astype(jnp.float32).reshape(b, nc, cs, H)
    Bf = B.astype(jnp.float32).reshape(b, nc, cs, G, N)
    Cf = C.astype(jnp.float32).reshape(b, nc, cs, G, N)

    da = dtf * A  # (b, nc, cs, H), negative
    cum = jnp.cumsum(da, axis=2)  # inclusive

    # ---- intra-chunk (quadratic in cs) ----
    # scores over matching groups: (b,nc,i,j,G)
    gb = jnp.einsum("bcign,bcjgn->bcijg", Cf, Bf)
    # expand groups to heads
    gb = jnp.repeat(gb, rep, axis=-1)  # (b,nc,i,j,H)
    L = jnp.exp(
        jnp.clip(cum[:, :, :, None, :] - cum[:, :, None, :, :], -60.0, 0.0)
    )  # (b,nc,i,j,H); >0 only meaningful for i>=j
    causal = jnp.tril(jnp.ones((cs, cs), bool))
    m = gb * L * jnp.where(causal[None, None, :, :, None], 1.0, 0.0)
    m = m * dtf[:, :, None, :, :]  # dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xf)

    # ---- chunk-end states ----
    decay_to_end = jnp.exp(jnp.clip(cum[:, :, -1:, :] - cum, -60.0, 0.0))  # (b,nc,cs,H)
    Bh = jnp.repeat(Bf, rep, axis=3) if G != H else Bf  # (b,nc,cs,H,N)
    states = jnp.einsum(
        "bcjh,bcjhn,bcjhp->bchpn", decay_to_end * dtf, Bh, xf
    )  # (b,nc,H,P,N)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(jnp.clip(cum[:, :, -1, :], -60.0, 0.0))  # (b,nc,H)

    def step(h, inp):
        dec, st = inp  # (b,H), (b,H,P,N)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h  # emit state *entering* the chunk

    init = h0 if h0 is not None else jnp.zeros((b, H, P, N), jnp.float32)
    h_final, h_prev = jax.lax.scan(
        step, init, (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1))
    )
    h_prev = h_prev.swapaxes(0, 1)  # (b,nc,H,P,N) state entering each chunk

    # ---- inter-chunk contribution ----
    Ch = jnp.repeat(Cf, rep, axis=3) if G != H else Cf  # (b,nc,cs,H,N)
    decay_in = jnp.exp(jnp.clip(cum, -60.0, 0.0))  # (b,nc,cs,H)
    y_inter = jnp.einsum("bcihn,bchpn,bcih->bcihp", Ch, h_prev, decay_in)

    y = (y_intra + y_inter).reshape(b, s, H, P)[:, :orig_s]
    return y.astype(x.dtype), h_final


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """One decode step. state:(B,H,P,N); x_t:(B,H,P); dt_t:(B,H); B_t,C_t:(B,G,N)."""
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t, rep, axis=1)  # (B,H,N)
    Ch = jnp.repeat(C_t, rep, axis=1)
    da = jnp.exp(jnp.clip(dt_t.astype(jnp.float32) * A, -60.0, 0.0))  # (B,H)
    new = state * da[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt_t.astype(jnp.float32), Bh.astype(jnp.float32), x_t.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), new)
    return new, y


# ---------------------------------------------------------------------------
# Full block
# ---------------------------------------------------------------------------

def _gated_norm(y, z, scale, eps):
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def _project_inputs(cfg, p, x):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H, G, N = s.heads(d), s.n_groups, s.state_size
    scale = cfg.lora.alpha / cfg.lora.rank
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xin = lora_lib.proj(x, p["wx"], None, p["lora"]["in"], scale)
    Braw = jnp.einsum("bsd,dgn->bsgn", x, p["wB"])
    Craw = jnp.einsum("bsd,dgn->bsgn", x, p["wC"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    return z, xin, Braw, Craw, dt_raw


def apply_mamba(cfg, p, x, h0=None, return_cache=False):
    """x:(B,S,d) -> (B,S,d). Training/prefill path (chunked SSD)."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H, G, N, P = s.heads(d), s.n_groups, s.state_size, s.head_dim
    bsz, S, _ = x.shape

    z, xin, Braw, Craw, dt_raw = _project_inputs(cfg, p, x)
    xbc_raw = jnp.concatenate(
        [xin, Braw.reshape(bsz, S, G * N), Craw.reshape(bsz, S, G * N)], axis=-1
    )
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].reshape(bsz, S, H, P)
    B = xbc[..., di : di + G * N].reshape(bsz, S, G, N)
    C = xbc[..., di + G * N :].reshape(bsz, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    xs = shard(xs, "batch", "seq", "ssm_heads", None)
    y, h_final = ssd_chunked(xs, dt, A, B, C, s.chunk_size, h0=h0)
    y = y + xs.astype(jnp.float32).astype(y.dtype) * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, S, di)

    out = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps)
    scale = cfg.lora.alpha / cfg.lora.rank
    res = lora_lib.proj(out, p["out_proj"], None, p["lora"]["out"], scale)
    if return_cache:
        # conv cache stores the *raw* (pre-conv) last width-1 frames
        wc = s.conv_width
        conv_tail = xbc_raw[:, S - (wc - 1) :] if S >= wc - 1 else jnp.pad(
            xbc_raw, ((0, 0), (wc - 1 - S, 0), (0, 0))
        )
        return res, {"conv": conv_tail, "ssd": h_final}
    return res


def init_mamba_cache(cfg, batch: int, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H, G, N, P = s.heads(d), s.n_groups, s.state_size, s.head_dim
    conv_dim = di + 2 * G * N
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def mamba_cache_specs():
    return {"conv": ("batch", None, "tensor"), "ssd": ("batch", "ssm_heads", None, None)}


def apply_mamba_decode(cfg, p, x_t, cache):
    """x_t:(B,1,d), cache {conv, ssd} -> (y:(B,1,d), new cache)."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H, G, N, P = s.heads(d), s.n_groups, s.state_size, s.head_dim
    bsz = x_t.shape[0]

    z, xin, Braw, Craw, dt_raw = _project_inputs(cfg, p, x_t)
    xbc = jnp.concatenate(
        [xin, Braw.reshape(bsz, 1, G * N), Craw.reshape(bsz, 1, G * N)], axis=-1
    )
    conv_state, xbc = _conv_step(cache["conv"], xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].reshape(bsz, H, P)
    B = xbc[..., di : di + G * N].reshape(bsz, G, N)
    C = xbc[..., di + G * N :].reshape(bsz, G, N)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])

    new_ssd, y = ssd_step(cache["ssd"], xs.astype(jnp.float32), dt, A, B, C)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(bsz, 1, di).astype(x_t.dtype)

    out = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps)
    scale = cfg.lora.alpha / cfg.lora.rank
    res = lora_lib.proj(out, p["out_proj"], None, p["lora"]["out"], scale)
    return res, {"conv": conv_state, "ssd": new_ssd}
