"""Spot market forecasting (paper Fig. 3): ARIMA vs persistence vs the four
noise regimes, on a 10-day Vast.ai-like A100 trace.

    PYTHONPATH=src python examples/market_forecast.py
"""
import numpy as np

from repro.core.market import TraceStats, vast_like_trace
from repro.core.predictor import (
    ARIMAPredictor,
    NOISE_KINDS,
    NoisyPredictor,
    forecast_errors,
    mape,
)

trace = vast_like_trace(seed=6, days=10)
print("trace:", TraceStats.of(trace))

H = 5
arima = forecast_errors(trace, ARIMAPredictor(trace), H)
T = len(trace)
persist_price = [mape(trace.prices[: T - j], trace.prices[j:]) for j in range(1, H + 1)]

print(f"\nprice MAPE by horizon (30-min steps):")
print(f"{'h':>3s} {'persistence':>12s} {'ARIMA':>8s}")
for j in range(H):
    print(f"{j+1:3d} {persist_price[j]:12.3f} {arima['price'][j]:8.3f}")

print(f"\navailability MAPE (ARIMA): "
      f"{[round(x, 3) for x in arima['avail']]}")

print("\nnoise regimes at level=0.3 (mean price MAPE over horizons):")
for kind in NOISE_KINDS:
    e = forecast_errors(trace, NoisyPredictor(trace, kind, 0.3, seed=0), H)
    print(f"  {kind:18s} {np.mean(e['price']):.3f}")
