"""Fine-tuning job model: the paper's four-tuple {L, d, N^min, N^max} plus the
deadline value function V(T) (Eq. 4) and its reformulation Ṽ(Z^ddl) (Eq. 9).

Ṽ absorbs the *termination configuration*: any workload left at the deadline
is finished immediately with N^max on-demand instances, so the value and the
post-deadline cost become functions of Z^ddl only (Sec. III-E.2).

All functions are jnp-compatible (work under jit/vmap) and accept numpy.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import JobConfig, ThroughputConfig


def expected_progress(job: JobConfig, t):
    """Uniform workload slicing Z^exp_t = (L/d) * t (Eq. 6)."""
    return job.workload / job.deadline * t


def value_fn(job: JobConfig, T):
    """V(T), Eq. 4: full value v until d, linear decay to 0 at gamma*d."""
    v, d, g = job.value, job.deadline, job.gamma
    T = jnp.asarray(T, jnp.float32)
    decay = v * (1.0 - (T - d) / ((g - 1.0) * d))
    return jnp.where(T <= d, v, jnp.clip(decay, 0.0, v))


def termination_time(job: JobConfig, tput: ThroughputConfig, z_ddl):
    """Extra (fractional) slots past d to finish L - Z^ddl with N^max on-demand."""
    rate = tput.alpha * job.n_max + tput.beta
    remaining = jnp.maximum(job.workload - jnp.asarray(z_ddl, jnp.float32), 0.0)
    return remaining / rate


def tilde_value(job: JobConfig, tput: ThroughputConfig, z_ddl):
    """Ṽ(Z^ddl), Eq. 9: value at completion minus post-deadline on-demand cost.

    Piecewise-linear in Z^ddl, increasing; NOT concave (slope jumps up at the
    point where completion crosses gamma*d) — the window solver must not
    greedy-stop early (see window_opt.py).
    """
    dt = termination_time(job, tput, z_ddl)
    val = value_fn(job, job.deadline + dt)
    post_cost = job.on_demand_price * job.n_max * dt
    return val - post_cost


def normalization_bounds(job: JobConfig):
    """(u_min, u_max) for the EG selector's normalized utility (Thm. 2 needs
    u in [0,1]). u_max = v; u_min = worst feasible spend with zero value."""
    u_max = job.value
    u_min = -job.on_demand_price * job.n_max * job.gamma * job.deadline
    return u_min, u_max


def normalize_utility(job: JobConfig, u):
    lo, hi = normalization_bounds(job)
    return jnp.clip((u - lo) / (hi - lo), 0.0, 1.0)


def normalization_bounds_batch(jobs):
    """Batched :func:`normalization_bounds`: ``jobs`` carries stacked (K,)
    leaves (fast_sim.JobArrays, or any object with the JobConfig fields) —
    returns ((K,), (K,)) f32 bounds."""
    p_o = getattr(jobs, "p_o", None)
    if p_o is None:
        p_o = jobs.on_demand_price
    u_max = jnp.asarray(jobs.value, jnp.float32)
    u_min = -(jnp.asarray(p_o, jnp.float32)
              * jnp.asarray(jobs.n_max, jnp.float32)
              * jnp.asarray(jobs.gamma, jnp.float32)
              * jnp.asarray(jobs.deadline, jnp.float32))
    return u_min, u_max


def normalize_utility_batch(jobs, u):
    """Map the whole (K, M) raw-utility matrix through the per-job [0, 1]
    normalization in one call (the EG selector's Thm. 2 precondition) —
    the batched twin of looping ``normalize_utility(jobs[k], u[k])``,
    jnp-native so core.engine keeps the matrix on device."""
    lo, hi = normalization_bounds_batch(jobs)
    return jnp.clip((u - lo[:, None]) / (hi - lo)[:, None], 0.0, 1.0)
