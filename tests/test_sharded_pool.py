"""Sharded-vs-single-device parity for the policy-pool simulator.

``simulate_pool_jobs_sharded`` must be BITWISE-equal to
``simulate_pool_jobs`` — per-job lanes are independent and every op is
elementwise over the jobs axis, so laying the job grid over a device mesh
may not change a single bit. The multi-device half runs in a subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (conftest
forbids the forcing flag in the main test process), covering job counts
that divide the mesh, need padding, and undershoot the device count.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

# Runs inside the forced-4-device subprocess. Odd lane count (12 AHAP +
# 3 AHANP + 3 RAND + 3 baselines = 21) exercises the kind partition; job
# counts 1/3/5 exercise the under-, non-dividing- and padding paths of the
# jobs mesh.
_CHILD = r"""
import numpy as np
import jax

assert jax.device_count() == 4, jax.devices()

from benchmarks.common import job_stream
from repro.configs.base import ThroughputConfig
from repro.core import fast_sim
from repro.core.market import vast_like_trace
from repro.core.policy_pool import (
    baseline_specs, paper_pool, rand_deadline_pool, specs_to_arrays,
)
from repro.core.predictor import NoisyPredictor

TPUT = ThroughputConfig(mu1=0.9, mu2=0.95)
pool = (paper_pool(omegas=(1, 3), sigmas=(0.3, 0.7, 0.9))
        + rand_deadline_pool((0.25, 0.5, 0.75)) + baseline_specs())
arrs = specs_to_arrays(pool)
rng = np.random.default_rng(0)
d = 10
for n_jobs in (1, 3, 5):
    jobs = list(job_stream(rng, n_jobs, deadline=d))
    traces = [vast_like_trace(seed=40 + i, days=1).window(0, d + 1)
              for i in range(n_jobs)]
    prices = np.stack([t.prices[:d] for t in traces]).astype(np.float32)
    avail = np.stack([t.avail[:d] for t in traces]).astype(np.int64)
    preds = np.stack([
        NoisyPredictor(t, "fixed_uniform", 0.2, seed=i).matrix(
            fast_sim.W1MAX - 1
        )[:d]
        for i, t in enumerate(traces)
    ]).astype(np.float32)
    stacked = fast_sim.stack_jobs(jobs)
    base = fast_sim.simulate_pool_jobs(arrs, stacked, TPUT, prices, avail, preds)
    sh = fast_sim.simulate_pool_jobs_sharded(
        arrs, stacked, TPUT, prices, avail, preds
    )
    for k in base:
        np.testing.assert_array_equal(
            np.asarray(base[k]), np.asarray(sh[k]),
            err_msg=f"{k} n_jobs={n_jobs}",
        )
print("SHARDED-PARITY-OK")
"""


def test_sharded_matches_single_device_4dev_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [SRC, os.path.dirname(SRC)] + env.get("PYTHONPATH", "").split(os.pathsep)
    ).rstrip(os.pathsep)
    out = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED-PARITY-OK" in out.stdout


def test_sharded_single_device_fallback_bitwise():
    """With one visible device the sharded entry point must fall through to
    (and bitwise-match) simulate_pool_jobs, and accept an explicit 1-device
    mesh."""
    import jax

    from benchmarks.common import job_stream
    from repro.configs.base import ThroughputConfig
    from repro.core import fast_sim
    from repro.core.market import vast_like_trace
    from repro.core.policy_pool import (
        baseline_specs,
        paper_pool,
        rand_deadline_pool,
        specs_to_arrays,
    )
    from repro.core.predictor import NoisyPredictor
    from repro.launch.mesh import make_pool_mesh

    assert jax.device_count() == 1
    tput = ThroughputConfig(mu1=0.9, mu2=0.95)
    pool = (paper_pool(omegas=(2,), sigmas=(0.5,))
            + rand_deadline_pool((0.4,)) + baseline_specs())
    arrs = specs_to_arrays(pool)
    rng = np.random.default_rng(3)
    d = 10
    jobs = list(job_stream(rng, 3, deadline=d))
    traces = [vast_like_trace(seed=60 + i, days=1).window(0, d + 1)
              for i in range(3)]
    prices = np.stack([t.prices[:d] for t in traces]).astype(np.float32)
    avail = np.stack([t.avail[:d] for t in traces]).astype(np.int64)
    preds = np.stack([
        NoisyPredictor(t, "fixed_uniform", 0.2, seed=i).matrix(
            fast_sim.W1MAX - 1
        )[:d]
        for i, t in enumerate(traces)
    ]).astype(np.float32)
    stacked = fast_sim.stack_jobs(jobs)
    base = fast_sim.simulate_pool_jobs(arrs, stacked, tput, prices, avail, preds)
    for mesh in (None, make_pool_mesh()):
        sh = fast_sim.simulate_pool_jobs_sharded(
            arrs, stacked, tput, prices, avail, preds, mesh=mesh
        )
        for k in base:
            np.testing.assert_array_equal(
                np.asarray(base[k]), np.asarray(sh[k]), err_msg=k
            )
