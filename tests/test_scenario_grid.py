"""Scenario-grid harness (benchmarks/scenario_grid.py) and the plumbing it
rides on.

Pins, per the tentpole's contracts:
  * ``market_regime_batch`` bitwise row-parity with per-regime
    ``vast_like_trace`` (the vectorized generator IS the scalar one);
  * one batched-grid cell bitwise-equal to an independent single-regime
    ``simulate_pool_jobs`` run (grid stacking adds nothing and loses
    nothing), in BOTH throughput groups, and with ``job_chunk`` streaming;
  * seed-determinism of the full grid (winner map included);
  * directional sanity across regime axes: scarce availability never
    increases the oracle-best mean utility (the availability axis is a
    pointwise-paired draw), and zero prediction noise weakly dominates
    high noise for the prediction-based (AHAP) lanes;
  * per-row noise levels in ``noisy_matrix_batch`` (scalar parity, level-0
    rows reduce to the perfect forecast);
  * ``concat_jobs`` / ``workload_scale`` round-trips.

The tests use a 13-lane sub-pool (8 AHAP + 2 AHANP + 3 baselines) for
speed; the bench itself runs the full 124-lane pool over 48 regimes.
"""
import numpy as np

from benchmarks import scenario_grid as sg
from benchmarks.common import job_stream_arrays
from repro.configs.base import ThroughputConfig
from repro.core import engine, fast_sim
from repro.core.market import vast_like_trace
from repro.core.policy_pool import (
    KIND_AHAP,
    baseline_specs,
    paper_pool,
    specs_to_arrays,
)
from repro.core.predictor import noisy_matrix_batch, true_future_batch
from repro.data.synthetic import market_regime_batch


def _small_pool():
    pool = paper_pool(omegas=(1, 3), sigmas=(0.3, 0.7)) + baseline_specs()
    return pool, specs_to_arrays(pool)


def _small_grid(n_jobs=6, **axes):
    kw = dict(avail=(3.5, 9.0), sigma=(0.5,), tight=(1.15,),
              mu=((0.9, 0.95), (0.7, 0.85)), noise=(0.3,))
    kw.update(axes)
    regimes = sg.grid_regimes(**kw)
    jobs, prices, avail, preds, t0s = sg.build_grid_inputs(
        regimes, n_jobs=n_jobs
    )
    return regimes, jobs, prices, avail, preds, t0s


def test_market_regime_batch_matches_vast_like_trace():
    """Row r of the vectorized generator is bitwise the scalar trace built
    from row r's (seed, params) — across availability, volatility, price
    level and seed variation."""
    params = [
        dict(mean_price=0.7, price_sigma=0.5, avail_mean=3.5,
             avail_season_amp=3.0),
        dict(mean_price=0.7, price_sigma=0.25, avail_mean=9.0,
             avail_season_amp=3.0),
        dict(mean_price=0.45, price_sigma=0.32, avail_mean=8.0,
             avail_season_amp=3.5),
    ]
    seeds = [11, 11, 5]
    pr, av = market_regime_batch(
        np.asarray(seeds), days=4.0,
        mean_price=[p["mean_price"] for p in params],
        price_sigma=[p["price_sigma"] for p in params],
        avail_mean=[p["avail_mean"] for p in params],
        avail_season_amp=[p["avail_season_amp"] for p in params],
    )
    assert pr.shape == av.shape == (3, 192)
    assert av.dtype == np.int64
    for r, (s, p) in enumerate(zip(seeds, params)):
        tr = vast_like_trace(seed=s, days=4.0, **p)
        np.testing.assert_array_equal(pr[r], tr.prices)
        np.testing.assert_array_equal(av[r], tr.avail)


def test_grid_cell_bitwise_vs_single_regime():
    """One batched-grid cell == an independent single-regime pipeline
    (trace -> prepare_noisy_inputs -> simulate_pool_jobs), bitwise — in
    both throughput groups; and chunked streaming doesn't change a bit."""
    _, arrs = _small_pool()
    regimes, jobs, prices, avail, preds, t0s = _small_grid()
    K = 6
    util = sg.evaluate_grid(arrs, regimes, jobs, prices, avail, preds,
                            n_jobs=K)
    assert util.shape == (len(regimes), K, int(arrs["kind"].shape[0]))

    # job_chunk streaming (incl. a size that doesn't divide the block)
    util_c = sg.evaluate_grid(arrs, regimes, jobs, prices, avail, preds,
                              n_jobs=K, job_chunk=5)
    np.testing.assert_array_equal(util, util_c)

    for ri in (1, 3):  # one cell per throughput group
        r = regimes[ri]
        tr = vast_like_trace(
            seed=sg.MARKET_SEED, days=sg.GRID_DAYS,
            mean_price=sg.MEAN_PRICE, price_sigma=r.price_sigma,
            avail_mean=r.avail_mean, avail_season_amp=sg.AVAIL_SEASON_AMP,
        )
        t0s_i = np.random.default_rng(sg.JOB_SEED + 1).integers(
            0, len(tr) - sg.DEADLINE - 1, K
        )
        np.testing.assert_array_equal(t0s_i, t0s)
        seeds = sg.JOB_SEED * 100003 + np.arange(K)
        pr, av, pd_ = engine.prepare_noisy_inputs(
            tr, t0s_i, sg.DEADLINE, sg.NOISE_KIND, r.noise, seeds
        )
        jb = job_stream_arrays(np.random.default_rng(sg.JOB_SEED), K,
                               sg.DEADLINE, workload_scale=r.tight)
        out = fast_sim.simulate_pool_jobs(
            arrs, jb,
            ThroughputConfig(alpha=1.0, beta=0.0, mu1=r.mu1, mu2=r.mu2),
            pr, av, pd_,
        )
        np.testing.assert_array_equal(np.asarray(out["utility"]), util[ri])


def test_grid_seed_determinism():
    """Building and evaluating the grid twice is bitwise-identical —
    utilities, winner map and regret table."""
    pool, arrs = _small_pool()
    runs = []
    for _ in range(2):
        regimes, jobs, prices, avail, preds, _ = _small_grid(n_jobs=4)
        util = sg.evaluate_grid(arrs, regimes, jobs, prices, avail, preds,
                                n_jobs=4)
        res = sg.analyze_grid(pool, regimes, util, jobs)
        runs.append((util, res))
    np.testing.assert_array_equal(runs[0][0], runs[1][0])
    assert [p["winner"] for p in runs[0][1]["per_regime"]] == \
        [p["winner"] for p in runs[1][1]["per_regime"]]
    np.testing.assert_array_equal(runs[0][1]["regret_fixed"],
                                  runs[1][1]["regret_fixed"])
    assert [p["eg_regret_ratio"] for p in runs[0][1]["per_regime"]] == \
        [p["eg_regret_ratio"] for p in runs[1][1]["per_regime"]]


def test_grid_directional_sanity():
    """Axis direction checks on a matched-pair mini-grid (shared market
    seed and job draws): scarcer availability never increases the
    oracle-best mean utility, and zero prediction noise weakly dominates
    high noise for the prediction-based (AHAP) lanes — best lane AND
    per-lane means."""
    pool, arrs = _small_pool()
    ahap = np.array([i for i, s in enumerate(pool) if s.kind == KIND_AHAP])
    K = 8
    regimes, jobs, prices, avail, preds, _ = _small_grid(
        n_jobs=K, tight=(1.0,), mu=((0.9, 0.95),), noise=(0.0, 1.2)
    )
    util = sg.evaluate_grid(arrs, regimes, jobs, prices, avail, preds,
                            n_jobs=K)
    mean_u = {r.key: util[i].mean(axis=0) for i, r in enumerate(regimes)}
    eps = 1e-4
    for nz in ("0", "1.2"):
        scarce = mean_u[f"a3.5_s0.5_t1_m0.9_n{nz}"]
        rich = mean_u[f"a9_s0.5_t1_m0.9_n{nz}"]
        assert scarce.max() <= rich.max() + eps, (nz, scarce.max(), rich.max())
    for a in ("3.5", "9"):
        zero = mean_u[f"a{a}_s0.5_t1_m0.9_n0"]
        high = mean_u[f"a{a}_s0.5_t1_m0.9_n1.2"]
        assert zero[ahap].max() >= high[ahap].max() - eps, a
        assert np.all(zero[ahap] >= high[ahap] - eps), a


def test_noisy_matrix_batch_per_row_levels():
    """Per-row ``level`` rows match per-row scalar calls bitwise; a
    constant level vector equals the scalar path; level-0 rows reduce to
    the perfect forecast."""
    rng = np.random.default_rng(3)
    P = rng.uniform(0.1, 1.2, (5, 9))
    A = rng.integers(0, 16, (5, 9))
    seeds = 40 + np.arange(5)
    levels = np.array([0.0, 0.1, 0.4, 0.0, 0.25])
    for kind in ("fixed_uniform", "magdep_heavytail"):
        batch = noisy_matrix_batch(P, A, kind, levels, seeds, 5)
        for k in range(5):
            one = noisy_matrix_batch(P[k:k + 1], A[k:k + 1], kind,
                                     float(levels[k]), seeds[k:k + 1], 5)
            np.testing.assert_array_equal(batch[k], one[0])
        const = noisy_matrix_batch(P, A, kind, 0.2, seeds, 5)
        const_vec = noisy_matrix_batch(P, A, kind, np.full(5, 0.2), seeds, 5)
        np.testing.assert_array_equal(const, const_vec)
        perfect = true_future_batch(P, A, 5)
        np.testing.assert_array_equal(batch[0], perfect[0])
        np.testing.assert_array_equal(batch[3], perfect[3])


def test_concat_jobs_roundtrip_and_workload_scale():
    rng = np.random.default_rng(5)
    jobs = job_stream_arrays(rng, 9)
    parts = [fast_sim.slice_jobs(jobs, 0, 4), fast_sim.slice_jobs(jobs, 4, 9)]
    cat = fast_sim.concat_jobs(parts)
    for f in fast_sim.JobArrays._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(cat, f)), np.asarray(getattr(jobs, f))
        )
    # workload_scale: 1.0 is a bitwise no-op; s scales the same base draws
    base = job_stream_arrays(np.random.default_rng(5), 9, workload_scale=1.0)
    np.testing.assert_array_equal(base.workload, jobs.workload)
    scaled = job_stream_arrays(np.random.default_rng(5), 9,
                               workload_scale=1.15)
    np.testing.assert_array_equal(
        scaled.workload,
        (np.random.default_rng(5).uniform(70, 120, 9) * 1.15)
        .astype(np.float32),
    )
    np.testing.assert_array_equal(scaled.n_min, jobs.n_min)


def test_grid_regimes_mu_major_and_count():
    """Default axes produce the >= 36-regime grid the bench sweeps, with
    the throughput axis varying slowest (contiguous tput groups)."""
    regimes = sg.grid_regimes()
    assert len(regimes) == (
        len(sg.AVAIL_AXIS) * len(sg.SIGMA_AXIS) * len(sg.TIGHT_AXIS)
        * len(sg.MU_AXIS) * len(sg.NOISE_AXIS)
    )
    if all(len(ax) > 1 for ax in (
            sg.AVAIL_AXIS, sg.SIGMA_AXIS, sg.TIGHT_AXIS, sg.NOISE_AXIS)) \
            and len(sg.AVAIL_AXIS) >= 3:
        assert len(regimes) >= 36
    mus = [(r.mu1, r.mu2) for r in regimes]
    seen = []
    for m in mus:
        if not seen or seen[-1] != m:
            seen.append(m)
    assert len(seen) == len(set(mus))  # each tput group is one contiguous run
    keys = [r.key for r in regimes]
    assert len(set(keys)) == len(keys)
