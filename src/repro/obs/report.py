"""Textual dashboard over a ledger dict (obs.ledger builders).

``render(ledger)`` returns a plain-text block; dispatch is on
``ledger["kind"]``. This is deliberately dependency-free formatting so CI
logs and quick REPL inspection get the same output."""
from __future__ import annotations

from typing import List


def _hdr(title: str) -> List[str]:
    return [title, "=" * len(title)]


def _recon_lines(recon: dict) -> List[str]:
    return [
        f"cost   spot {recon['spot_cost']:.2f} + od {recon['od_cost']:.2f}"
        f" + term {recon['termination_cost']:.2f}"
        f" = {recon['total_cost']:.2f}"
        f"  (spot share {recon['spot_share']:.1%})",
        f"recon  |cost resid| <= {recon['max_abs_cost_residual']:.3g}"
        f"  |utility resid| <= {recon['max_abs_utility_residual']:.3g}",
    ]


def _bar(frac: float, width: int = 20) -> str:
    n = int(round(max(0.0, min(1.0, frac)) * width))
    return "#" * n + "." * (width - n)


def _render_pool(ledger: dict) -> List[str]:
    sh = ledger["shape"]
    lines = _hdr(f"pool flight record  ({sh['n_jobs']} jobs x "
                 f"{sh['n_lanes']} lanes x {sh['n_slots']} slots)")
    lines += _recon_lines(ledger["cost_reconciliation"])
    pl = ledger["per_lane"]
    util = pl["mean_utility"]
    order = sorted(range(len(util)), key=lambda i: -util[i])[:5]
    names = pl.get("name")
    lines.append("top lanes by mean utility:")
    for i in order:
        tag = names[i] if names else f"lane {i}"
        lines.append(
            f"  {tag:<28} u={util[i]:8.2f}  cost={pl['mean_cost'][i]:7.2f}"
            f"  spot={pl['mean_spot_cost'][i]:7.2f}"
            f"  preempt={pl['preemptions_mean'][i]:.2f}"
            f"  done={pl['completion_rate'][i]:.0%}"
        )
    if "migration" in ledger:
        mg = ledger["migration"]
        occ = " ".join(f"r{r}={f:.0%}" for r, f in
                       enumerate(mg["region_occupancy"]))
        lines.append(
            f"migration  {mg['total_migrations']} switches"
            f" (mean {mg['migrations_mean']:.2f}/lane)"
            f"  occupancy {occ}"
            f"  reconciled={'yes' if mg['events_reconciled'] and mg['series_matches_leaf'] else 'NO'}"
        )
    return lines


def _render_fleet(ledger: dict) -> List[str]:
    sh = ledger["shape"]
    wf = ledger["waterfall"]
    lines = _hdr(f"fleet flight record  ({sh['n_jobs']} jobs x "
                 f"{sh['n_slots']} slots)")
    lines += _recon_lines(ledger["cost_reconciliation"])
    lines.append(
        f"waterfall  granted {wf['total_granted']}/{wf['total_demand']}"
        f" ({wf['grant_ratio']:.1%})"
        f"  starvation incidence {wf['starvation_incidence']:.1%}"
        f" ({wf['starved_slots_total']} starved slots)"
    )
    if "max_oversubscription" in wf:
        lines.append(f"           max oversubscription "
                     f"{wf['max_oversubscription']} (<= 0 is conserving)")
    return lines


def _render_selection(ledger: dict) -> List[str]:
    sh = ledger["shape"]
    lines = _hdr(f"selection flight record  ({sh['n_jobs']} jobs x "
                 f"{sh['n_policies']} policies)")
    lines.append(
        f"best policy {ledger['best_policy']}"
        f"  iters-to-half {ledger['iters_to_half']}"
        f"  regret/bound {ledger['regret_ratio']:.3f}"
    )
    if "entropy_final" in ledger:
        frac = ledger["entropy_final"] / max(ledger["entropy_uniform"], 1e-12)
        lines.append(
            f"weight entropy {ledger['entropy_final']:.3f}"
            f" / uniform {ledger['entropy_uniform']:.3f}  [{_bar(frac)}]"
        )
    if "top_policy" in ledger:
        tp = ledger["top_policy"]
        trace = " -> ".join(
            f"{p}@{s}" for p, s in zip(tp["policy"], tp["since_job"])
        )
        lines.append(f"leader trace ({tp['n_switches']} switches): {trace}")
    return lines


def _render_grid(ledger: dict) -> List[str]:
    sh = ledger["shape"]
    lines = _hdr(f"scenario-grid flight record  ({sh['n_regimes']} regimes x"
                 f" {sh['jobs_per_regime']} jobs x {sh['n_lanes']} lanes)")
    lines.append(
        f"recon  |cost resid| <= {ledger['max_abs_cost_residual']:.3g}"
        f"  |utility resid| <= {ledger['max_abs_utility_residual']:.3g}"
    )
    for e in ledger["per_regime"]:
        wl = e["winner_lane"]
        tag = e.get("winner", f"lane {e['winner_idx']}")
        lines.append(
            f"  {e.get('key', '?'):<26} winner {tag:<24}"
            f" u={e['winner_mean_utility']:8.2f}"
            f" spot%={e['pool']['spot_share']:.0%}"
            f" preempt={wl['preemptions_mean']:.2f}"
            f" done={wl['completion_rate']:.0%}"
        )
    return lines


_RENDERERS = {
    "pool": _render_pool,
    "fleet": _render_fleet,
    "selection": _render_selection,
    "scenario_grid": _render_grid,
}


def render(ledger: dict) -> str:
    """Render any obs.ledger dict as a textual dashboard."""
    kind = ledger.get("kind")
    if kind not in _RENDERERS:
        raise ValueError(f"unknown ledger kind: {kind!r}")
    return "\n".join(_RENDERERS[kind](ledger))
