"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each kernel's tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b, scale: float):
    """x:(M,K) @ w:(K,N) + scale * (x@a):(M,r) @ b:(r,N), f32 accumulation."""
    base = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    delta = jnp.dot(
        jnp.dot(x.astype(jnp.float32), a.astype(jnp.float32)),
        b.astype(jnp.float32),
    )
    return (base + scale * delta).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None):
    """q,k,v:(B,H,S,D) -> (B,H,S,D); f32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(d)
    sq, sk = q.shape[2], k.shape[2]
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    s = jnp.where(ok[None, None], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def window_dp_ref(slot_cost, gain, big: float = 1.0e9):
    """Batched scan-based min-plus DP oracle for the CHC window kernel.

    slot_cost:(B, w1, tn+1), gain:(B, U+1), U = w1*tn.
    Returns (n_tot:(B, w1) i32, obj:(B,) f32) — same semantics as
    window_dp.window_dp (smallest-k / smallest-u tie-breaking)."""
    _, w1, kw = slot_cost.shape
    u1 = gain.shape[1]

    def one(cost, g):
        u_grid = jnp.arange(u1)

        def dp_step(C, row):
            uk = u_grid[:, None] - jnp.arange(kw)[None, :]
            prevC = jnp.where(uk >= 0, C[jnp.clip(uk, 0, u1 - 1)], big)
            cand = prevC + row[None, :]
            return jnp.min(cand, axis=1), jnp.argmin(cand, axis=1)

        C0 = jnp.where(u_grid == 0, 0.0, big)
        C, choices = jax.lax.scan(dp_step, C0, cost)
        obj = jnp.where(C < big / 2, g - C, -jnp.inf)
        u_star = jnp.argmax(obj)

        def back_step(u, choice_row):
            k = choice_row[u]
            return u - k, k

        _, k_rev = jax.lax.scan(back_step, u_star, choices, reverse=True)
        return k_rev.astype(jnp.int32), obj[u_star]

    return jax.vmap(one)(slot_cost, gain)


def ssd_scan_ref(x, dt, A, B, C, h0=None):
    """Sequential SSD recurrence oracle.

    x:(BH, S, P), dt:(BH, S), A:(BH,), B,C:(BH, S, N).
    h_t = exp(dt_t A) h_{t-1} + dt_t * outer(B_t, x_t);  y_t = C_t @ h_t.
    Returns (y:(BH,S,P), h_final:(BH,N,P))."""
    bh, s, p = x.shape
    n = B.shape[-1]

    def one(xh, dth, Ah, Bh, Ch, h0h):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            decay = jnp.exp(dtt * Ah)
            h = decay * h + dtt * jnp.outer(bt, xt)  # (N, P)
            y = ct @ h  # (P,)
            return h, y

        h, ys = jax.lax.scan(
            step, h0h, (xh.astype(jnp.float32), dth.astype(jnp.float32),
                        Bh.astype(jnp.float32), Ch.astype(jnp.float32))
        )
        return ys, h

    if h0 is None:
        h0 = jnp.zeros((bh, n, p), jnp.float32)
    ys, hf = jax.vmap(one)(x, dt, A, B, C, h0)
    return ys.astype(x.dtype), hf
