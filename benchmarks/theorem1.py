"""Theorem 1 (empirical): AHAP's gap to the offline optimum tightens as the
prediction error shrinks; commitment level v trades stability for
responsiveness; the sigma term contributes an error floor."""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_JOB, PAPER_TPUT, timed
from repro.core.market import vast_like_trace
from repro.core.offline_opt import solve_offline
from repro.core.policies import AHAP, AHAPParams
from repro.core.predictor import NoisyPredictor, PerfectPredictor
from repro.core.simulator import simulate

N_TRACES = 24


def _mean_gap(level: float, params: AHAPParams, seed0: int = 200) -> float:
    gaps = []
    for s in range(N_TRACES):
        tr = vast_like_trace(seed=seed0 + s, days=1, avail_mean=6.0).window(
            0, PAPER_JOB.deadline + 1
        )
        opt = solve_offline(PAPER_JOB, PAPER_TPUT, tr)
        if level <= 0:
            pred = PerfectPredictor(tr).matrix(5)
        else:
            pred = NoisyPredictor(tr, "magdep_uniform", level, seed=s).matrix(5)
        r = simulate(AHAP(params), PAPER_JOB, PAPER_TPUT, tr, pred)
        gaps.append(opt.utility - r.utility)
    return float(np.mean(gaps))


def run() -> list:
    rows = []
    gaps = []
    for level in (0.0, 0.1, 0.25, 0.5, 1.0):
        g, us = timed(_mean_gap, level, AHAPParams(3, 1, 0.7))
        gaps.append(g)
        rows.append((f"theorem1_gap_noise{level:g}", us, g))
    # monotone trend (allow small statistical wiggle per adjacent pair)
    mono = float(gaps[0] <= gaps[-1] and gaps[1] <= gaps[-1])
    rows.append(("theorem1_gap_monotone_in_error", 0.0, mono))
    # commitment level: higher v smooths noisy predictions (stability)
    g_v1, _ = timed(_mean_gap, 0.5, AHAPParams(5, 1, 0.7))
    g_v5, _ = timed(_mean_gap, 0.5, AHAPParams(5, 5, 0.7))
    rows.append(("theorem1_gap_v1_noisy", 0.0, g_v1))
    rows.append(("theorem1_gap_v5_noisy", 0.0, g_v5))
    return rows
