"""jit-able train / prefill / decode step factories.

``train_step`` differentiates ONLY the LoRA leaves (path-partitioned), so the
frozen base model never gets gradients or optimizer state — faithful to the
paper's LoRA fine-tuning setting and what makes 100B-scale dry-runs fit.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.optim import adamw, warmup_cosine
from repro.train.losses import task_loss
from repro.utils.partition import is_lora_path, partition_by_path


class TrainMetrics(NamedTuple):
    loss: jnp.ndarray
    grad_norm: jnp.ndarray
    lr: jnp.ndarray


def init_opt_state(params):
    lora_leaves, _ = partition_by_path(params, is_lora_path)
    return adamw.init(lora_leaves)


def make_train_step(cfg, tcfg):
    """-> train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    from repro.sharding import shard

    def train_step(params, opt_state, batch):
        lora0, merge = partition_by_path(params, is_lora_path)

        def loss_fn(lora_leaves, mb):
            full = merge(lora_leaves)
            logits, aux = tf.forward(cfg, full, mb, remat=tcfg.remat)
            return task_loss(cfg, logits, mb) + aux

        a = tcfg.microbatches
        if a > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]), batch
            )

            def mb_step(carry, mb):
                loss_sum, gsum = carry
                mb = jax.tree.map(
                    lambda x: shard(x, "batch", *((None,) * (x.ndim - 1))), mb
                )
                l, g = jax.value_and_grad(loss_fn)(lora0, mb)
                return (loss_sum + l, jax.tree.map(jnp.add, gsum, g)), None

            init = (jnp.zeros((), jnp.float32), jax.tree.map(jnp.zeros_like, lora0))
            (loss, grads), _ = jax.lax.scan(mb_step, init, mbs)
            loss = loss / a
            grads = jax.tree.map(lambda g: g / a, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(lora0, batch)
        grads, gnorm = adamw.clip_by_global_norm(grads, tcfg.grad_clip)
        lr = warmup_cosine(
            opt_state.step,
            base_lr=tcfg.lr,
            warmup_steps=tcfg.warmup_steps,
            total_steps=tcfg.total_steps,
        )
        new_lora, new_opt = adamw.update(
            grads, opt_state, lora0,
            lr=lr, b1=tcfg.b1, b2=tcfg.b2, eps=tcfg.eps,
            weight_decay=tcfg.weight_decay,
        )
        return merge(new_lora), new_opt, TrainMetrics(loss, gnorm, lr)

    return train_step


def make_grad_step(cfg, tcfg):
    """Gradient-only step for accumulation: (params, batch) -> (loss, grads)."""

    def grad_step(params, batch):
        lora0, merge = partition_by_path(params, is_lora_path)

        def loss_fn(lora_leaves):
            full = merge(lora_leaves)
            logits, aux = tf.forward(cfg, full, batch, remat=tcfg.remat)
            return task_loss(cfg, logits, batch) + aux

        return jax.value_and_grad(loss_fn)(lora0)

    return grad_step


def apply_grads(cfg, tcfg, params, opt_state, grads):
    """Optimizer apply for externally-accumulated grads (elastic trainer)."""
    lora0, merge = partition_by_path(params, is_lora_path)
    grads, _ = adamw.clip_by_global_norm(grads, tcfg.grad_clip)
    lr = warmup_cosine(
        opt_state.step, base_lr=tcfg.lr,
        warmup_steps=tcfg.warmup_steps, total_steps=tcfg.total_steps,
    )
    new_lora, new_opt = adamw.update(
        grads, opt_state, lora0, lr=lr,
        b1=tcfg.b1, b2=tcfg.b2, eps=tcfg.eps, weight_decay=tcfg.weight_decay,
    )
    return merge(new_lora), new_opt


def make_eval_step(cfg):
    def eval_step(params, batch):
        logits, aux = tf.forward(cfg, params, batch)
        return task_loss(cfg, logits, batch)

    return eval_step


def make_prefill_step(cfg, max_len: int):
    def prefill_step(params, batch):
        return tf.prefill(cfg, params, batch, max_len=max_len)

    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, cache, batch):
        logits, new_cache = tf.decode_step(cfg, params, batch, cache)
        return logits, new_cache

    return decode_step
