"""BEYOND-PAPER: Robust-AHAP (availability-pessimistic forecasts).

Hypothesis: the paper's AHAP trusts predicted availability; under large /
heavy-tailed forecast noise, over-trust under-provisions on-demand and slips
deadlines. Discounting predicted (not observed) availability by rho < 1
hedges at a small cost in spot utilization. We evaluate the best plain-AHAP
vs the best Robust-AHAP over the pool for each noise regime/level, and show
the EG selector over the extended pool (112 + 24) picks robust variants
exactly when noise is heavy. One ``engine.simulate_and_select`` call per
setting (the selection engine carries the EG scan on device).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from benchmarks.fig9_convergence import _run_setting
from repro.core.policy_pool import paper_pool, robust_pool
from repro.core.selector import best_policy

SETTINGS = [
    ("fixed_uniform", 0.1),
    ("fixed_uniform", 0.6),
    ("magdep_heavytail", 0.3),
    ("fixed_heavytail", 0.8),
]
N_JOBS = 300


def run() -> list:
    base = paper_pool()
    robust = robust_pool()
    pool = base + robust
    is_robust = np.array([p.rho < 1.0 for p in pool])
    is_plain_ahap = np.array([p.kind == 0 and p.rho >= 1.0 for p in pool])

    rows = []
    wins = 0
    for kind, level in SETTINGS:
        res, us = timed(_run_setting, pool, kind, level, N_JOBS, seed=77)
        mean_u = res.mean_utility
        best_plain = float(mean_u[is_plain_ahap].max())
        best_robust = float(mean_u[is_robust].max())
        gain = 100.0 * (best_robust - best_plain) / abs(best_plain)
        tag = f"{kind}_{level:g}"
        rows.append((f"robust_{tag}_best_plain_ahap", us, best_plain))
        rows.append((f"robust_{tag}_best_robust_ahap", us, best_robust))
        rows.append((f"robust_{tag}_gain_pct", 0.0, gain))
        # does the selector actually pick a robust variant?
        picked = best_policy(res.state)
        rows.append((f"robust_{tag}_selector_picks_robust", 0.0,
                     float(is_robust[picked])))
        if level >= 0.6:
            wins += int(best_robust >= best_plain)
    rows.append(("robust_helps_under_heavy_noise", 0.0, float(wins >= 1)))
    return rows
