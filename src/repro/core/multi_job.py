"""Multi-job scheduling — the paper's stated extension (Sec. III-A: "our
framework can be readily extended to handle multiple jobs").

Jobs arrive over time and COMPETE for the same finite spot pool; each job
runs its own policy instance (chosen by the per-job EG selector state), and
a simple priority mechanism arbitrates the shared capacity:

  * every live job first *demands* spot against the full slot supply (its
    policy sees the real market, so single-job semantics are intact and a
    solo job matches the reference simulator exactly);
  * spot grants then run a least-slack-first waterfall (deadline slack,
    float32, job-id tie-break): jobs closest to violating their SLO drain
    the supply first — the textbook EDF-style rule adapted to elastic
    allocations — and each job executes with what it was granted;
  * on-demand is unlimited (cloud semantics), so contention only reshapes
    the cheap-capacity split (a job whose grant fell below N^min tops up
    with on-demand, exactly like the single-job feasibility repair).

This demand-then-grant formulation is order-free on the decision side —
which is what lets core.fleet run the identical semantics as one batched
``lax.scan`` on device. This module is the numpy parity oracle for that
engine: the slack key is computed in float32 with the same op order, and
ties break on job id, so the two waterfalls sort identical keys.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.job import value_fn
from repro.core.market import Trace
from repro.core.policies import BasePolicy, Obs


@dataclass
class ActiveJob:
    job_id: int
    job: JobConfig
    policy: BasePolicy
    arrival: int
    pred: Optional[np.ndarray] = None      # (T, h+1, 2) absolute-time forecasts
    z: float = 0.0
    n_prev: int = 0
    cost: float = 0.0
    t_complete: Optional[float] = None
    alloc_spot: List[int] = field(default_factory=list)
    alloc_od: List[int] = field(default_factory=list)

    def slack(self, t: int, tput: ThroughputConfig) -> np.float32:
        """Slots to spare if finished at N^max from now on (can be < 0).

        float32 on purpose: the device fleet engine (core.fleet) sorts the
        same key, so the waterfall priority order cannot drift between the
        oracle and the batched scan.
        """
        remaining = np.float32(max(self.job.workload - self.z, 0.0))
        h_max = (np.float32(tput.alpha) * np.float32(self.job.n_max)
                 + np.float32(tput.beta))
        deadline_abs = self.arrival + self.job.deadline
        return np.float32(deadline_abs - t) - remaining / h_max


@dataclass
class JobResult:
    job_id: int
    utility: float
    value: float
    cost: float
    completion_time: float
    completed_by_deadline: bool


class MultiJobScheduler:
    """Slot-synchronous scheduler over a shared market trace."""

    def __init__(self, tput: ThroughputConfig, trace: Trace):
        self.tput = tput
        self.trace = trace
        self.active: List[ActiveJob] = []
        self.done: List[JobResult] = []
        self._next_id = 0

    def submit(self, t: int, job: JobConfig, policy: BasePolicy,
               pred: Optional[np.ndarray] = None) -> int:
        policy.reset(job, self.tput)
        aj = ActiveJob(self._next_id, job, policy, arrival=t, pred=pred)
        self.active.append(aj)
        self._next_id += 1
        return aj.job_id

    # ------------------------------------------------------------------
    def step(self, t: int):
        """One market slot: demand at full supply, then least-slack grants."""
        price = float(self.trace.prices[t])
        supply = int(self.trace.avail[t])
        live = [aj for aj in self.active
                if 0 <= t - aj.arrival < aj.job.deadline]

        # Phase 1 — every live job demands against the FULL slot supply.
        demands = []
        for aj in live:
            pred = None
            if aj.pred is not None:
                pred = np.array(aj.pred[t], copy=True)
                # the pool caps what the present slot can deliver;
                # future rows stay the global forecast
                pred[0, 1] = min(pred[0, 1], supply)
            obs = Obs(t=t - aj.arrival, price=price, avail=supply,
                      z_prev=aj.z, n_prev=aj.n_prev, pred=pred)
            n_o, n_s = aj.policy.decide(obs)
            n_s = int(np.clip(n_s, 0, min(supply, aj.job.n_max)))
            n_o = int(np.clip(n_o, 0, aj.job.n_max - n_s))
            demands.append((aj, n_o, n_s))

        # Phase 2 — least-slack-first waterfall over the shared pool;
        # job-id tie-break keeps the order total (and matches core.fleet).
        demands.sort(key=lambda d: (d[0].slack(t, self.tput), d[0].job_id))
        residual = supply
        a32 = np.float32(self.tput.alpha)
        b32 = np.float32(self.tput.beta)
        for aj, n_o, n_s in demands:
            n_s = min(n_s, residual)
            residual -= n_s
            n = n_o + n_s
            if 0 < n < aj.job.n_min:  # grant fell below N^min: top up with od
                n_o += aj.job.n_min - n
                n = n_o + n_s
            local_t = t - aj.arrival

            mu = 1.0 if n == aj.n_prev else (
                self.tput.mu1 if n > aj.n_prev else self.tput.mu2
            )
            if n == 0 and aj.n_prev == 0:
                mu = 1.0
            # float32 execution arithmetic, op-for-op the device engine's
            # _execute: progress trajectories stay bitwise-aligned with
            # core.fleet, so discrete policy decisions downstream of z (the
            # window DP's argmax sits on near-ties) cannot flip between the
            # oracle and the batched scan.
            wl32 = np.float32(aj.job.workload)
            z32 = np.float32(aj.z)
            work = np.float32(mu) * (
                a32 * np.float32(n) + b32 if n > 0 else np.float32(0.0)
            )
            aj.cost += n_s * price + n_o * aj.job.on_demand_price
            aj.alloc_spot.append(n_s)
            aj.alloc_od.append(n_o)
            if work > 0 and z32 + work >= wl32 and aj.t_complete is None:
                frac = (wl32 - z32) / max(work, np.float32(1e-9))
                aj.t_complete = float(np.float32(local_t) + frac)
            aj.z = float(min(z32 + work, wl32))
            aj.n_prev = n

        # retire finished / past-deadline jobs
        still = []
        for aj in self.active:
            if self._retired(aj, t):
                self.done.append(self._finalize(aj))
            else:
                still.append(aj)
        self.active = still

    @staticmethod
    def _retired(aj: ActiveJob, t: int) -> bool:
        """Completed, or the deadline passes before the next slot."""
        return aj.t_complete is not None or t - aj.arrival + 1 >= aj.job.deadline

    # ------------------------------------------------------------------
    def _finalize(self, aj: ActiveJob) -> JobResult:
        job, tput = aj.job, self.tput
        if aj.t_complete is None:
            h_max = tput.alpha * job.n_max + tput.beta
            dt = (job.workload - aj.z) / h_max
            aj.t_complete = job.deadline + dt
            aj.cost += job.on_demand_price * job.n_max * dt
        value = float(value_fn(job, aj.t_complete))
        return JobResult(
            job_id=aj.job_id, utility=value - aj.cost, value=value,
            cost=aj.cost, completion_time=float(aj.t_complete),
            completed_by_deadline=aj.t_complete <= job.deadline,
        )

    # ------------------------------------------------------------------
    def run(self, t_end: int):
        for t in range(t_end):
            if not self.active:
                continue
            self.step(t)
        for aj in self.active:  # anything left at horizon end
            self.done.append(self._finalize(aj))
        self.active = []
        return self.done
