import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""§Perf hillclimb driver: run one (arch x shape) under named variants and
report the roofline-term deltas vs baseline.

    PYTHONPATH=src python -m repro.launch.perf \
        --arch qwen1.5-110b --shape train_4k --variants baseline,mb8,seqpar

Each variant is a (microbatches, sharding-rules) override; results land in
experiments/perf/ and the comparison table prints the three roofline terms
so the hypothesis -> change -> measure loop (EXPERIMENTS.md §Perf) has one
command per iteration.
"""

import argparse  # noqa: E402
import json  # noqa: E402

from repro.launch.dryrun import run_one  # noqa: E402
from repro.launch.roofline import analyze_record  # noqa: E402

VARIANTS = {
    "baseline": {},
    # --- train: microbatch count (weight-gather amortization vs HBM peak) ---
    "mb8": {"microbatches": 8},
    "mb4": {"microbatches": 4},
    "mb2": {"microbatches": 2},
    # --- sequence parallelism: shard the residual stream's seq dim over the
    # model axis (Megatron-SP analogue; norms/elementwise stop being
    # replicated 16x across the tensor axis) ---
    "seqpar": {"rules": {"seq": ("model",)}},
    "seqpar_mb8": {"rules": {"seq": ("model",)}, "microbatches": 8},
    # --- decode cache placement ---
    "cache_replicated": {"rules": {"kv_seq": ()}},
    "cache_batch": {"rules": {"kv_seq": (), "batch": ("pod", "data", "model")}},
    # --- keep base weights un-sharded over data (pure 16-way TP) ---
    "no_fsdp": {"rules": {"fsdp": ()}},
    # --- MoE experts sharded over the data axis (expert parallelism) ---
    "expert_par": {"rules": {"experts": ("data",)}},
    # --- pad attention heads to the next multiple of the model axis:
    # 28 heads on a 16-way axis fall back to full replication (16x redundant
    # attention compute + traffic). Zero-initialized padding heads keep the
    # function identical; only the sharding changes. (qwen2-vl-7b) ---
    "head_pad32": {"cfg": {"num_heads": 32, "head_dim": 128}},
    "head_pad32_no_fsdp": {"cfg": {"num_heads": 32, "head_dim": 128},
                           "rules": {"fsdp": ()}},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    rows = []
    for name in args.variants.split(","):
        ov = VARIANTS[name]
        rec = run_one(
            args.arch, args.shape, args.multi_pod, verbose=True,
            microbatches=ov.get("microbatches"), rules=ov.get("rules"),
            variant=name, cfg_overrides=ov.get("cfg"),
        )
        fname = f"{args.arch}_{args.shape}_{rec['mesh']}_{name}.json".replace("/", "-")
        with open(os.path.join(args.out, fname), "w") as f:
            json.dump(rec, f, indent=2)
        r = analyze_record(rec)
        if r is None:
            print(f"{name}: FAILED/SKIPPED: {rec.get('error', rec.get('reason'))}")
            continue
        temp = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
        rows.append((name, r, temp))

    print(f"\n{'variant':18s} {'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
          f"{'bound_s':>9s} {'tempGiB':>8s} {'dominant':>10s}")
    base = rows[0][1] if rows else None
    for name, r, temp in rows:
        d = ""
        if base is not None and r is not base:
            d = f"  ({100*(r['step_bound_s']/base['step_bound_s']-1):+.1f}% bound)"
        print(f"{name:18s} {r['compute_s']:10.4f} {r['memory_s']:10.4f} "
              f"{r['collective_s']:10.4f} {r['step_bound_s']:9.4f} {temp:8.2f} "
              f"{r['dominant']:>10s}{d}")


if __name__ == "__main__":
    main()
