"""LoRA (Hu et al., ICLR'22) — the paper's fine-tuning method (Sec. II-A).

Base weights stay frozen; each adapted projection W gets a low-rank update
W + (alpha/r) * A @ B with A:(in, r), B:(r, *out). LoRA params live in a
separate ``params["lora"]`` subtree so the optimizer/train_step only ever
touches adapters (the paper's memory argument for N^min).

Kernel note: the fused base+LoRA projection has a Pallas TPU kernel
(`repro/kernels/lora_matmul.py`); this module is the XLA path and the
semantics oracle.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import normal_param, zeros_param
from repro.sharding import Param


def init_lora_pair(key, in_dim: int, out_shape: Tuple[int, ...], rank: int):
    """A:(in, r) gaussian, B:(r, *out) zeros  (standard LoRA init: AB = 0)."""
    a = normal_param(key, (in_dim, rank), ("fsdp", "lora_rank"), jnp.float32)
    out_axes = ("lora_rank",) + ("tensor",) + (None,) * (len(out_shape) - 1)
    b = zeros_param((rank,) + tuple(out_shape), out_axes[: 1 + len(out_shape)], jnp.float32)
    return {"a": a, "b": b}


def lora_delta(x: jnp.ndarray, lora: dict, scale: float) -> jnp.ndarray:
    """(..., in) -> (..., *out): scale * (x @ A) @ B.

    Computed in the model dtype (adapters keep f32 master copies but are cast
    for the matmul): computing in f32 here would make every upstream
    activation cotangent f32 and double the FSDP all-gather traffic — found
    via the dry-run HLO (EXPERIMENTS.md §Perf)."""
    a = lora["a"].astype(x.dtype)
    b = lora["b"].astype(x.dtype)
    xa = jnp.einsum("...d,dr->...r", x, a)
    out_dims = "efg"[: b.ndim - 1]
    y = jnp.einsum(f"...r,r{out_dims}->...{out_dims}", xa, b)
    return (scale * y).astype(x.dtype)


def proj(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    lora: Optional[dict] = None,
    scale: float = 0.0,
) -> jnp.ndarray:
    """y = x @ W (+bias) (+ LoRA delta). W may be (in, out) or (in, h, hd)."""
    out_dims = "efg"[: w.ndim - 1]
    y = jnp.einsum(f"...d,d{out_dims}->...{out_dims}", x, w)
    if bias is not None:
        y = y + bias
    if lora is not None:
        y = y + lora_delta(x, lora, scale)
    return y


def merge_lora(w: jnp.ndarray, lora: dict, scale: float) -> jnp.ndarray:
    """Materialize W + scale*A@B (checkpoint export / serving)."""
    b = lora["b"]
    out_dims = "efg"[: b.ndim - 1]
    delta = scale * jnp.einsum(f"dr,r{out_dims}->d{out_dims}", lora["a"], b)
    return (w.astype(jnp.float32) + delta).astype(w.dtype)
