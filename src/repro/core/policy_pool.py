"""Policy pool construction (Sec. V-A / VI-A).

The paper's pool: 105 AHAP policies (omega in 1..5, v in 1..omega, sigma in
{0.3 .. 0.9}) + 7 AHANP policies (same sigmas) = 112, indexed 1..112 in
Fig. 10. ``PolicySpec`` is the array encoding shared by the python policies
and the vmapped JAX simulator.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.core.policies import (
    AHANP,
    AHANPParams,
    AHAP,
    AHAPParams,
    BasePolicy,
    MSU,
    ODOnly,
    UP,
)

KIND_AHAP, KIND_AHANP, KIND_OD, KIND_MSU, KIND_UP = 0, 1, 2, 3, 4
KIND_NAMES = {0: "ahap", 1: "ahanp", 2: "od_only", 3: "msu", 4: "up"}

SIGMAS = (0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)
OMEGAS = (1, 2, 3, 4, 5)


@dataclass(frozen=True)
class PolicySpec:
    kind: int
    omega: int = 0
    v: int = 0
    sigma: float = 0.0
    rho: float = 1.0  # Robust-AHAP availability discount (1.0 = paper AHAP)

    @property
    def name(self) -> str:
        if self.kind == KIND_AHAP:
            r = f",r={self.rho:.2f}" if self.rho < 1.0 else ""
            return f"ahap(w={self.omega},v={self.v},s={self.sigma:.1f}{r})"
        if self.kind == KIND_AHANP:
            return f"ahanp(s={self.sigma:.1f})"
        return KIND_NAMES[self.kind]

    def build(self) -> BasePolicy:
        if self.kind == KIND_AHAP:
            return AHAP(AHAPParams(self.omega, self.v, self.sigma, self.rho))
        if self.kind == KIND_AHANP:
            return AHANP(AHANPParams(self.sigma))
        return {KIND_OD: ODOnly, KIND_MSU: MSU, KIND_UP: UP}[self.kind]()


def paper_pool(
    omegas: Sequence[int] = OMEGAS,
    sigmas: Sequence[float] = SIGMAS,
    fixed_v: Optional[int] = None,
    fixed_sigma: Optional[float] = None,
    include_ahanp: bool = True,
) -> List[PolicySpec]:
    """105 AHAP + 7 AHANP by default; the fixed_* arguments reproduce the
    Fig. 9 hyperparameter-ablation pools (e.g. v=1 only, or sigma=0.9 only)."""
    pool: List[PolicySpec] = []
    for w in omegas:
        for v in range(1, w + 1):
            if fixed_v is not None and v != fixed_v:
                continue
            for s in sigmas:
                if fixed_sigma is not None and abs(s - fixed_sigma) > 1e-9:
                    continue
                pool.append(PolicySpec(KIND_AHAP, w, v, s))
    if include_ahanp:
        for s in sigmas:
            if fixed_sigma is not None and abs(s - fixed_sigma) > 1e-9:
                continue
            pool.append(PolicySpec(KIND_AHANP, 0, 0, s))
    return pool


def baseline_specs() -> List[PolicySpec]:
    return [PolicySpec(KIND_OD), PolicySpec(KIND_MSU), PolicySpec(KIND_UP)]


def robust_pool(
    rhos: Sequence[float] = (0.5, 0.7, 0.85),
    omegas: Sequence[int] = (3, 5),
    sigmas: Sequence[float] = (0.3, 0.5, 0.7, 0.9),
) -> List[PolicySpec]:
    """BEYOND-PAPER: Robust-AHAP candidates (availability-pessimistic)."""
    return [
        PolicySpec(KIND_AHAP, w, 1, s, rho=r)
        for r in rhos for w in omegas for s in sigmas
    ]


def specs_to_arrays(pool: Sequence[PolicySpec]) -> dict:
    """Array encoding for the vmapped simulator."""
    return {
        "kind": np.array([p.kind for p in pool], np.int32),
        "omega": np.array([p.omega for p in pool], np.int32),
        "v": np.array([max(p.v, 1) for p in pool], np.int32),
        "sigma": np.array([p.sigma for p in pool], np.float32),
        "rho": np.array([p.rho for p in pool], np.float32),
    }
