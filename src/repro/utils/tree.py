"""Small pytree utilities (no flax in this environment)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    total = 0
    for x in jax.tree.leaves(tree):
        dt = x.dtype if hasattr(x, "dtype") else jnp.float32
        total += int(np.prod(x.shape)) * jnp.dtype(dt).itemsize
    return total


def tree_map_with_path_names(fn, tree):
    """Like tree.map_with_path but paths rendered as '/'-joined strings."""

    def _fn(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        return fn(name, leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)
