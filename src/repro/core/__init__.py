"""The paper's contribution: deadline-aware online scheduling for LLM
fine-tuning on mixed on-demand/spot GPU markets with predictions."""
from repro.core.engine import (
    SelectionResult,
    prepare_noisy_inputs,
    select_from_utilities,
    simulate_and_select,
)
from repro.core.job import (
    expected_progress,
    normalization_bounds,
    normalization_bounds_batch,
    normalize_utility,
    normalize_utility_batch,
    tilde_value,
    value_fn,
)
from repro.core.market import (
    Trace,
    TraceStats,
    constant_trace,
    from_arrays,
    gather_windows,
    vast_like_trace,
)
from repro.core.offline_opt import OfflineResult, solve_offline
from repro.core.policies import (
    AHANP,
    AHANPParams,
    AHAP,
    AHAPParams,
    MSU,
    ODOnly,
    RSEL_AVAIL,
    RSEL_FIXED,
    RSEL_NAMES,
    RSEL_PRED,
    RSEL_PRICE,
    RandDeadline,
    RandDeadlineParams,
    RegionSelector,
    RegionSelectorParams,
    UP,
    rand_commit_frac,
    uniform_commit_frac,
)
from repro.core.policy_pool import (
    PolicySpec,
    baseline_specs,
    paper_pool,
    rand_deadline_pool,
    region_pool,
    specs_to_arrays,
    uniform_rand_deadline_pool,
)
from repro.core.predictor import (
    ARIMAPredictor,
    NoisyPredictor,
    PerfectPredictor,
    RegionalPredictor,
    forecast_errors,
    noisy_matrix_batch,
    true_future_batch,
)
from repro.core.region_market import (
    RegionalMarket,
    RegionalSimResult,
    simulate_regional,
    vast_like_regions,
)
from repro.core.selector import (
    EGState,
    best_policy,
    eg_init,
    init_selector,
    iters_to_half,
    regret,
    regret_bound,
    run_eg_scan,
    select,
    update,
)
from repro.core.simulator import SimResult, simulate
from repro.core.throughput import calibrate, effective_work, mu_factor, throughput
from repro.core.window_opt import (
    brute_force_window,
    solve_window,
    solve_window_batch,
    solve_window_numpy,
)
