"""Config registry + assigned-architecture invariants."""
import pytest

from repro.configs import (
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    get_config,
    get_smoke_config,
    shape_applicable,
)

EXPECTED_PARAMS_B = {  # coarse (±20%) match to the public model sizes
    "qwen2-vl-7b": 7.1,
    "mamba2-370m": 0.37,
    "olmo-1b": 1.2,
    "zamba2-2.7b": 2.6,
    "qwen1.5-110b": 111.0,
    "mixtral-8x7b": 46.7,
    "mixtral-8x22b": 141.0,
    "granite-20b": 25.0,
    "command-r-plus-104b": 104.0,
    "hubert-xlarge": 0.96,
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_counts_match_public_sizes(arch):
    cfg = get_config(arch)
    got = cfg.param_count() / 1e9
    want = EXPECTED_PARAMS_B[arch]
    assert abs(got - want) / want < 0.20, (arch, got, want)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_configs_are_reduced(arch):
    s = get_smoke_config(arch)
    assert s.num_layers <= 2
    assert s.d_model <= 512
    if s.moe is not None:
        assert s.moe.num_experts <= 4
    assert s.arch_type == get_config(arch).arch_type


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_head_dims_consistent(arch):
    cfg = get_config(arch)
    if cfg.num_heads:
        assert cfg.num_heads % cfg.num_kv_heads == 0
        assert cfg.head_dim * cfg.num_heads >= cfg.d_model // 2


def test_shape_skip_rules():
    # encoder-only: no decode shapes
    hub = get_config("hubert-xlarge")
    assert not shape_applicable(hub, INPUT_SHAPES["decode_32k"])[0]
    assert not shape_applicable(hub, INPUT_SHAPES["long_500k"])[0]
    assert shape_applicable(hub, INPUT_SHAPES["train_4k"])[0]
    # long_500k: sub-quadratic only
    assert shape_applicable(get_config("mamba2-370m"), INPUT_SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("zamba2-2.7b"), INPUT_SHAPES["long_500k"])[0]
    assert shape_applicable(get_config("mixtral-8x7b"), INPUT_SHAPES["long_500k"])[0]  # SWA
    assert not shape_applicable(get_config("olmo-1b"), INPUT_SHAPES["long_500k"])[0]
    assert not shape_applicable(get_config("command-r-plus-104b"), INPUT_SHAPES["long_500k"])[0]


def test_moe_active_params():
    cfg = get_config("mixtral-8x7b")
    assert cfg.active_param_count() < cfg.param_count()
    assert 12.0e9 < cfg.active_param_count() < 14.5e9  # ~12.9B active


def test_lora_params_tiny():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        assert cfg.lora_param_count() < 0.02 * cfg.param_count()
