"""Path-based pytree partitioning — used to train LoRA params only.

``partition_by_path(tree, pred)`` returns the selected leaves (a flat list,
itself a valid pytree for grad/optimizer state) plus a merge function that
reinserts them into the full tree. The base model stays frozen by simply
never being part of the differentiated pytree.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

import jax


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def partition_by_path(tree, pred: Callable[[str], bool]):
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    sel_idx = [i for i, (p, _) in enumerate(paths_leaves) if pred(_path_str(p))]
    sel_set = set(sel_idx)
    sel = [paths_leaves[i][1] for i in sel_idx]
    rest = [l for i, (_, l) in enumerate(paths_leaves) if i not in sel_set]

    def merge(sel_leaves: List):
        assert len(sel_leaves) == len(sel_idx)
        out, ri, si = [], 0, 0
        for i in range(len(paths_leaves)):
            if i in sel_set:
                out.append(sel_leaves[si])
                si += 1
            else:
                out.append(rest[ri])
                ri += 1
        return jax.tree_util.tree_unflatten(treedef, out)

    return sel, merge


def is_lora_path(path: str) -> bool:
    return "lora" in path.split("/")


def select_paths(tree, pred: Callable[[str], bool]):
    """Just the selected (path, leaf) pairs."""
    paths_leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [( _path_str(p), l) for p, l in paths_leaves if pred(_path_str(p))]
