from repro.train.losses import cross_entropy, lm_loss, masked_prediction_loss, task_loss
from repro.train.step import (
    apply_grads,
    init_opt_state,
    make_decode_step,
    make_eval_step,
    make_grad_step,
    make_prefill_step,
    make_train_step,
)
