"""End-to-end driver: LoRA fine-tune a ~100M-parameter model for a few
hundred optimizer steps, with the paper's AHAP scheduler deciding the
instance allocation each market slot (spec deliverable b).

    PYTHONPATH=src python examples/elastic_finetune.py [--quick]

The global batch stays fixed while the instance count varies, so the loss
curve is the one a real elastic cluster would produce; reconfigurations do a
real checkpoint save/restore roundtrip.
"""
import argparse

import numpy as np

from repro.configs import TrainConfig, get_config
from repro.configs.base import JobConfig
from repro.core.market import vast_like_trace
from repro.core.policies import AHAP, AHAPParams
from repro.core.predictor import ARIMAPredictor
from repro.core.throughput import calibrate, tokens_per_slot
from repro.train.elastic import ElasticTrainer

ap = argparse.ArgumentParser()
ap.add_argument("--quick", action="store_true", help="reduced model + fewer steps")
args = ap.parse_args()

if args.quick:
    from repro.configs import get_smoke_config

    cfg = get_smoke_config("tiny-100m")
    tcfg = TrainConfig(seq_len=64, global_batch=4, lr=2e-3, total_steps=64)
    job = JobConfig(workload=12, deadline=5, n_min=1, n_max=6, value=30.0)
    spu = 1.5
else:
    cfg = get_config("tiny-100m")  # ~134M params
    tcfg = TrainConfig(seq_len=128, global_batch=8, lr=1e-3, total_steps=400)
    job = JobConfig(workload=50, deadline=8, n_min=1, n_max=10, value=80.0)
    spu = 5.0  # -> a few hundred steps across the job

tput = calibrate(cfg, bandwidth_bps=800e6)
print(f"model={cfg.name} ({cfg.param_count()/1e6:.0f}M params, "
      f"LoRA {cfg.lora_param_count()/1e6:.2f}M trainable)")
print(f"switching: mu1={tput.mu1:.3f} mu2={tput.mu2:.3f} "
      f"(~{tokens_per_slot(cfg)/1e6:.1f}M tokens/slot/instance on v5e)")

market = vast_like_trace(seed=4, days=2)
pred = ARIMAPredictor(market).matrix(5)
policy = AHAP(AHAPParams(omega=3, v=1, sigma=0.7))

trainer = ElasticTrainer(cfg, tcfg, job, tput, policy, market, pred,
                         steps_per_unit=spu)
report = trainer.run()

print(f"\nutility={report.utility:.2f} cost={report.cost:.2f} "
      f"T={report.completion_time:.2f}/{job.deadline} slots, "
      f"{report.total_steps} optimizer steps")
print(f"loss: {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")
print(f"\n{'slot':>4s} {'od':>3s} {'spot':>4s} {'price':>6s} {'mu':>5s} "
      f"{'steps':>5s} {'loss':>7s} {'ckpt':>9s}")
for s in report.slots:
    print(f"{s.t:4d} {s.n_od:3d} {s.n_spot:4d} {s.price:6.2f} {s.mu:5.2f} "
          f"{s.steps:5d} {s.mean_loss:7.3f} {s.ckpt_bytes:9d}")
