"""Granite-20B (code) [arXiv:2405.04324] — llama-style dense with MQA (kv=1)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        arch_type="dense",
        num_layers=52,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,   # multi-query attention
        d_ff=24576,
        vocab_size=49152,
        rope_theta=10000.0,
        norm_type="rmsnorm",
        mlp_act="silu",
        source="arXiv:2405.04324",
    )


def smoke_config() -> ModelConfig:
    return config().reduced(num_kv_heads=1)
