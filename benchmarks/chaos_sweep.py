"""Chaos sweep: fault intensity x fallback threshold over the 124-lane
pool, end to end through ``engine.simulate_and_select``.

The robustness claim this bench measures: when the spot market breaks in
ways the predictor did not see coming (preemption storms + price spikes
while the forecast stack stays stale), the prediction-consuming AHAP
lanes armed with the online fallback monitor (``repro.chaos.
FallbackConfig``) beat the same lanes running pure AHAP on the bad
forecasts — and the EG selector re-converges after the storms instead of
thrashing.

Regime (the *forced* storm regime the regression guard pins): an
abundant, cheap pre-storm market (so the stale forecasts are rosy),
deadline-tight workloads (so storm slots lost to phantom-spot deferral
are unrecoverable), and ``storm_schedule`` faults aligned with a
``pred_stale`` predictor freeze — the market turns, the forecasts don't.

Sweep structure per fault intensity (number of storms; 0 = clean):

  off       timed ``simulate_and_select`` with ``fallback=None``
  on        timed run per ``CHAOS_THRESHOLD`` value (each distinct
            FallbackConfig is a distinct compiled program — sweep few)
  collect   one untimed ``collect=True`` flight-recorder pass at the
            first threshold, pinned bitwise against the timed on-run's
            mean utilities, folded into the pool ledger's ``fallback``
            block (trigger/recovery reconciliation) and the selection
            ledger (top-policy switch trace = selector re-convergence)

Headline derived values (AHAP lanes only — cheap lanes carry no monitor):
``chaos_gain__s<max>`` (fallback-on minus fallback-off mean utility at
max intensity; the RUN_BENCH_REGRESSION guard pins it positive) and the
per-intensity on/off utilities.

Env knobs: CHAOS_JOBS (default 64), CHAOS_INTENSITY (comma-separated
storm counts, default "0,1,2"), CHAOS_THRESHOLD (comma-separated EWMA
thresholds, default "0.5"), CHAOS_STORM_LEN, CHAOS_SPIKE, CHAOS_LAM
(monitor EWMA weight), CHAOS_REPEAT,
CHAOS_LEDGER (path: write the collect-pass ledgers as a standalone JSON
artifact — the CI upload); POOL_SIM_MESH / POOL_SIM_JSON as everywhere.
"""
from __future__ import annotations

import json
import os
from typing import List, Tuple

import numpy as np

from benchmarks.common import (PAPER_TPUT, Row, StageTimer, job_stream_arrays,
                               merge_bench_rows, paper_market, timed)
from benchmarks.pool_sim_bench import _JSON_PATH

N_JOBS = int(os.environ.get("CHAOS_JOBS", "64"))
REPEAT = int(os.environ.get("CHAOS_REPEAT", "1"))
INTENSITY = tuple(int(x) for x in
                  os.environ.get("CHAOS_INTENSITY", "0,1,2").split(",") if x)
THRESHOLDS = tuple(float(x) for x in
                   os.environ.get("CHAOS_THRESHOLD", "0.5").split(",") if x)
STORM_LEN = int(os.environ.get("CHAOS_STORM_LEN", "4"))
SPIKE_MAG = float(os.environ.get("CHAOS_SPIKE", "2.5"))
LAM = float(os.environ.get("CHAOS_LAM", "0.5"))
LEDGER_JSON = os.environ.get("CHAOS_LEDGER", "")

# the forced storm regime: rosy pre-storm market + tight workloads (see
# module docstring); deadline matches the paper setting, workloads are
# scaled so the deadline has no slack to absorb a storm
MARKET_SEED = 11
JOB_SEED = 3
# seed 11 lands the single-storm case early in the window, so the monitor
# has clean slots to stand down in — the recovery telemetry is visible
FAULT_SEED = 11
DEADLINE = 10
WORKLOAD_SCALE = 1.4
NOISE_KIND = "magdep_uniform"
NOISE_LEVEL = 0.1
MARKET_KW = dict(avail_mean=9.0, mean_price=0.4, price_sigma=0.3)
PRED_FAULT = "stale"


def build_inputs(n_storms: int, n_jobs: int = N_JOBS):
    """Engine inputs for one fault intensity: the clean per-job windows
    (shared across intensities — paired comparison), faulted by one
    ``storm_schedule`` applied at window-relative slots, so every job
    rides through the same storms. Returns ``(jobs, prices, avail, preds,
    schedule)``."""
    from repro.chaos import inject, storm_schedule
    from repro.core import engine

    rng = np.random.default_rng(JOB_SEED)
    jobs = job_stream_arrays(rng, n_jobs, deadline=DEADLINE,
                             workload_scale=WORKLOAD_SCALE)
    trace = paper_market(MARKET_SEED, **MARKET_KW)
    t0s = np.random.default_rng(JOB_SEED + 1).integers(
        0, len(trace) - DEADLINE - 1, n_jobs)
    pw, aw, preds = engine.prepare_noisy_inputs(
        trace, t0s, DEADLINE, NOISE_KIND, NOISE_LEVEL,
        JOB_SEED * 100003 + np.arange(n_jobs))
    sched = storm_schedule(FAULT_SEED, pw.shape[1], n_storms=n_storms,
                           storm_len=STORM_LEN, spike_mag=SPIKE_MAG,
                           pred_fault=PRED_FAULT)
    pw, aw, preds = inject(pw, aw, preds, sched)
    return jobs, pw, aw, preds, sched


def run() -> List[Row]:
    import jax

    from repro.chaos import FallbackConfig
    from repro.core import engine
    from repro.core.policy_pool import (KIND_AHAP, baseline_specs, paper_pool,
                                        rand_deadline_pool, specs_to_arrays)
    from repro.launch.mesh import make_pool_mesh, parse_pool_mesh_shape
    from repro.obs import pool_ledger, selection_ledger

    pool = paper_pool() + rand_deadline_pool() + baseline_specs()
    arrs = specs_to_arrays(pool)
    ahap = np.asarray(arrs["kind"]) == KIND_AHAP
    mesh = make_pool_mesh(
        shape=parse_pool_mesh_shape(os.environ.get("POOL_SIM_MESH", "")))
    # lam 0.5 arms the monitor within one storm slot and disarms within a
    # few clean ones — both edges land inside a 10-slot window
    configs = [FallbackConfig(threshold=t, lam=LAM) for t in THRESHOLDS]

    def select(inputs, fallback, collect=False):
        jobs, pw, aw, preds, _ = inputs
        return engine.simulate_and_select(
            arrs, jobs, PAPER_TPUT, pw, aw, preds, mesh=mesh,
            collect=collect, fallback=fallback)

    st = StageTimer()
    rows: List[Row] = []
    ledgers = {}
    gains: List[Tuple[int, float]] = []
    for n_storms in INTENSITY:
        with st.stage(f"prep_s{n_storms}"):
            inputs = build_inputs(n_storms)
        select(inputs, None)                      # warm-up pays compilation
        res_off, us_off = timed(select, inputs, None, repeat=max(REPEAT, 1))
        u_off = float(res_off.mean_utility[ahap].mean())
        rows.append((f"chaos_off__s{n_storms}", us_off, u_off))
        for cfg in configs:
            select(inputs, cfg)
            res_on, us_on = timed(select, inputs, cfg, repeat=max(REPEAT, 1))
            u_on = float(res_on.mean_utility[ahap].mean())
            rows.append(
                (f"chaos_on__s{n_storms}_thr{cfg.threshold:g}", us_on, u_on))
            if cfg is configs[0]:
                gains.append((n_storms, u_on - u_off))
                # flight-recorder pass OUTSIDE the timed runs, pinned
                # bitwise to the timed on-run (collect only ADDS outputs)
                with st.stage(f"telemetry_s{n_storms}"):
                    res_c = select(inputs, cfg, collect=True)
                np.testing.assert_array_equal(res_c.mean_utility,
                                              res_on.mean_utility)
                led = pool_ledger(res_c.sim_out, inputs[0], PAPER_TPUT)
                sel = selection_ledger(res_c)
                ledgers[f"s{n_storms}"] = {"pool": led, "selection": sel}
                fb = led["fallback"]
                rows += [
                    (f"chaos_triggers__s{n_storms}", 0.0,
                     float(fb["triggers"])),
                    (f"chaos_recoveries__s{n_storms}", 0.0,
                     float(fb["recoveries"])),
                    (f"chaos_fallback_frac__s{n_storms}", 0.0,
                     fb["active_fraction"]),
                    (f"chaos_events_reconciled__s{n_storms}", 0.0,
                     float(fb["events_reconciled"])),
                    (f"chaos_selector_switches__s{n_storms}", 0.0,
                     float(sel["top_policy"]["n_switches"])),
                ]

    worst = max(INTENSITY)
    gain = dict(gains)[worst]
    rows.append((f"chaos_gain__s{worst}", 0.0, gain))
    rows += st.rows("chaos")

    extra = {
        "workload": {
            "jobs": N_JOBS, "slots": DEADLINE, "policies": len(pool),
            "ahap_lanes": int(ahap.sum()), "workload_scale": WORKLOAD_SCALE,
            "noise_kind": NOISE_KIND, "noise_level": NOISE_LEVEL,
        },
        "regime": {
            **MARKET_KW, "storm_len": STORM_LEN, "spike_mag": SPIKE_MAG,
            "pred_fault": PRED_FAULT, "intensity": list(INTENSITY),
            "thresholds": list(THRESHOLDS),
        },
        "gain_at_max_intensity": gain,
        "pool_mesh": "x".join(map(str, mesh.devices.shape)),
        "devices": jax.device_count(),
        "ledgers": ledgers,
    }
    merge_bench_rows(_JSON_PATH, "chaos", "chaos_sweep", rows, extra)
    if LEDGER_JSON:
        os.makedirs(os.path.dirname(LEDGER_JSON) or ".", exist_ok=True)
        with open(LEDGER_JSON, "w") as f:
            json.dump({"regime": extra["regime"], "ledgers": ledgers}, f,
                      indent=2)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
