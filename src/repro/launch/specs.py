"""ShapeDtypeStruct stand-ins for every model input (dry-run; no allocation).

``input_specs(cfg, shape)`` mirrors what the data pipeline / serving frontend
would feed the jitted step for that (architecture, input-shape) pair:
  train    -> the training batch (tokens or stub embeddings + targets)
  prefill  -> the prompt batch
  decode   -> ONE new token plus a KV/state cache of seq_len
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf


def _token_batch(cfg: ModelConfig, b: int, s: int, with_targets: bool):
    dt = jnp.dtype(cfg.dtype)
    out = {}
    if cfg.embed_inputs:
        out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
        if with_targets:
            out["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.m_rope:
        out["positions"] = jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
    if cfg.encoder_only and with_targets:
        out["loss_mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
    return out


def batch_axes(batch_spec):
    """Logical axes for a batch dict (string leaves, see sharding.axes_to_str)."""
    from repro.sharding import axes_to_str as a2s

    ax = {}
    for k, v in batch_spec.items():
        if k == "embeds":
            ax[k] = a2s(("batch", "seq", "embed"))
        elif k == "positions":
            ax[k] = a2s(("batch", "seq", None))
        else:
            ax[k] = a2s(("batch", "seq"))
    return ax


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Returns (batch_spec, cache_spec_or_None)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.mode == "train":
        return _token_batch(cfg, b, s, with_targets=True), None
    if shape.mode == "prefill":
        return _token_batch(cfg, b, s, with_targets=False), None
    if shape.mode == "decode":
        one = _token_batch(cfg, b, 1, with_targets=False)
        cache = jax.eval_shape(lambda: tf.init_cache(cfg, b, s))
        return one, cache
    raise ValueError(shape.mode)
