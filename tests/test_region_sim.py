"""Region-aware pool simulation: R=1 bitwise parity, reference parity,
migration-cost accounting, and the hysteresis no-thrash property."""
import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core import fast_sim
from repro.core.market import constant_trace, from_arrays, vast_like_trace
from repro.core.policies import (
    RSEL_AVAIL,
    RSEL_PRED,
    RSEL_PRICE,
)
from repro.core.policy_pool import (
    KIND_MSU,
    PolicySpec,
    baseline_specs,
    paper_pool,
    rand_deadline_pool,
    region_pool,
    specs_to_arrays,
)
from repro.core.predictor import NoisyPredictor, RegionalPredictor
from repro.core.region_market import (
    RegionalMarket,
    simulate_regional,
    vast_like_regions,
)

JOB = JobConfig(workload=80, deadline=10, n_min=1, n_max=12, value=120.0)
TPUT = ThroughputConfig(mu1=0.9, mu2=0.95)


def _mixed_pool():
    return (paper_pool(omegas=(1, 3, 5), sigmas=(0.3, 0.7))
            + rand_deadline_pool((0.2, 0.6)) + baseline_specs())


def test_r1_bitwise_parity_with_simulate_pool_jobs():
    """The acceptance pin: with one region, every simulate_pool_jobs leaf is
    BITWISE-identical through the region-aware scans (mixed AHAP + cheap
    kinds, region lanes' where-branches all passthrough), and no lane ever
    migrates."""
    arrs = specs_to_arrays(_mixed_pool())
    jobs_list = [JOB,
                 JobConfig(workload=100, deadline=10, n_min=2, n_max=14,
                           value=120.0)]
    stacked = fast_sim.stack_jobs(jobs_list)
    prices_l, avail_l, pm_l, rp_l, ra_l, rpm_l = [], [], [], [], [], []
    for seed in range(len(jobs_list)):
        tr = vast_like_trace(seed=30 + seed, days=1).window(0, 10)
        pred = NoisyPredictor(tr, "fixed_uniform", 0.2, seed=seed).matrix(
            fast_sim.W1MAX - 1
        )
        prices, avail, pm = fast_sim.prepare_inputs(tr, pred, JOB.deadline)
        rp, ra, rpm = fast_sim.prepare_inputs_regions(
            RegionalMarket.from_traces([tr]), pred[None], JOB.deadline
        )
        prices_l.append(prices); avail_l.append(avail); pm_l.append(pm)
        rp_l.append(rp); ra_l.append(ra); rpm_l.append(rpm)
    single = fast_sim.simulate_pool_jobs(
        arrs, stacked, TPUT, np.stack(prices_l), np.stack(avail_l),
        np.stack(pm_l),
    )
    regional = fast_sim.simulate_pool_regions(
        arrs, stacked, TPUT, np.stack(rp_l), np.stack(ra_l), np.stack(rpm_l),
        delta_mig=1,
    )
    for k in single:
        np.testing.assert_array_equal(
            np.asarray(single[k]), np.asarray(regional[k]), err_msg=k
        )
    assert np.all(np.asarray(regional["migrations"]) == 0)
    assert np.all(np.asarray(regional["region"]) == 0)


def test_regions_sharded_single_device_fallback_bitwise():
    """simulate_pool_regions_sharded must fall through to (and bitwise-match)
    simulate_pool_regions on one visible device, for the default mesh and
    explicit 1-device meshes of either rank. The real multi-device parity
    (jobs / lanes / 2-D layouts under 4 forced host devices) runs in the
    tests/test_sharded_pool.py subprocess."""
    import jax

    from repro.launch.mesh import make_pool_mesh

    assert jax.device_count() == 1
    mkt = vast_like_regions(3, seed=5, days=1).window(0, 11)
    rpred = RegionalPredictor(
        mkt, lambda t, r: NoisyPredictor(t, "fixed_uniform", 0.2, seed=r)
    ).matrix(fast_sim.W1MAX - 1)
    arrs = specs_to_arrays(region_pool())
    rp, ra, rpm = fast_sim.prepare_inputs_regions(mkt, rpred, JOB.deadline)
    stacked = fast_sim.stack_jobs([JOB])
    tile = lambda x: np.asarray(x)[None]
    base = fast_sim.simulate_pool_regions(
        arrs, stacked, TPUT, tile(rp), tile(ra), tile(rpm), delta_mig=1
    )
    for mesh in (None, make_pool_mesh(), make_pool_mesh(shape=(1, 1))):
        sh = fast_sim.simulate_pool_regions_sharded(
            arrs, stacked, TPUT, tile(rp), tile(ra), tile(rpm),
            delta_mig=1, mesh=mesh,
        )
        for k in base:
            np.testing.assert_array_equal(
                np.asarray(base[k]), np.asarray(sh[k]), err_msg=k
            )


def test_region_lanes_match_python_reference():
    """Every region_pool lane (AHAP/AHANP/MSU/UP x strategy x margin) agrees
    with the python reference simulator (simulate_regional +
    policies.RegionSelector) on a 3-region phase-shifted market — utility,
    migration count, and per-slot region path."""
    mkt = vast_like_regions(3, seed=1, days=1).window(0, 11)
    rpred = RegionalPredictor(
        mkt, lambda t, r: NoisyPredictor(t, "fixed_uniform", 0.2, seed=r)
    ).matrix(fast_sim.W1MAX - 1)
    pool = region_pool()
    arrs = specs_to_arrays(pool)
    rp, ra, rpm = fast_sim.prepare_inputs_regions(mkt, rpred, JOB.deadline)
    out = fast_sim.simulate_pool_regions(
        arrs, fast_sim.stack_jobs([JOB]), TPUT,
        np.asarray(rp)[None], np.asarray(ra)[None], np.asarray(rpm)[None],
        delta_mig=mkt.delta_mig,
    )
    uj = np.asarray(out["utility"])[0]
    migs = np.asarray(out["migrations"])[0]
    regions = np.asarray(out["region"])[0]
    for i, spec in enumerate(pool):
        r = simulate_regional(
            spec.build(), spec.build_selector(), JOB, TPUT, mkt,
            np.asarray(rpm),
        )
        assert abs(r.utility - uj[i]) < 1e-2, (spec.name, r.utility, uj[i])
        assert r.migrations == int(migs[i]), spec.name
        # the reference breaks out of its loop on completion; compare the
        # region path only up to that point
        done_at = len(r.region_hist)
        if r.completed_by_deadline:
            done_at = int(np.ceil(r.completion_time))
        np.testing.assert_array_equal(
            regions[i, :done_at], r.region_hist[:done_at], err_msg=spec.name
        )


def test_migration_cost_accounting_two_region_toy():
    """Hand-checked 2-region crossover: MSU@greedy_price rides region 0's
    cheap spot for 4 slots, pays exactly one delta_mig slot (zero instances,
    zero billing) to move when the price advantage flips, then rides
    region 1. Cost and progress match the hand-derived numbers."""
    job = JobConfig(workload=200.0, deadline=8, n_min=1, n_max=4, value=120.0)
    tput = ThroughputConfig(alpha=1.0, beta=0.0, mu1=0.9, mu2=0.95)
    p0 = np.array([0.2] * 4 + [0.9] * 4)
    p1 = np.array([0.8] * 4 + [0.3] * 4)
    av = np.full(8, 4, np.int64)
    mkt = RegionalMarket.from_traces(
        [from_arrays(p0, av), from_arrays(p1, av)], delta_mig=1
    )
    spec = PolicySpec(KIND_MSU, rsel=RSEL_PRICE, rmargin=0.0)
    arrs = specs_to_arrays([spec])
    rp, ra, rpm = fast_sim.prepare_inputs_regions(mkt, None, job.deadline)
    out = fast_sim.simulate_pool_regions(
        arrs, fast_sim.stack_jobs([job]), tput,
        np.asarray(rp)[None], np.asarray(ra)[None], np.asarray(rpm)[None],
        delta_mig=1,
    )
    region = np.asarray(out["region"])[0, 0]
    n_spot = np.asarray(out["n_spot"])[0, 0]
    np.testing.assert_array_equal(region, [0] * 4 + [1] * 4)
    # slot 4 is the checkpoint transfer: zero instances
    np.testing.assert_array_equal(n_spot, [4, 4, 4, 4, 0, 4, 4, 4])
    assert int(np.asarray(out["migrations"])[0, 0]) == 1
    # progress: mu1-discounted ramp after each 0->4 jump
    # slots 0-3: 0.9*4 + 4+4+4 = 15.6 ; slot 4: 0 ; slots 5-7: 3.6+4+4
    z_exp = 15.6 + 0.0 + 11.6
    assert abs(float(np.asarray(out["z_ddl"])[0, 0]) - z_exp) < 1e-4
    # billing: 4 slots at 0.2, the migration slot free, 3 slots at 0.3,
    # then the termination configuration finishes the remainder on-demand
    run_cost = 4 * 4 * 0.2 + 3 * 4 * 0.3
    term_cost = job.on_demand_price * job.n_max * (job.workload - z_exp) / 4.0
    assert abs(float(np.asarray(out["cost"])[0, 0])
               - (run_cost + term_cost)) < 1e-3
    # reference agrees
    ref = simulate_regional(spec.build(), spec.build_selector(), job, tput,
                            mkt, None)
    assert ref.migrations == 1
    assert abs(ref.cost - (run_cost + term_cost)) < 1e-3


def test_per_region_od_price_accounting_two_region_toy():
    """Hand-checked per-region on-demand pricing: the crossover toy of
    test_migration_cost_accounting_two_region_toy with od multipliers
    (1.0, 2.0). MSU@greedy_price selects regions on SPOT prices and never
    buys on-demand inside the window, so the region path, allocations and
    running spot cost are unchanged — only the termination configuration,
    billed at the final region's od rate, doubles. A scalar multiplier of
    1.0 must be a bitwise no-op (the shipped-program pin), and the python
    reference (market.p_od) must agree with the fast path."""
    job = JobConfig(workload=200.0, deadline=8, n_min=1, n_max=4, value=120.0)
    tput = ThroughputConfig(alpha=1.0, beta=0.0, mu1=0.9, mu2=0.95)
    p0 = np.array([0.2] * 4 + [0.9] * 4)
    p1 = np.array([0.8] * 4 + [0.3] * 4)
    av = np.full(8, 4, np.int64)
    traces = [from_arrays(p0, av), from_arrays(p1, av)]
    p_od = np.array([1.0, 2.0])
    mkt = RegionalMarket.from_traces(traces, delta_mig=1, p_od=p_od)
    spec = PolicySpec(KIND_MSU, rsel=RSEL_PRICE, rmargin=0.0)
    arrs = specs_to_arrays([spec])
    stacked = fast_sim.stack_jobs([job])
    rp, ra, rpm = fast_sim.prepare_inputs_regions(mkt, None, job.deadline)
    tile = lambda x: np.asarray(x)[None]
    run = lambda po: fast_sim.simulate_pool_regions(
        arrs, stacked, tput, tile(rp), tile(ra), tile(rpm),
        delta_mig=1, p_od=po,
    )
    out = run(mkt.p_od)
    # region path and allocations are untouched by the od multipliers
    np.testing.assert_array_equal(np.asarray(out["region"])[0, 0],
                                  [0] * 4 + [1] * 4)
    np.testing.assert_array_equal(np.asarray(out["n_spot"])[0, 0],
                                  [4, 4, 4, 4, 0, 4, 4, 4])
    assert not np.asarray(out["n_od"])[0, 0].any()
    # spot billing as in the base toy; termination finishes on-demand in
    # the final region (r1) at DOUBLE the flat od rate
    z_exp = 15.6 + 0.0 + 11.6
    run_cost = 4 * 4 * 0.2 + 3 * 4 * 0.3
    term = 2.0 * job.on_demand_price * job.n_max * (job.workload - z_exp) / 4.0
    assert abs(float(np.asarray(out["cost"])[0, 0]) - (run_cost + term)) < 1e-3
    # base toy (flat od) differs by exactly the doubled termination leg
    base = run(None)
    flat_term = term / 2.0
    assert abs(float(np.asarray(out["cost"])[0, 0])
               - float(np.asarray(base["cost"])[0, 0]) - flat_term) < 1e-3
    # scalar 1.0 multiplier: IEEE-exact no-op, every leaf bitwise
    ones = run(1.0)
    for k in base:
        np.testing.assert_array_equal(
            np.asarray(base[k]), np.asarray(ones[k]), err_msg=k
        )
    # the python reference sees market.p_od and lands on the same books
    ref = simulate_regional(spec.build(), spec.build_selector(), job, tput,
                            mkt, None)
    assert ref.migrations == 1
    np.testing.assert_array_equal(ref.region_hist, [0] * 4 + [1] * 4)
    assert abs(ref.cost - (run_cost + term)) < 1e-3
    assert abs(ref.cost - float(np.asarray(out["cost"])[0, 0])) < 1e-3
    # sharded entry forwards p_od (single-device fallthrough, bitwise)
    sh = fast_sim.simulate_pool_regions_sharded(
        arrs, stacked, tput, tile(rp), tile(ra), tile(rpm),
        delta_mig=1, p_od=mkt.p_od,
    )
    for k in out:
        np.testing.assert_array_equal(
            np.asarray(out[k]), np.asarray(sh[k]), err_msg=k
        )


def test_hysteresis_prevents_thrash():
    """Alternating-argmin market (price lead flips every slot by 0.05): the
    margin-0 greedy lane thrashes, the sticky lane (margin > oscillation)
    never migrates after free initial placement — and with a nonzero
    migration cost the sticky lane's utility strictly wins."""
    d = 10
    t = np.arange(d)
    p0 = 0.50 + 0.05 * (t % 2)
    p1 = 0.55 - 0.05 * (t % 2)
    av = np.full(d, 8, np.int64)
    mkt = RegionalMarket.from_traces(
        [from_arrays(p0, av), from_arrays(p1, av)], delta_mig=2
    )
    specs = [
        PolicySpec(KIND_MSU, rsel=RSEL_PRICE, rmargin=0.0),    # thrasher
        PolicySpec(KIND_MSU, rsel=RSEL_PRICE, rmargin=0.10),   # sticky
    ]
    arrs = specs_to_arrays(specs)
    rp, ra, rpm = fast_sim.prepare_inputs_regions(mkt, None, d)
    job = JobConfig(workload=200.0, deadline=d, n_min=1, n_max=8, value=120.0)
    out = fast_sim.simulate_pool_regions(
        arrs, fast_sim.stack_jobs([job]), TPUT,
        np.asarray(rp)[None], np.asarray(ra)[None], np.asarray(rpm)[None],
        delta_mig=2,
    )
    migs = np.asarray(out["migrations"])[0]
    assert migs[0] >= 3, migs          # greedy chases every flip
    assert migs[1] == 0, migs          # hysteresis holds the home region
    util = np.asarray(out["utility"])[0]
    assert util[1] > util[0], util     # thrash pays delta_mig repeatedly


def test_free_migration_when_delta_zero():
    """delta_mig=0 models preemptible-checkpoint-free moves: switches happen
    but no slot is lost and no allocation is zeroed."""
    d = 8
    p0 = np.array([0.2] * 4 + [0.9] * 4)
    p1 = np.array([0.8] * 4 + [0.3] * 4)
    av = np.full(d, 4, np.int64)
    mkt = RegionalMarket.from_traces(
        [from_arrays(p0, av), from_arrays(p1, av)], delta_mig=0
    )
    job = JobConfig(workload=200.0, deadline=d, n_min=1, n_max=4, value=120.0)
    tput = ThroughputConfig(alpha=1.0, beta=0.0, mu1=0.9, mu2=0.95)
    arrs = specs_to_arrays([PolicySpec(KIND_MSU, rsel=RSEL_PRICE)])
    rp, ra, rpm = fast_sim.prepare_inputs_regions(mkt, None, d)
    out = fast_sim.simulate_pool_regions(
        arrs, fast_sim.stack_jobs([job]), tput,
        np.asarray(rp)[None], np.asarray(ra)[None], np.asarray(rpm)[None],
        delta_mig=0,
    )
    np.testing.assert_array_equal(np.asarray(out["n_spot"])[0, 0], [4] * d)
    assert int(np.asarray(out["migrations"])[0, 0]) == 1
    # cost: 4 slots at 0.2 then 4 at 0.3, no lost slot
    run_cost = 4 * 4 * 0.2 + 4 * 4 * 0.3
    z_exp = 0.9 * 4 + 7 * 4  # one mu1 ramp, constant 4 thereafter
    term = job.on_demand_price * 4 * (200.0 - z_exp) / 4.0
    assert abs(float(np.asarray(out["cost"])[0, 0]) - (run_cost + term)) < 1e-3


def test_no_migration_after_completion():
    """A job that finishes before the price lead flips must not be moved (or
    counted as migrating) by post-completion score changes — the reference
    loop stops at completion and the fast scan freezes the region state."""
    d = 10
    p0 = np.array([0.2] * 5 + [0.9] * 5)
    p1 = np.array([0.8] * 5 + [0.3] * 5)
    av = np.full(d, 8, np.int64)
    mkt = RegionalMarket.from_traces(
        [from_arrays(p0, av), from_arrays(p1, av)], delta_mig=1
    )
    # finishes in ~2 slots at n_max=8, long before the flip at t=5
    job = JobConfig(workload=10.0, deadline=d, n_min=1, n_max=8, value=120.0)
    spec = PolicySpec(KIND_MSU, rsel=RSEL_PRICE)
    arrs = specs_to_arrays([spec])
    rp, ra, rpm = fast_sim.prepare_inputs_regions(mkt, None, d)
    out = fast_sim.simulate_pool_regions(
        arrs, fast_sim.stack_jobs([job]), TPUT,
        np.asarray(rp)[None], np.asarray(ra)[None], np.asarray(rpm)[None],
        delta_mig=1,
    )
    assert bool(np.asarray(out["completed"])[0, 0])
    assert int(np.asarray(out["migrations"])[0, 0]) == 0
    np.testing.assert_array_equal(np.asarray(out["region"])[0, 0], 0)
    ref = simulate_regional(spec.build(), spec.build_selector(), job, TPUT,
                            mkt, None)
    assert ref.migrations == 0


def test_no_migration_after_deadline_heterogeneous_batch():
    """In a stacked batch the scan runs dmax slots for every job; a job
    whose own deadline expired (missed, not completed) must not be moved by
    — or charged migrations for — score flips after its deadline."""
    dmax = 10
    p0 = np.array([0.2] * 6 + [0.9] * 4)
    p1 = np.array([0.8] * 6 + [0.3] * 4)
    av = np.full(dmax, 2, np.int64)
    mkt = RegionalMarket.from_traces(
        [from_arrays(p0, av), from_arrays(p1, av)], delta_mig=1
    )
    # job 0: deadline 5, huge workload -> misses, expires before the t=6
    # flip; job 1: deadline 10 -> legitimately migrates at the flip
    jobs = [
        JobConfig(workload=500.0, deadline=5, n_min=1, n_max=2, value=120.0),
        JobConfig(workload=500.0, deadline=dmax, n_min=1, n_max=2,
                  value=120.0),
    ]
    spec = PolicySpec(KIND_MSU, rsel=RSEL_PRICE)
    arrs = specs_to_arrays([spec])
    rp, ra, rpm = fast_sim.prepare_inputs_regions(mkt, None, dmax)
    tile = lambda x: np.repeat(np.asarray(x)[None], 2, axis=0)
    out = fast_sim.simulate_pool_regions(
        arrs, fast_sim.stack_jobs(jobs), TPUT,
        tile(rp), tile(ra), tile(rpm), delta_mig=1,
    )
    migs = np.asarray(out["migrations"])[:, 0]
    assert migs[0] == 0 and migs[1] == 1, migs
    np.testing.assert_array_equal(np.asarray(out["region"])[0, 0], 0)
    for ji, job in enumerate(jobs):  # reference agrees per job
        ref = simulate_regional(spec.build(), spec.build_selector(), job,
                                TPUT, mkt, None)
        assert ref.migrations == int(migs[ji]), ji


def test_short_horizon_pred_scores_match_reference():
    """pred_horizon with a predictor horizon SHORTER than the scoring window:
    prepare_inputs_regions edge-pads the forecast and the reference selector
    pads identically (RSEL_PRED_WINDOW), so both sides pick the same regions.
    Region 0 dangles a 2-slot teaser rate that a short forecast would
    overweight without the shared padding convention."""
    d, h = 8, 2  # h+1 = 3 < W1MAX = 6
    p0 = np.array([0.3, 0.3] + [0.9] * (d - 2))
    p1 = np.full(d, 0.5)
    av = np.full(d, 8, np.int64)
    mkt = RegionalMarket.from_traces(
        [from_arrays(p0, av), from_arrays(p1, av)], delta_mig=1
    )
    pred = RegionalPredictor(mkt).matrix(h)
    spec = PolicySpec(KIND_MSU, rsel=RSEL_PRED)
    arrs = specs_to_arrays([spec])
    rp, ra, rpm = fast_sim.prepare_inputs_regions(mkt, pred, d)
    job = JobConfig(workload=500.0, deadline=d, n_min=1, n_max=8, value=120.0)
    out = fast_sim.simulate_pool_regions(
        arrs, fast_sim.stack_jobs([job]), TPUT,
        np.asarray(rp)[None], np.asarray(ra)[None], np.asarray(rpm)[None],
        delta_mig=1,
    )
    # the reference consumes the RAW (h+1)-entry forecast and pads inside
    # RegionSelector.scores; the fast path consumes the padded rpm
    ref = simulate_regional(spec.build(), spec.build_selector(), job, TPUT,
                            mkt, pred)
    np.testing.assert_array_equal(
        np.asarray(out["region"])[0, 0], ref.region_hist
    )
    assert int(np.asarray(out["migrations"])[0, 0]) == ref.migrations
    assert abs(float(np.asarray(out["utility"])[0, 0]) - ref.utility) < 1e-2


def test_greedy_avail_follows_capacity():
    """greedy_avail ignores price and tracks the deeper pool."""
    d = 6
    av0 = np.array([8, 8, 8, 1, 1, 1], np.int64)
    av1 = np.array([1, 1, 1, 8, 8, 8], np.int64)
    pr = np.full(d, 0.5)
    mkt = RegionalMarket.from_traces(
        [from_arrays(pr, av0), from_arrays(pr, av1)], delta_mig=0
    )
    arrs = specs_to_arrays([PolicySpec(KIND_MSU, rsel=RSEL_AVAIL)])
    rp, ra, rpm = fast_sim.prepare_inputs_regions(mkt, None, d)
    job = JobConfig(workload=500.0, deadline=d, n_min=1, n_max=8, value=120.0)
    out = fast_sim.simulate_pool_regions(
        arrs, fast_sim.stack_jobs([job]), TPUT,
        np.asarray(rp)[None], np.asarray(ra)[None], np.asarray(rpm)[None],
        delta_mig=0,
    )
    np.testing.assert_array_equal(
        np.asarray(out["region"])[0, 0], [0, 0, 0, 1, 1, 1]
    )


def test_pred_horizon_lane_uses_forecasts():
    """pred_horizon scores average the forecast window: a region that is
    cheap now but predicted to collapse loses to a region predicted cheap
    throughout."""
    d, h = 6, fast_sim.W1MAX - 1
    # region 0: cheap at t=0 but predicted expensive after; region 1: flat 0.5
    p0 = np.array([0.3] + [1.0] * (d - 1))
    p1 = np.full(d, 0.5)
    av = np.full(d, 8, np.int64)
    mkt = RegionalMarket.from_traces(
        [from_arrays(p0, av), from_arrays(p1, av)], delta_mig=1
    )
    pred = RegionalPredictor(mkt).matrix(h)  # perfect foresight
    arrs = specs_to_arrays([
        PolicySpec(KIND_MSU, rsel=RSEL_PRICE),
        PolicySpec(KIND_MSU, rsel=RSEL_PRED),
    ])
    rp, ra, rpm = fast_sim.prepare_inputs_regions(mkt, pred, d)
    job = JobConfig(workload=500.0, deadline=d, n_min=1, n_max=8, value=120.0)
    out = fast_sim.simulate_pool_regions(
        arrs, fast_sim.stack_jobs([job]), TPUT,
        np.asarray(rp)[None], np.asarray(ra)[None], np.asarray(rpm)[None],
        delta_mig=1,
    )
    region = np.asarray(out["region"])[0]
    assert region[0, 0] == 0          # greedy-price grabs the teaser rate
    assert np.all(region[1] == 1)     # pred-horizon sees through it
    # the predictive lane never pays the migration the greedy lane must make
    migs = np.asarray(out["migrations"])[0]
    assert migs[1] == 0 and migs[0] >= 1
    util = np.asarray(out["utility"])[0]
    assert util[1] > util[0]
