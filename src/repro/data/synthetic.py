"""Synthetic fine-tuning data: a deterministic token stream with enough
structure that LM loss visibly decreases (bigram-ish Markov source), plus
instruction-style (prompt, completion) pairs with loss masks — and the
vectorized multi-regime market generator behind the scenario-grid harness
(:func:`market_regime_batch`).

Real deployments would swap this for a tokenized corpus reader; everything
downstream (packing, sharding, elastic trainer) is source-agnostic.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np


def _ar1_rows(e: np.ndarray, rho: float) -> np.ndarray:
    """Row-batched AR(1): x[:, i] = rho * x[:, i-1] + e[:, i], x[:, 0] = 0.
    Elementwise over the regime axis, so each row is bitwise-equal to
    ``market._ar1`` fed the same innovations."""
    x = np.zeros_like(e)
    for i in range(1, e.shape[1]):
        x[:, i] = rho * x[:, i - 1] + e[:, i]
    return x


def market_regime_batch(
    seeds,
    days: float = 10.0,
    slots_per_day: int = 48,
    *,
    mean_price=0.45,
    price_sigma=0.32,
    price_season_amp: float = 0.12,
    avail_mean=8.0,
    avail_season_amp=3.5,
    avail_sigma=2.0,
    avail_max: int = 16,
    price_avail_corr: float = -0.5,
    rho: float = 0.85,
    season_phase_slots: float = 0.0,
):
    """Vectorized multi-regime :func:`repro.core.market.vast_like_trace`.

    ``seeds`` is (R,); ``mean_price`` / ``price_sigma`` / ``avail_mean`` /
    ``avail_season_amp`` / ``avail_sigma`` broadcast to (R,) — one market
    regime per row. Returns ``(prices (R, T) f64, avail (R, T) i64)``.

    Row r is bitwise-equal to ``vast_like_trace(seed=seeds[r], ...)`` with
    that row's parameters (pinned in tests/test_scenario_grid.py): the
    per-seed ``np.random.default_rng`` draws are issued in exactly the
    scalar constructor's order (price innovations first, then availability)
    — the one per-row loop left, like predictor.noisy_matrix_batch — and
    every transform around them is elementwise over the regime axis,
    including the AR(1) recursion (row-batched in :func:`_ar1_rows`).
    Because each row depends only on its own (seed, params), a regime's
    market is invariant to the grid composition around it.
    """
    seeds = np.asarray(seeds)
    R = seeds.shape[0]
    n = int(days * slots_per_day)
    mp = np.broadcast_to(np.asarray(mean_price, float), (R,))
    ps = np.broadcast_to(np.asarray(price_sigma, float), (R,))
    am = np.broadcast_to(np.asarray(avail_mean, float), (R,))
    aa = np.broadcast_to(np.asarray(avail_season_amp, float), (R,))
    av_sig = np.broadcast_to(np.asarray(avail_sigma, float), (R,))

    tod = (
        2 * np.pi
        * ((np.arange(n) - season_phase_slots) % slots_per_day)
        / slots_per_day
    )
    season = np.cos(tod)

    e_p = np.empty((R, n))
    e_a = np.empty((R, n))
    for r in range(R):
        rng = np.random.default_rng(int(seeds[r]))
        e_p[r] = rng.normal(0, ps[r] * np.sqrt(1 - rho**2), n)
        e_a[r] = rng.normal(0, av_sig[r] * np.sqrt(1 - rho**2), n)

    z_price = _ar1_rows(e_p, rho)
    prices = mp[:, None] * np.exp(
        price_season_amp * season[None, :] + z_price - 0.5 * ps[:, None] ** 2
    )
    prices = np.clip(prices, 0.02, 1.5)

    z_av = _ar1_rows(e_a, rho)
    corr_term = (
        price_avail_corr
        * (z_price / np.maximum(ps, 1e-9)[:, None])
        * av_sig[:, None]
    )
    avail = (
        am[:, None]
        - aa[:, None] * season[None, :]
        + z_av * np.sqrt(1 - price_avail_corr**2)
        + corr_term
    )
    avail = np.clip(np.round(avail), 0, avail_max).astype(np.int64)
    return prices.astype(np.float64), avail


def market_regime_fault_batch(
    seeds,
    fault_seeds,
    days: float = 10.0,
    slots_per_day: int = 48,
    *,
    n_storms=2,
    storm_len: int = 4,
    spike_mag: float = 1.0,
    pred_fault="stale",
    **regime_kw,
):
    """:func:`market_regime_batch` with a per-row seeded preemption-storm
    schedule on top — faults become one more scenario-grid axis.

    ``fault_seeds`` is (R,) like ``seeds``; ``n_storms`` broadcasts to
    (R,) so a grid can sweep fault *intensity* across rows (0 storms = the
    clean regime, bitwise-equal to :func:`market_regime_batch`). Returns
    ``(prices (R, T), avail (R, T), schedules)`` where ``schedules`` is
    the R-tuple of per-row ``FaultSpec`` tuples — feed each row's schedule
    to :func:`repro.chaos.inject` to fault that row's forecast stack the
    same way.
    """
    from repro.chaos import inject_market, storm_schedule

    prices, avail = market_regime_batch(
        seeds, days, slots_per_day, **regime_kw)
    fault_seeds = np.asarray(fault_seeds)
    R, T = prices.shape
    if fault_seeds.shape != (R,):
        raise ValueError(
            f"fault_seeds must be shape ({R},), got {fault_seeds.shape}")
    ns = np.broadcast_to(np.asarray(n_storms, int), (R,))
    schedules = tuple(
        storm_schedule(int(fault_seeds[r]), T, n_storms=int(ns[r]),
                       storm_len=storm_len, spike_mag=spike_mag,
                       pred_fault=pred_fault)
        for r in range(R)
    )
    for r, sched in enumerate(schedules):
        if sched:
            prices[r], avail[r] = inject_market(prices[r], avail[r], sched)
    return prices, avail, schedules


class MarkovLM:
    """Order-1 Markov chain over the vocab with a few latent 'topics'."""

    def __init__(self, vocab_size: int, seed: int = 0, n_topics: int = 4):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        self.n_topics = n_topics
        # sparse-ish transition structure: each token has ~16 likely successors
        self.succ = rng.integers(0, vocab_size, size=(n_topics, vocab_size, 16))
        self.topic_stick = 0.995

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, np.int64)
        tok = int(rng.integers(self.vocab))
        topic = int(rng.integers(self.n_topics))
        for i in range(length):
            out[i] = tok
            if rng.random() > self.topic_stick:
                topic = int(rng.integers(self.n_topics))
            if rng.random() < 0.9:
                tok = int(self.succ[topic, tok, rng.integers(16)])
            else:
                tok = int(rng.integers(self.vocab))
        return out


def token_stream(
    vocab_size: int, seq_len: int, seed: int = 0, doc_len: int = 512
) -> Iterator[np.ndarray]:
    """Infinite stream of (seq_len,) int32 sequences (packed docs)."""
    src = MarkovLM(vocab_size, seed)
    rng = np.random.default_rng(seed + 1)
    buf = np.empty(0, np.int64)
    while True:
        while len(buf) < seq_len:
            buf = np.concatenate([buf, src.sample(rng, doc_len)])
        yield buf[:seq_len].astype(np.int32)
        buf = buf[seq_len:]


def lm_batches(
    vocab_size: int,
    global_batch: int,
    seq_len: int,
    seed: int = 0,
    num_batches: Optional[int] = None,
) -> Iterator[dict]:
    """Batches {'tokens': (B, S) int32} for next-token training."""
    stream = token_stream(vocab_size, seq_len, seed)
    i = 0
    while num_batches is None or i < num_batches:
        yield {"tokens": np.stack([next(stream) for _ in range(global_batch)])}
        i += 1
