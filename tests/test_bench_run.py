"""benchmarks/run.py CLI: --only resolution must error on unknown names
instead of silently skipping typos (a misspelled ``--only pool_sim,felt_sim``
used to drop the fleet bench without a word), and a crashing benchmark
module must degrade to an error row + nonzero exit instead of taking the
whole sweep down."""
import json
import sys
import types

import pytest

from benchmarks.run import MODULES, main, select_modules


def test_select_modules_empty_selects_all():
    selected, unknown = select_modules("")
    assert selected == MODULES
    assert unknown == []


def test_select_modules_prefixes():
    selected, unknown = select_modules("pool_sim,scenario_grid")
    assert selected == ["pool_sim_bench", "scenario_grid"]
    assert unknown == []
    # prefix semantics: fig1 matches fig10_adaptation too? no — fig1 is a
    # prefix of both fig1_throughput and fig10_adaptation, and both match
    selected, _ = select_modules("fig1")
    assert selected == ["fig1_throughput", "fig10_adaptation"]


def test_select_modules_reports_unknown():
    selected, unknown = select_modules("pool_sim,felt_sim")
    assert selected == ["pool_sim_bench"]
    assert unknown == ["felt_sim"]


def test_main_errors_on_unknown_name(monkeypatch):
    """The CLI refuses a typo'd --only up front (before importing or
    running any benchmark module) and names the offender."""
    monkeypatch.setattr(
        sys, "argv", ["benchmarks.run", "--only", "pool_sim,felt_sim"]
    )
    with pytest.raises(SystemExit) as exc_info:
        main()
    assert "felt_sim" in str(exc_info.value)
    assert "pool_sim_bench" in str(exc_info.value)  # lists known modules


def test_failing_module_degrades_to_error_row(monkeypatch, tmp_path, capsys):
    """One crashing module: the sweep keeps going, the --json payload
    carries an ``{"error": ...}`` row naming the exception, the healthy
    module's rows survive, and the exit code is 1."""
    import benchmarks.run as run_mod

    ok = types.ModuleType("benchmarks.fake_ok")
    ok.run = lambda: [("ok_row", 1.0, 2.0)]
    boom = types.ModuleType("benchmarks.fake_boom")

    def _boom():
        raise RuntimeError("synthetic benchmark failure")

    boom.run = _boom
    monkeypatch.setitem(sys.modules, "benchmarks.fake_ok", ok)
    monkeypatch.setitem(sys.modules, "benchmarks.fake_boom", boom)
    monkeypatch.setattr(run_mod, "MODULES", ["fake_ok", "fake_boom"])
    out_json = tmp_path / "bench.json"
    monkeypatch.setattr(
        sys, "argv", ["benchmarks.run", "--json", str(out_json)])
    with pytest.raises(SystemExit) as exc_info:
        main()
    assert exc_info.value.code == 1

    payload = json.loads(out_json.read_text())
    by_module = {r["module"]: r for r in payload["rows"]}
    assert by_module["fake_ok"]["name"] == "ok_row"
    err_row = by_module["fake_boom"]
    assert err_row["name"] == "fake_boom__FAILED"
    assert err_row["derived"] is None
    assert err_row["error"] == "RuntimeError: synthetic benchmark failure"
    assert "FAILED" in capsys.readouterr().out
