"""Mamba2-370m [arXiv:2405.21060] — attention-free SSM with SSD (state-space duality)."""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        arch_type="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,          # attention-free
        num_kv_heads=0,
        head_dim=1,           # unused
        d_ff=0,               # no MLP; Mamba2 block is the mixer
        vocab_size=50280,
        norm_type="rmsnorm",
        ssm=SSMConfig(
            state_size=128,
            head_dim=64,
            expand=2,         # d_inner = 2048 -> 32 SSD heads
            n_groups=1,
            conv_width=4,
            chunk_size=256,
        ),
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )


def smoke_config() -> ModelConfig:
    return config().reduced()
