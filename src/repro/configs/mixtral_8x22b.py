"""Mixtral-8x22B [arXiv:2401.04088] — MoE, 8 experts top-2, sliding-window attention."""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        arch_type="moe",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=32768,
        rope_theta=1_000_000.0,
        sliding_window=4096,
        norm_type="rmsnorm",
        mlp_act="silu",
        moe=MoEConfig(num_experts=8, top_k=2),
        source="arXiv:2401.04088",
    )


def smoke_config() -> ModelConfig:
    return config().reduced()
