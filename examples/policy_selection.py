"""Online policy selection over the full 112-policy pool (paper Sec. V).

    PYTHONPATH=src python examples/policy_selection.py

Streams 400 fine-tuning jobs through the EG selector; every job evaluates
the whole pool in one vmapped JAX call. Prints the regret trajectory against
the Theorem-2 bound and the final winner.
"""
import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core import fast_sim
from repro.core.job import normalize_utility
from repro.core.market import vast_like_trace
from repro.core.policy_pool import baseline_specs, paper_pool, specs_to_arrays
from repro.core.predictor import NoisyPredictor
from repro.core.selector import (
    best_policy, init_selector, regret, regret_bound, select, update,
)

K = 400
TPUT = ThroughputConfig(mu1=0.9, mu2=0.95)

pool = paper_pool() + baseline_specs()          # 112 + 3
arrs = specs_to_arrays(pool)
market = vast_like_trace(seed=3, days=40, mean_price=0.7, price_sigma=0.5,
                         avail_mean=5.5, avail_season_amp=3.0)
rng = np.random.default_rng(0)
st = init_selector(len(pool), K)

for k in range(K):
    job = JobConfig(workload=float(rng.uniform(70, 120)), deadline=10,
                    n_min=int(rng.integers(1, 4)),
                    n_max=int(rng.integers(12, 17)), value=120.0)
    tr = market.window(int(rng.integers(0, len(market) - 11)), 11)
    pred = NoisyPredictor(tr, "fixed_uniform", 0.15, seed=k).matrix(5)
    prices, avail, pm = fast_sim.prepare_inputs(tr, pred, job.deadline)
    chosen = select(st, rng)  # the policy that would actually run job k
    out = fast_sim.simulate_pool(arrs, fast_sim.JobArrays.of(job), TPUT,
                                 prices, avail, pm)
    u = np.asarray(normalize_utility(job, np.asarray(out["utility"])))
    st = update(st, u)
    if (k + 1) % 50 == 0:
        b = best_policy(st)
        print(f"job {k+1:4d}: regret={regret(st):7.2f} "
              f"bound={regret_bound(len(pool), k+1):7.2f} "
              f"leader={pool[b].name} (w={st.weights[b]:.2f})")

b = best_policy(st)
print(f"\nselected policy after {K} jobs: {pool[b].name} "
      f"(weight {st.weights[b]:.3f})")
print(f"final regret {regret(st):.2f} <= bound {regret_bound(len(pool), K):.2f}: "
      f"{regret(st) <= regret_bound(len(pool), K)}")
