"""Checkpoint hardening pins: CRC envelope, atomic writes, bounded
retries. The failure model is the one the paper's switching cost lives in
— preemption storms hit the checkpoint path exactly when the scheduler is
reconfiguring — so a torn/bit-flipped file must be *detected*
(CheckpointCorruptError), a flaky filesystem must be *ridden out*
(bounded OSError retries), and a pre-envelope blob must still restore
(legacy fallback)."""
import os
import zlib

import msgpack
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruptError,
    restore,
    save,
    serialize,
)
from repro.checkpoint import ckpt as _ckpt


def _tree():
    return {
        "w": np.arange(12, dtype=np.float32).reshape(3, 4),
        "step": np.int64(7),
    }


def _assert_tree_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
    assert int(a["step"]) == int(b["step"])


def test_roundtrip_with_meta(tmp_path):
    path = str(tmp_path / "ck.msgpack")
    tree = _tree()
    nbytes = save(path, tree, meta={"arch": "t"})
    assert nbytes == os.path.getsize(path)
    out, meta = restore(path, tree)
    _assert_tree_equal(out, tree)
    assert meta == {"arch": "t"}
    assert not [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]


def test_bitflip_raises_corrupt(tmp_path):
    path = str(tmp_path / "ck.msgpack")
    save(path, _tree())
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(path, "wb").write(bytes(blob))
    with pytest.raises(CheckpointCorruptError):
        restore(path, _tree())


def test_truncation_raises_corrupt(tmp_path):
    path = str(tmp_path / "ck.msgpack")
    save(path, _tree())
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[: len(blob) - 8])
    with pytest.raises(CheckpointCorruptError):
        restore(path, _tree())


def test_crc_mismatch_message(tmp_path):
    # decompresses fine, envelope intact, CRC wrong: the envelope's case
    inner = msgpack.packb(
        {"meta": "{}", "leaves": []}, use_bin_type=True)
    raw = msgpack.packb(
        {"body": inner, "crc": zlib.crc32(inner) ^ 1}, use_bin_type=True)
    path = str(tmp_path / "ck.msgpack")
    open(path, "wb").write(zlib.compress(raw))
    with pytest.raises(CheckpointCorruptError, match="checksum mismatch"):
        restore(path, {})


def test_legacy_blob_without_envelope_restores(tmp_path):
    # a blob written before the CRC envelope: inner payload compressed
    # directly, no {"body", "crc"} wrapper
    tree = _tree()
    leaves, _ = __import__("jax").tree_util.tree_flatten(tree)
    payload = {
        "meta": "{}",
        "leaves": [_ckpt._pack_leaf(l) for l in leaves],
    }
    blob = zlib.compress(msgpack.packb(payload, use_bin_type=True), 6)
    path = str(tmp_path / "legacy.msgpack")
    open(path, "wb").write(blob)
    out, meta = restore(path, tree)
    _assert_tree_equal(out, tree)
    assert meta == {}


class _Flaky:
    """Raise OSError the first ``n_fail`` calls, then delegate."""

    def __init__(self, n_fail, fn):
        self.n_fail, self.fn, self.calls = n_fail, fn, 0

    def __call__(self, *a, **kw):
        self.calls += 1
        if self.calls <= self.n_fail:
            raise OSError(f"transient #{self.calls}")
        return self.fn(*a, **kw)


def test_save_retries_transient_oserror(tmp_path, monkeypatch):
    path = str(tmp_path / "ck.msgpack")
    flaky = _Flaky(2, _ckpt._write_bytes_atomic)
    monkeypatch.setattr(_ckpt, "_write_bytes_atomic", flaky)
    save(path, _tree(), retries=2, backoff=0.0)
    assert flaky.calls == 3
    out, _ = restore(path, _tree())
    _assert_tree_equal(out, _tree())


def test_save_retry_exhaustion_propagates(tmp_path, monkeypatch):
    flaky = _Flaky(10, _ckpt._write_bytes_atomic)
    monkeypatch.setattr(_ckpt, "_write_bytes_atomic", flaky)
    with pytest.raises(OSError, match="transient"):
        save(str(tmp_path / "ck.msgpack"), _tree(), retries=2, backoff=0.0)
    assert flaky.calls == 3  # first attempt + exactly `retries` retries


def test_restore_retries_transient_oserror(tmp_path, monkeypatch):
    path = str(tmp_path / "ck.msgpack")
    save(path, _tree())
    flaky = _Flaky(1, _ckpt._read_bytes)
    monkeypatch.setattr(_ckpt, "_read_bytes", flaky)
    out, _ = restore(path, _tree(), retries=1, backoff=0.0)
    assert flaky.calls == 2
    _assert_tree_equal(out, _tree())


def test_corruption_is_never_retried(tmp_path, monkeypatch):
    path = str(tmp_path / "ck.msgpack")
    save(path, _tree())
    blob = bytearray(open(path, "rb").read())
    blob[-1] ^= 0xFF
    open(path, "wb").write(bytes(blob))
    reads = _Flaky(0, _ckpt._read_bytes)
    monkeypatch.setattr(_ckpt, "_read_bytes", reads)
    with pytest.raises(CheckpointCorruptError):
        restore(path, _tree(), retries=5, backoff=0.0)
    assert reads.calls == 1  # a bad CRC does not heal on a reread


def test_atomic_write_leaves_no_tmp_on_failure(tmp_path, monkeypatch):
    # fail the replace: the target must not exist and the tmp is cleaned
    def boom(src, dst):
        raise OSError("replace failed")

    monkeypatch.setattr(_ckpt.os, "replace", boom)
    path = str(tmp_path / "ck.msgpack")
    with pytest.raises(OSError):
        _ckpt._write_bytes_atomic(path, b"payload")
    assert not os.path.exists(path)
    assert os.listdir(tmp_path) == []


def test_elastic_trainer_threads_retries():
    import inspect

    from repro.train.elastic import ElasticTrainer

    assert "ckpt_retries" in inspect.signature(ElasticTrainer).parameters
    src = inspect.getsource(ElasticTrainer._reconfigure)
    assert "retries=self.ckpt_retries" in src
