"""Checkpointing: msgpack + zstd over flattened pytrees.

This is the substrate behind the paper's *switching cost* (Sec. II-A): when
the spot scheduler changes the instance count or a preemption hits, the
fine-tuning state (LoRA params + optimizer state + data-stream position) is
written, shipped over the (possibly slow) network, and restored. The paper
measures 0.58 s at 200 Gbps vs 1152 s at 100 Mbps for a full LLaMA2-7B
checkpoint; ``checkpoint_bytes``/``transfer_seconds`` reproduce that model
from the actual serialized sizes.

Elastic resharding: checkpoints are *instance-count independent* (full
logical arrays), so restoring onto a different data-parallel width is a
no-op — the loader re-shards on the next step.

Hardening (preemption storms hit the checkpoint path exactly when it
matters most): writes are atomic (tmp + rename, so a preempted writer
never leaves a torn file at the target path), every blob carries a CRC32
of its compressed body that is verified on load, and both ``save`` and
``restore`` retry transient ``OSError``s with exponential backoff.
Corruption (bad CRC, truncation, undecodable body) raises
:class:`CheckpointCorruptError` — callers distinguish "retry elsewhere"
from "this replica's state is gone". Blobs from before the CRC envelope
restore unchanged (legacy fallback).
"""
from __future__ import annotations

import io
import json
import os
import tempfile
import time
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional: zstd is the preferred codec but not a hard dependency
    import zstandard
except ModuleNotFoundError:  # pragma: no cover - env-dependent
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


class CheckpointCorruptError(RuntimeError):
    """The checkpoint file is damaged: CRC mismatch, truncation, or an
    undecodable body. Retrying the read will not help."""


def _pack_leaf(x) -> dict:
    arr = np.asarray(x)
    if arr.dtype == jnp.bfloat16:
        return {
            "dtype": "bfloat16",
            "shape": list(arr.shape),
            "data": arr.view(np.uint16).tobytes(),
        }
    return {"dtype": str(arr.dtype), "shape": list(arr.shape), "data": arr.tobytes()}


def _unpack_leaf(d: dict):
    if d["dtype"] == "bfloat16":
        arr = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    arr = np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])
    return jnp.asarray(arr)


def serialize(tree, meta: Optional[Dict[str, Any]] = None) -> bytes:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "meta": json.dumps(meta or {}),
        "leaves": [_pack_leaf(l) for l in leaves],
    }
    inner = msgpack.packb(payload, use_bin_type=True)
    # CRC envelope: the checksum covers the full inner payload so any
    # truncation or bit-flip that survives decompression is still caught
    raw = msgpack.packb(
        {"body": inner, "crc": zlib.crc32(inner)}, use_bin_type=True)
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def deserialize(blob: bytes, tree_like) -> Tuple[Any, Dict[str, Any]]:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ModuleNotFoundError(
                "checkpoint was written with zstd but the 'zstandard' package "
                "is not installed (zlib-written checkpoints need no extra deps)"
            )
        decompress = zstandard.ZstdDecompressor().decompress
    else:
        decompress = zlib.decompress
    try:
        raw = decompress(blob)
        payload = msgpack.unpackb(raw, raw=False)
        if isinstance(payload, dict) and "body" in payload:
            inner = payload["body"]
            if zlib.crc32(inner) != payload["crc"]:
                raise CheckpointCorruptError(
                    "checkpoint checksum mismatch: the file decompressed but "
                    "its body does not match the stored CRC32")
            payload = msgpack.unpackb(inner, raw=False)
        # else: legacy blob from before the CRC envelope — restore as-is
        leaves = [_unpack_leaf(d) for d in payload["leaves"]]
    except CheckpointCorruptError:
        raise
    except Exception as e:
        raise CheckpointCorruptError(
            f"checkpoint is undecodable ({type(e).__name__}: {e})") from e
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves), json.loads(payload["meta"])


def _with_retries(fn, retries: int, backoff: float):
    """Run ``fn`` retrying transient ``OSError``s with exponential backoff
    (``retries`` extra attempts after the first). Corruption is never
    retried — a bad CRC will not heal on a reread."""
    for attempt in range(retries + 1):
        try:
            return fn()
        except OSError:
            if attempt >= retries:
                raise
            time.sleep(backoff * (2 ** attempt))


def _write_bytes_atomic(path: str, blob: bytes) -> None:
    """tmp + rename in the target directory, so a crash mid-write never
    leaves a torn file at ``path`` (split out for fault-injection tests)."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def _read_bytes(path: str) -> bytes:
    with open(path, "rb") as f:
        return f.read()


def save(path: str, tree, meta: Optional[Dict[str, Any]] = None, *,
         retries: int = 2, backoff: float = 0.05) -> int:
    """Atomic write; returns byte size (feeds the switching-cost model).
    Transient ``OSError``s are retried ``retries`` times with exponential
    backoff before propagating."""
    blob = serialize(tree, meta)
    _with_retries(lambda: _write_bytes_atomic(path, blob), retries, backoff)
    return len(blob)


def restore(path: str, tree_like, *,
            retries: int = 2, backoff: float = 0.05) -> Tuple[Any, Dict[str, Any]]:
    blob = _with_retries(lambda: _read_bytes(path), retries, backoff)
    return deserialize(blob, tree_like)


# ---------------------------------------------------------------------------
# Switching-cost model (paper Sec. II-A / VI-A)
# ---------------------------------------------------------------------------

def checkpoint_bytes(cfg) -> int:
    """Base model + LoRA + Adam moments, bf16 base / f32 adapters."""
    base = cfg.param_count() * 2
    lora = cfg.lora_param_count() * 4
    adam = cfg.lora_param_count() * 8  # m and v in f32
    return base + lora + adam


def transfer_seconds(cfg, bandwidth_bps: float) -> float:
    return checkpoint_bytes(cfg) * 8.0 / bandwidth_bps


def reconfiguration_mu(cfg, bandwidth_bps: float, slot_seconds: float,
                       startup_seconds: float = 180.0) -> float:
    """Effective-compute fraction of a slot after a scale-up event (Eq. 2):
    checkpoint transfer + container/startup time, clipped to [0, 1]."""
    dead = transfer_seconds(cfg, bandwidth_bps) + startup_seconds
    return float(np.clip(1.0 - dead / slot_seconds, 0.0, 1.0))
