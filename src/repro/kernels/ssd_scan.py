"""Mamba2 SSD chunk-scan Pallas TPU kernel.

One grid step processes one (head, chunk) tile: the quadratic intra-chunk
part runs as two MXU matmuls ((C Bᵀ ⊙ L-mask) and @x), and the (N x P)
recurrent state lives in VMEM scratch across the *sequential* chunk grid
dimension — the inter-chunk recurrence never leaves the core. This is the
TPU-native shape of the SSD algorithm [arXiv:2405.21060]: no warp shuffles,
the chunk length rides the MXU sublane dim and (N, P) the lane dim.

Layout: x:(BH, S, P), dt:(BH, S), A:(BH, 1), B,C:(BH, S, N), S = nc * cs.
Oracle: repro.kernels.ref.ssd_scan_ref (step-by-step recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hfin_ref, state_ref, *,
            cs: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)        # (cs, P)
    dt = dt_ref[0].astype(jnp.float32)      # (cs,)
    a = a_ref[0, 0]                         # scalar
    b = b_ref[0].astype(jnp.float32)        # (cs, N)
    c = c_ref[0].astype(jnp.float32)        # (cs, N)

    da = dt * a                             # (cs,) negative
    cum = jnp.cumsum(da)                    # inclusive

    # ---- intra-chunk: (C Bᵀ ⊙ mask ⊙ decay ⊙ dt_j) @ x ----
    gb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                       # (cs_i, cs_j)
    li = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    dec = jnp.exp(jnp.clip(cum[:, None] - cum[None, :], -60.0, 0.0))
    m = jnp.where(li >= lj, gb * dec, 0.0) * dt[None, :]
    y_intra = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                       # (cs, P)

    # ---- inter-chunk: contribution of the incoming state ----
    decay_in = jnp.exp(jnp.clip(cum, -60.0, 0.0))  # (cs,)
    y_inter = jax.lax.dot_general(
        c, state_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * decay_in[:, None]                   # (cs, N)@(N, P) -> (cs, P)

    y_ref[0, ...] = (y_intra + y_inter).astype(y_ref.dtype)

    # ---- state update: h <- exp(sum da) h + sum_j exp(cum_l - cum_j) dt_j B_j x_jᵀ
    decay_to_end = jnp.exp(jnp.clip(cum[-1] - cum, -60.0, 0.0)) * dt  # (cs,)
    chunk_state = jax.lax.dot_general(
        b * decay_to_end[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                       # (N, P)
    chunk_decay = jnp.exp(jnp.clip(cum[-1], -60.0, 0.0))
    state_ref[...] = state_ref[...] * chunk_decay + chunk_state

    @pl.when(ci == nc - 1)
    def _done():
        hfin_ref[0, ...] = state_ref[...]


def ssd_scan(
    x: jnp.ndarray,   # (BH, S, P)
    dt: jnp.ndarray,  # (BH, S)
    A: jnp.ndarray,   # (BH,) negative per-head decay
    B: jnp.ndarray,   # (BH, S, N)
    C: jnp.ndarray,   # (BH, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y:(BH,S,P), h_final:(BH,N,P))."""
    bh, s, p = x.shape
    n = B.shape[-1]
    cs = min(chunk, s)
    assert s % cs == 0, (s, cs)
    nc = s // cs
    a2 = A.reshape(bh, 1)

    return pl.pallas_call(
        functools.partial(_kernel, cs=cs, nc=nc),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, cs, p), lambda h, c_: (h, c_, 0)),  # x
            pl.BlockSpec((1, cs), lambda h, c_: (h, c_)),        # dt
            pl.BlockSpec((1, 1), lambda h, c_: (h, 0)),          # A
            pl.BlockSpec((1, cs, n), lambda h, c_: (h, c_, 0)),  # B
            pl.BlockSpec((1, cs, n), lambda h, c_: (h, c_, 0)),  # C
        ],
        out_specs=[
            pl.BlockSpec((1, cs, p), lambda h, c_: (h, c_, 0)),  # y
            pl.BlockSpec((1, n, p), lambda h, c_: (h, 0, 0)),    # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a2, B, C)
