"""Throughput and reconfiguration model (Eqs. 1-2).

H(n) = alpha*n + beta for n>0 (paper Fig. 1: near-linear multi-GPU LoRA
scaling); mu_t in {mu1, mu2, 1} charges scale-up/scale-down overhead as a
lost fraction of the slot. ``calibrate`` derives (alpha, mu) for a concrete
architecture from its FLOPs/token and checkpoint size — the arch-aware
extension described in DESIGN.md §3 (the paper's fixed mu=0.9 is the default).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ThroughputConfig


def throughput(tput: ThroughputConfig, n):
    n = jnp.asarray(n)
    h = tput.alpha * n + tput.beta
    return jnp.where(n > 0, h, 0.0)


def mu_factor(tput: ThroughputConfig, n_prev, n_now):
    """Eq. 2: mu1 on scale-up (new instances boot + reshard), mu2 on
    scale-down (reshard only), 1 when unchanged."""
    n_prev, n_now = jnp.asarray(n_prev), jnp.asarray(n_now)
    up = jnp.asarray(tput.mu1, jnp.float32)
    down = jnp.asarray(tput.mu2, jnp.float32)
    out = jnp.where(n_now > n_prev, up, jnp.where(n_now < n_prev, down, 1.0))
    # no reconfiguration cost when nothing was or is running
    return jnp.where((n_prev == 0) & (n_now == 0), 1.0, out)


def effective_work(tput: ThroughputConfig, n_prev, n_now):
    """mu_t * H(n_t): workload completed in one slot."""
    return mu_factor(tput, n_prev, n_now) * throughput(tput, n_now)


def calibrate(
    cfg: ModelConfig,
    *,
    slot_seconds: float = 1800.0,
    bandwidth_bps: float = 800e6,
    chip_flops: float = 197e12,
    mfu: float = 0.4,
    startup_seconds: float = 180.0,
) -> ThroughputConfig:
    """Arch-aware (alpha, mu1, mu2).

    alpha: workload-units/slot per instance. With the paper's convention
    "unit GPU compute power = 1" alpha is 1 by definition; we expose the
    tokens/slot rate via ``tokens_per_slot`` instead. mu1 folds checkpoint
    transfer + startup; mu2 transfer only (scale-down needs no boot).
    """
    from repro.checkpoint.ckpt import transfer_seconds

    xfer = transfer_seconds(cfg, bandwidth_bps)
    mu1 = float(jnp.clip(1.0 - (xfer + startup_seconds) / slot_seconds, 0.0, 1.0))
    mu2 = float(jnp.clip(1.0 - xfer / slot_seconds, 0.0, 1.0))
    return ThroughputConfig(alpha=1.0, beta=0.0, mu1=mu1, mu2=mu2)


def tokens_per_slot(
    cfg: ModelConfig, *, slot_seconds: float = 1800.0,
    chip_flops: float = 197e12, mfu: float = 0.4,
) -> float:
    """Tokens one instance (chip) fine-tunes per slot (3x fwd FLOPs for LoRA
    train: fwd + recompute + activation-grad backward; no base weight grads)."""
    per_token = 3.0 * cfg.flops_per_token()
    return chip_flops * mfu * slot_seconds / per_token
