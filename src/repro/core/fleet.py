"""Device-resident fleet-scale multi-job contention engine.

The paper's Sec. III-A extension — jobs arriving over time and competing
for one finite spot pool under least-slack-first arbitration — as a single
``lax.scan`` over market slots with the job axis batched (and optionally
sharded over the pool mesh). Semantics are pinned bit-for-bit-in-spirit to
the numpy parity oracle ``core.multi_job.MultiJobScheduler``:

  * **demand phase** — every live job's policy decides against the FULL
    slot supply. AHAP jobs run the slot-major batched window DP
    (``fast_sim._ahap_rule_batch`` over per-job local clocks ``t -
    arrival``); the five cheap kinds run their vectorized rules;
  * **waterfall phase** — spot demand is granted least-slack-first as a
    sort + cumulative-supply clip instead of a Python loop: with demands
    sorted by the float32 slack key (job-id tie-break), ``grant_i =
    clip(S - (cumsum(d)_i - d_i), 0, d_i)`` makes cumulative grants equal
    ``min(cumsum(d), S)`` — integer-exact, identical to the oracle's
    sequential residual loop;
  * **execute phase** — ``fast_sim._execute`` on the granted spot (its
    internal feasibility clip reduces to exactly the oracle's post-grant
    N^min top-up), with arrivals/retirements gated by ``t - arrival``
    masks so jobs stream in and out without host round-trips.

Sharding lays the job axis over the pool mesh's ``"jobs"`` axis (2-D
meshes replicate over ``"lanes"``: the fleet has no lane axis). Each
device holds an equal ``[AHAP block | cheap block]`` slice — both kind
blocks pad to device divisibility independently, so the static AHAP split
is uniform across shards — and the waterfall runs on an ``all_gather`` of
(demand, slack, id), every device granting the identical global order and
keeping its own slice. Padded jobs carry ``arrival = T`` (never live,
demand 0), so they cannot perturb real grants in any sort position:
sharded results are bitwise-equal to the single-device scan.

Per-job policy rows come from the EG selector weights that
``engine.simulate_and_select`` produces (``policy_rows_from_weights`` /
``SelectionResult.admission_rows``), closing the select -> admit loop.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ThroughputConfig
from repro.core import fast_sim
from repro.core.fast_sim import VMAX, W1MAX, JobArrays
from repro.core.policy_pool import KIND_AHAP

_POLICY_KEYS = ("kind", "omega", "v", "sigma", "rho", "cfrac")


# ---------------------------------------------------------------------------
# Least-slack-first waterfall
# ---------------------------------------------------------------------------

def _waterfall(demand, slack, ids, supply):
    """Grant ``demand`` (i32) in ascending ``(slack, id)`` order against a
    scalar ``supply``. Cumulative grants equal ``min(cumsum(demand),
    supply)`` — the vectorized form of "each job takes ``min(demand,
    residual)``" — so the result is integer-exact, not an approximation."""
    order = jnp.lexsort((ids, slack))
    d_sorted = demand[order]
    cum = jnp.cumsum(d_sorted)
    g_sorted = jnp.clip(supply - (cum - d_sorted), 0, d_sorted)
    return jnp.zeros_like(demand).at[order].set(g_sorted)


def _demand_rank(demand, slack, ids):
    """Flight-recorder companion to :func:`_waterfall`: each job's position
    in the demanders-only grant order (-1 for jobs demanding nothing this
    slot). Sorting demanders first (extra ``demand <= 0`` key ahead of the
    same ``(slack, id)`` keys) keeps demander positions identical whether
    or not zero-demand jobs — including the sharded path's sentinel pads,
    which never demand — are present, so sharded and unsharded collect
    runs agree bitwise. Only traced when ``collect=True``."""
    n = ids.shape[0]
    order = jnp.lexsort((ids, slack, (demand <= 0).astype(jnp.int32)))
    pos = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32)
    )
    return jnp.where(demand > 0, pos, -1)


# ---------------------------------------------------------------------------
# The fleet scan (runs whole on one device, or per shard under shard_map)
# ---------------------------------------------------------------------------

_TEL_FLEET = ("tel_demand", "tel_grant", "tel_slack", "tel_rank",
              "tel_starved")


def _fleet_scan(pol, jobs, arrivals, ids, tput, prices, avail, pred,
                backend: str, n_ahap: int, axis_name: Optional[str] = None,
                collect: bool = False, fallback=None):
    """One ``lax.scan`` over market slots for a fleet (shard).

    ``jobs``/``arrivals``/``ids`` are (Jl,) leaves ordered ``[AHAP block |
    cheap block]`` with the static split at ``n_ahap``; ``pol`` holds the
    per-job policy rows in the same order. ``prices``/``avail``/``pred``
    are the full shared market ((T,), (T,), (T, W1MAX, 2)); the present-
    slot forecast row is pre-clamped to the pool supply by the callers.
    Under ``shard_map`` (``axis_name="jobs"``) the waterfall all-gathers
    (demand, slack, id) so every shard grants the identical global order.
    ``collect`` (static) appends the flight-recorder series to the scan
    ys: the shared ``fast_sim._TEL_SLOTS`` slot telemetry (preemption =
    the waterfall grant fell below last slot's allocation) plus the
    ``_TEL_FLEET`` waterfall series (demand vs grant, slack, demanders-only
    grant rank, starvation). The False branch traces the identical
    program as before telemetry existed.

    ``fallback`` (static repro.chaos.FallbackConfig, or None) arms the
    per-job prediction-health monitor for the AHAP block: a per-job
    realized-forecast-error EWMA over the shared market forecasts, updated
    only once a job has arrived, switches that job's demand to the
    prediction-free AHANP rule while above threshold (AHANP's "previous
    availability" is the shifted supply ``sup_prev``, the convention the
    cheap AHANP jobs already use). ``None`` traces the bitwise-identical
    shipped program; with ``collect`` also on, the per-job
    ``fast_sim._TEL_FALLBACK`` series join the ys (all-zero for the cheap
    block, which consumes no predictions).
    """
    prices = jnp.asarray(prices, jnp.float32)
    av_i = jnp.asarray(avail).astype(jnp.int32)
    dmax = prices.shape[0]
    n_jobs = arrivals.shape[0]
    has_ahap = n_ahap > 0
    has_cheap = n_jobs - n_ahap > 0
    ts = jnp.arange(dmax)
    # AHANP observes last slot's availability; in the fleet every job sees
    # the shared pool, so the "previous avail" is just the shifted supply
    # (a job's first live slot sees the current supply, like the python
    # policy's first decide).
    sup_prev = jnp.concatenate([av_i[:1], av_i[:-1]])

    # fallback monitor state only exists for the prediction-consuming block
    fb_on = fallback is not None and has_ahap
    if fb_on:
        fb_thr = jnp.float32(fallback.threshold)
        prev1 = fast_sim._fallback_prev1(pred)            # (T, 2)

    ja = fast_sim.slice_jobs(jobs, 0, n_ahap)
    jc = fast_sim.slice_jobs(jobs, n_ahap, n_jobs)
    if has_ahap:
        jcfg_a = fast_sim._job_cfg(ja)
        v_a = pol["v"][:n_ahap]
        arr_a = arrivals[:n_ahap]
        # scan-invariant AHAP scaffolding, slot-major like
        # _simulate_lanes_ahap, but on per-job local clocks t - arrival
        # (pre-arrival rows are garbage-but-finite; the plans-validity mask
        # k <= local_t in _ahap_rule_batch keeps them out of every average)
        pr, thr_s, z_exp_end, eff_slots = jax.vmap(
            lambda t, pm: jax.vmap(
                lambda jr, w, s, r, a: fast_sim._ahap_precompute(
                    jr, w, s, r, t - a, pm
                )
            )(ja, pol["omega"][:n_ahap], pol["sigma"][:n_ahap],
              pol["rho"][:n_ahap], arr_a)
        )(ts, pred)
    if has_cheap:
        kind_c = pol["kind"][n_ahap:]
        sigma_c = pol["sigma"][n_ahap:]
        cfrac_c = pol["cfrac"][n_ahap:]

    if axis_name is None:
        ids_all, start = ids, 0
    else:
        ids_all = jax.lax.all_gather(ids, axis_name, tiled=True)
        start = jax.lax.axis_index(axis_name) * n_jobs

    h_max = tput.alpha * jobs.n_max.astype(jnp.float32) + tput.beta

    def step(carry, xs):
        if fb_on:
            z, n_prev, cost, done, T, plans, err = carry
            price, sup, sup_p, t, pr_t, thr_t, zee_t, eff_t, p1_t = xs
        elif has_ahap:
            z, n_prev, cost, done, T, plans = carry
            price, sup, sup_p, t, pr_t, thr_t, zee_t, eff_t = xs
        else:
            z, n_prev, cost, done, T, plans = carry
            price, sup, sup_p, t = xs
        lt = t - arrivals
        live = (lt >= 0) & (lt < jobs.deadline) & ~done

        # ---- demand phase: every policy decides at the FULL supply
        d_o_parts, d_s_parts = [], []
        if has_ahap:
            d_o_a, d_s_a, plans = fast_sim._ahap_rule_batch(
                jcfg_a, ja, tput, v_a, backend, z[:n_ahap], lt[:n_ahap],
                price, sup, plans, pr_t, thr_t, zee_t, eff_t,
            )
            if fb_on:
                lta = lt[:n_ahap]
                # the monitor only accumulates once the job is watching
                # the market (arrived); the shared error sample is scalar
                err = jnp.where(
                    lta >= 0,
                    fast_sim._fallback_error(fallback, err, price, sup, p1_t),
                    err,
                )
                fb = err > fb_thr
                pa_a = jnp.where(lta >= 1, sup_p, sup)
                an_o, an_s = fast_sim._ahanp_rule(
                    ja, pol["sigma"][:n_ahap], z[:n_ahap], lta, price, sup,
                    n_prev[:n_ahap], pa_a,
                )
                d_o_a = jnp.where(fb, an_o, d_o_a)
                d_s_a = jnp.where(fb, an_s, d_s_a)
            d_o_parts.append(d_o_a)
            d_s_parts.append(d_s_a)
        if has_cheap:
            ltc = lt[n_ahap:]
            zc, npv = z[n_ahap:], n_prev[n_ahap:]
            pa = jnp.where(ltc >= 1, sup_p, sup)
            an_o, an_s = fast_sim._ahanp_rule(
                jc, sigma_c, zc, ltc, price, sup, npv, pa)
            od_o, od_s = fast_sim._od_rule(jc, tput, zc, ltc, price, sup)
            ms_o, ms_s = fast_sim._msu_rule(jc, tput, zc, ltc, price, sup)
            up_o, up_s = fast_sim._up_rule(jc, tput, zc, ltc, price, sup)
            rd_o, rd_s = fast_sim._rand_rule(
                jc, tput, cfrac_c, zc, ltc, price, sup)
            sel = [kind_c == 1, kind_c == 2, kind_c == 3, kind_c == 4,
                   kind_c == 5]
            d_o_parts.append(jnp.select(sel, [an_o, od_o, ms_o, up_o, rd_o]))
            d_s_parts.append(jnp.select(sel, [an_s, od_s, ms_s, up_s, rd_s]))
        d_o = d_o_parts[0] if len(d_o_parts) == 1 else jnp.concatenate(d_o_parts)
        d_s = d_s_parts[0] if len(d_s_parts) == 1 else jnp.concatenate(d_s_parts)
        # demand clip against the full pool; dead jobs demand nothing
        d_s = jnp.clip(d_s, 0, jnp.minimum(sup, jobs.n_max))
        d_o = jnp.clip(d_o, 0, jobs.n_max - d_s)
        d_s = jnp.where(live, d_s, 0)
        d_o = jnp.where(live, d_o, 0)

        # ---- waterfall phase: least-slack-first grants (global order)
        slack = ((arrivals + jobs.deadline - t).astype(jnp.float32)
                 - jnp.maximum(jobs.workload - z, 0.0) / h_max)
        if axis_name is None:
            grant = _waterfall(d_s, slack, ids, sup)
            if collect:
                rank = _demand_rank(d_s, slack, ids)
        else:
            d_all = jax.lax.all_gather(d_s, axis_name, tiled=True)
            s_all = jax.lax.all_gather(slack, axis_name, tiled=True)
            g_all = _waterfall(d_all, s_all, ids_all, sup)
            grant = jax.lax.dynamic_slice(g_all, (start,), (n_jobs,))
            if collect:
                r_all = _demand_rank(d_all, s_all, ids_all)
                rank = jax.lax.dynamic_slice(r_all, (start,), (n_jobs,))

        # ---- execute phase: local clock, pre-arrival masked to inactive
        mt = jnp.where(lt >= 0, lt, jobs.deadline)
        n_prev0 = n_prev
        z, n_prev, cost, done, T, n_o, n_s, active = fast_sim._execute(
            jobs, tput, z, n_prev, cost, done, T, mt, d_o, grant, price,
            grant,
        )
        ys = (n_o, n_s)
        if collect:
            ys = ys + fast_sim._slot_telemetry(
                jobs, n_prev0, z, n_o, n_s, active, price, grant
            ) + (d_s, grant, jnp.where(live, slack, 0.0), rank,
                 live & (d_s > 0) & (grant < d_s))
            if fallback is not None:
                if fb_on:
                    pad = (n_jobs - n_ahap,)
                    fb_all = jnp.concatenate(
                        [fb, jnp.zeros(pad, jnp.bool_)]) if has_cheap else fb
                    err_all = jnp.concatenate(
                        [err, jnp.zeros(pad, jnp.float32)]) if has_cheap else err
                else:
                    fb_all = jnp.zeros((n_jobs,), jnp.bool_)
                    err_all = jnp.zeros((n_jobs,), jnp.float32)
                ys = ys + (fb_all, err_all)
        new_carry = (z, n_prev, cost, done, T, plans)
        if fb_on:
            new_carry = new_carry + (err,)
        return new_carry, ys

    init = (
        jnp.zeros((n_jobs,), jnp.float32), jnp.zeros((n_jobs,), jnp.int32),
        jnp.zeros((n_jobs,), jnp.float32), jnp.zeros((n_jobs,), jnp.bool_),
        jnp.zeros((n_jobs,), jnp.float32),
        jnp.zeros((n_ahap, VMAX, W1MAX, 2), jnp.float32),
    )
    xs = (prices, av_i, sup_prev, ts)
    if has_ahap:
        xs = xs + (pr, thr_s, z_exp_end, eff_slots)
    if fb_on:
        init = init + (jnp.zeros((n_ahap,), jnp.float32),)
        xs = xs + (prev1,)
    (z, _, cost, done, T, *_rest), ys = jax.lax.scan(step, init, xs)
    out = fast_sim._finalize(
        fast_sim._job_cfg(jobs), jobs, tput, z, cost, done, T,
        jnp.swapaxes(ys[0], 0, 1), jnp.swapaxes(ys[1], 0, 1),
    )
    if collect:
        keys = fast_sim._TEL_SLOTS + _TEL_FLEET + (
            fast_sim._TEL_FALLBACK if fallback is not None else ())
        for key, hist in zip(keys, ys[2:]):
            out[key] = jnp.swapaxes(hist, 0, 1)
    return out


@functools.partial(jax.jit, static_argnames=(
    "tput", "backend", "n_ahap", "collect", "fallback"))
def _fleet_call(pol, jobs, arrivals, ids, tput, prices, avail, pred,
                backend: str, n_ahap: int, collect: bool = False,
                fallback=None):
    return _fleet_scan(pol, jobs, arrivals, ids, tput, prices, avail, pred,
                       backend, n_ahap, collect=collect, fallback=fallback)


@functools.lru_cache(maxsize=None)
def _sharded_fleet_call(mesh, tput, backend: str, n_ahap: int,
                        collect: bool = False, fallback=None):
    """jit(shard_map)-wrapped fleet runner, cached on the static
    configuration (same reasoning as fast_sim._sharded_pool_call: a fresh
    shard_map closure per call would re-lower the whole program)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    jspec, rspec = P("jobs"), P()

    def local(pol, jobs, arrivals, ids, prices, avail, pred):
        return _fleet_scan(pol, jobs, arrivals, ids, tput, prices, avail,
                           pred, backend, n_ahap, axis_name="jobs",
                           collect=collect, fallback=fallback)

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(jspec, jspec, jspec, jspec, rspec, rspec, rspec),
        out_specs=jspec, check_rep=False,
    ))


# ---------------------------------------------------------------------------
# Host-side prep: policy rows, market tensors, kind blocking
# ---------------------------------------------------------------------------

def _norm_rows(pool_rows):
    """Per-job policy rows as host arrays with engine dtypes + defaults."""
    kind = np.asarray(pool_rows["kind"], np.int32)
    n = kind.shape[0]
    rows = {
        "kind": kind,
        "omega": np.asarray(pool_rows.get("omega", np.zeros(n)), np.int32),
        "v": np.maximum(
            np.asarray(pool_rows.get("v", np.ones(n)), np.int32), 1),
        "sigma": np.asarray(pool_rows.get("sigma", np.zeros(n)), np.float32),
        "rho": np.asarray(pool_rows.get("rho", np.ones(n)), np.float32),
        "cfrac": np.asarray(pool_rows.get("cfrac", np.zeros(n)), np.float32),
    }
    return rows, n


def _prepare_market(prices, avail, pred):
    """f32/i-typed market tensors with the oracle's present-slot clamp:
    ``pred[t, 0, 1] <- min(pred[t, 0, 1], avail[t])`` (the pool caps what
    the present slot can deliver; future rows stay the global forecast).
    ``pred=None`` falls back to a persistence forecast (present price and
    supply repeated over the horizon)."""
    prices = np.asarray(prices, np.float32)
    avail = np.asarray(avail)
    dmax = prices.shape[0]
    if pred is None:
        base = np.stack([prices, avail.astype(np.float32)], axis=-1)
        pred = np.broadcast_to(base[:, None, :], (dmax, W1MAX, 2))
    pred = np.array(pred, dtype=np.float32, copy=True)
    pred[:, 0, 1] = np.minimum(pred[:, 0, 1], avail.astype(np.float32))
    return prices, avail, pred


def _take_jobs(jobs: JobArrays, idx) -> JobArrays:
    idx = jnp.asarray(idx)
    return JobArrays(*[jnp.asarray(f)[idx] for f in jobs])


def simulate_fleet(pool_rows, jobs: JobArrays, arrivals, tput, prices,
                   avail, pred=None, backend: str = "xla",
                   collect: bool = False, fallback=None):
    """Simulate a fleet of jobs contending for one spot pool, on device.

    ``pool_rows`` — per-job policy rows (``kind``/``omega``/``v``/``sigma``
    /``rho``/``cfrac``, each (J,)), e.g. from
    :func:`policy_rows_from_weights`. ``jobs`` — stacked (J,) JobArrays
    (``fast_sim.stack_jobs``). ``arrivals`` — (J,) absolute arrival slots.
    ``prices``/``avail``/``pred`` — ONE shared market trace ((T,), (T,),
    optional (T, W1MAX, 2) absolute-time forecasts).

    Returns the ``fast_sim._finalize`` dict (utility/value/cost/
    completion_time/z_ddl/completed + (J, T) allocation histories), in
    submission order. Semantics match ``multi_job.MultiJobScheduler`` (the
    numpy oracle): completion times are on each job's local clock.
    """
    rows, n = _norm_rows(pool_rows)
    assert n == int(np.shape(jobs.workload)[0]) == int(np.shape(arrivals)[0])
    prices, avail_np, pred = _prepare_market(prices, avail, pred)
    aidx = np.flatnonzero(rows["kind"] == KIND_AHAP)
    cidx = np.flatnonzero(rows["kind"] != KIND_AHAP)
    order = np.concatenate([aidx, cidx]).astype(np.int32)
    pos = np.argsort(order, kind="stable")
    pol = {k: jnp.asarray(v[order]) for k, v in rows.items()}
    out = _fleet_call(
        pol, _take_jobs(jobs, order),
        jnp.asarray(np.asarray(arrivals, np.int32)[order]),
        jnp.asarray(order), tput, jnp.asarray(prices),
        jnp.asarray(avail_np), jnp.asarray(pred), backend, len(aidx),
        collect, fallback,
    )
    take = jnp.asarray(pos)
    return {k: jnp.take(v, take, axis=0) for k, v in out.items()}


def simulate_fleet_sharded(pool_rows, jobs: JobArrays, arrivals, tput,
                           prices, avail, pred=None, backend: str = "xla",
                           mesh=None, collect: bool = False, fallback=None):
    """:func:`simulate_fleet` with the job axis laid over the pool mesh.

    Default mesh: ``launch.mesh.make_pool_mesh()`` (1-D over every visible
    device). On a 2-D ``("jobs", "lanes")`` mesh only the ``"jobs"`` axis
    shards (the fleet has no lane axis; lanes replicate), so a lanes-only
    ``(1, n)`` mesh — like a single device — falls through to the
    unsharded scan. Each kind block pads to device divisibility with
    ``arrival = T`` sentinel jobs (never live, zero demand: provably
    inert in the waterfall), and results are bitwise-equal to
    :func:`simulate_fleet` (pinned in tests/test_fleet.py)."""
    from repro.launch.mesh import make_pool_mesh, pool_mesh_job_axes

    mesh = make_pool_mesh() if mesh is None else mesh
    _, n_jobs_dev, _ = pool_mesh_job_axes(mesh)
    if n_jobs_dev <= 1:
        return simulate_fleet(pool_rows, jobs, arrivals, tput, prices,
                              avail, pred, backend, collect, fallback)

    rows, n = _norm_rows(pool_rows)
    assert n == int(np.shape(jobs.workload)[0]) == int(np.shape(arrivals)[0])
    prices, avail_np, pred = _prepare_market(prices, avail, pred)
    dmax = prices.shape[0]
    arr_np = np.asarray(arrivals, np.int32)
    aidx = np.flatnonzero(rows["kind"] == KIND_AHAP)
    cidx = np.flatnonzero(rows["kind"] != KIND_AHAP)
    d = n_jobs_dev
    j_a = -(-len(aidx) // d) if len(aidx) else 0   # per-device block sizes
    j_c = -(-len(cidx) // d) if len(cidx) else 0

    def block(idx, per_dev):
        lay = np.full(d * per_dev, -1, np.int64)
        lay[: len(idx)] = idx
        return lay.reshape(d, per_dev)

    # interleave [AHAP block | cheap block] per device: every shard gets
    # the same static (j_a + j_c) structure with the AHAP split at j_a
    lay = np.concatenate([block(aidx, j_a), block(cidx, j_c)], axis=1)
    lay = lay.reshape(-1)
    fill = np.concatenate([
        np.full((d, j_a), aidx[0] if len(aidx) else 0, np.int64),
        np.full((d, j_c), cidx[0] if len(cidx) else 0, np.int64),
    ], axis=1).reshape(-1)
    gidx = np.where(lay >= 0, lay, fill)
    is_pad = lay < 0
    arr_l = arr_np[gidx].copy()
    arr_l[is_pad] = dmax                       # sentinel: never live
    ids_l = np.where(is_pad, n + np.arange(lay.shape[0]), lay)

    pol = {k: jnp.asarray(v[gidx]) for k, v in rows.items()}
    call = _sharded_fleet_call(mesh, tput, backend, j_a, collect, fallback)
    out = call(
        pol, _take_jobs(jobs, gidx), jnp.asarray(arr_l),
        jnp.asarray(ids_l.astype(np.int32)), jnp.asarray(prices),
        jnp.asarray(avail_np), jnp.asarray(pred),
    )
    # rows of real ids 0..n-1 in submission order; pads (ids >= n) dropped
    take = jnp.asarray(np.argsort(ids_l, kind="stable")[:n])
    return {k: jnp.take(v, take, axis=0) for k, v in out.items()}


# ---------------------------------------------------------------------------
# EG-weighted admission (select -> admit loop)
# ---------------------------------------------------------------------------

def policy_rows_from_weights(pool_arrays, weights, n, rng=None,
                             greedy: bool = False):
    """Per-job policy rows drawn from EG selector weights.

    Algorithm 2's Line 6 "select" generalized to fleet admission: each of
    the ``n`` arriving jobs samples its policy i.i.d. from the selector
    distribution (``greedy=True`` admits everyone on the argmax instead).
    ``pool_arrays`` is the ``specs_to_arrays`` dict the weights were
    learned over. Returns ``(rows, idx)`` — the per-job row dict
    :func:`simulate_fleet` consumes, plus the (n,) pool indices (handy for
    building python oracle policies via ``pool[i].build()``)."""
    from repro.core.selector import sample_policies

    w = np.asarray(weights, np.float64)
    if greedy:
        idx = np.full(int(n), int(np.argmax(w)), np.int64)
    else:
        rng = np.random.default_rng(0) if rng is None else rng
        idx = sample_policies(w, int(n), rng)
    rows = {k: np.asarray(pool_arrays[k])[idx]
            for k in _POLICY_KEYS if k in pool_arrays}
    return rows, idx.astype(np.int32)
