"""Theorem 2: selector regret <= sqrt(2 K ln M) — measured regret/bound vs K.

Each (M, K) trial is one ``selector.run_eg_scan`` call over a vectorized
(K, M) utility draw (pre-engine this was a K-iteration numpy update loop)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.core.selector import eg_init, regret, regret_bound, run_eg_scan


def _run_k(M: int, K: int, seed: int) -> float:
    rng = np.random.default_rng(seed)
    means = rng.uniform(0.2, 0.8, M)
    u = np.clip(rng.normal(means, 0.15, size=(K, M)), 0, 1)
    st, _ = run_eg_scan(eg_init(M, K), u)
    return regret(st) / regret_bound(M, K)


def run() -> list:
    rows = []
    worst = 0.0
    for K in (50, 200, 800, 3200):
        ratios, us = timed(
            lambda: [_run_k(112, K, s) for s in range(5)]
        )
        r = float(np.max(ratios))
        worst = max(worst, r)
        rows.append((f"theorem2_regret_over_bound_K{K}", us, r))
    rows.append(("theorem2_bound_holds", 0.0, float(worst <= 1.0)))
    return rows
