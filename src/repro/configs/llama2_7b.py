"""LLaMA2-7B [arXiv:2307.09288] — the paper's own fine-tuning target (LoRA rank 16)."""
from repro.configs.base import LoRAConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b",
        arch_type="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=11008,
        vocab_size=32000,
        rope_theta=10000.0,
        norm_type="rmsnorm",
        mlp_act="silu",
        lora=LoRAConfig(rank=16, alpha=32.0, targets=("q", "v")),
        source="arXiv:2307.09288 (paper Sec. VI-A)",
    )


def smoke_config() -> ModelConfig:
    return config().reduced()
