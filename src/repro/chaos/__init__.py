"""Chaos engineering for the scheduling engines: seeded fault injection
over market traces / forecast stacks (:mod:`repro.chaos.faults`) and the
online prediction-failure fallback the engines degrade to when their
forecasts go bad (:mod:`repro.chaos.fallback`). Benchmarked end to end by
benchmarks/chaos_sweep.py."""
from repro.chaos.fallback import FallbackConfig
from repro.chaos.faults import (
    FAULT_KINDS,
    FORECAST_KINDS,
    MARKET_KINDS,
    FaultSpec,
    blackout_schedule,
    inject,
    inject_forecasts,
    inject_market,
    storm_schedule,
    sync_present,
    window_mask,
)

__all__ = [
    "FAULT_KINDS",
    "MARKET_KINDS",
    "FORECAST_KINDS",
    "FaultSpec",
    "FallbackConfig",
    "window_mask",
    "inject_market",
    "inject_forecasts",
    "sync_present",
    "inject",
    "storm_schedule",
    "blackout_schedule",
]
