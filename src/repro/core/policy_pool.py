"""Policy pool construction (Sec. V-A / VI-A).

The paper's pool: 105 AHAP policies (omega in 1..5, v in 1..omega, sigma in
{0.3 .. 0.9}) + 7 AHANP policies (same sigmas) = 112, indexed 1..112 in
Fig. 10. ``PolicySpec`` is the array encoding shared by the python policies
and the vmapped JAX simulator.

BEYOND-PAPER pool expansions (selector breadth is the robustness lever —
Thm. 2's regret only grows as sqrt(log M)):

* Robust-AHAP (``robust_pool``): availability-pessimistic AHAP, rho < 1.
* RAND_DEADLINE (``rand_deadline_pool``): the optimal randomized
  commitment-threshold strategies of arXiv:2601.14612, discretized as
  quantiles of the optimal commitment CDF — each pool member commits to
  on-demand at a different deterministic fraction of the deadline, so the
  *pool* carries the randomization and the selector learns the best
  quantile for the observed market. These lanes run on the cheap (DP-free)
  scan, so they are nearly free to add.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.core.policies import (
    AHANP,
    AHANPParams,
    AHAP,
    AHAPParams,
    BasePolicy,
    MSU,
    ODOnly,
    RandDeadline,
    RandDeadlineParams,
    UP,
    rand_commit_frac,
)

KIND_AHAP, KIND_AHANP, KIND_OD, KIND_MSU, KIND_UP = 0, 1, 2, 3, 4
KIND_RAND = 5
KIND_NAMES = {0: "ahap", 1: "ahanp", 2: "od_only", 3: "msu", 4: "up",
              5: "rand_deadline"}

SIGMAS = (0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)
OMEGAS = (1, 2, 3, 4, 5)
RAND_QS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)


@dataclass(frozen=True)
class PolicySpec:
    kind: int
    omega: int = 0
    v: int = 0
    sigma: float = 0.0
    rho: float = 1.0  # Robust-AHAP availability discount (1.0 = paper AHAP)

    @property
    def name(self) -> str:
        if self.kind == KIND_AHAP:
            r = f",r={self.rho:.2f}" if self.rho < 1.0 else ""
            return f"ahap(w={self.omega},v={self.v},s={self.sigma:.1f}{r})"
        if self.kind == KIND_AHANP:
            return f"ahanp(s={self.sigma:.1f})"
        if self.kind == KIND_RAND:
            return f"rand_ddl(q={self.sigma:.2f})"
        return KIND_NAMES[self.kind]

    def build(self) -> BasePolicy:
        if self.kind == KIND_AHAP:
            return AHAP(AHAPParams(self.omega, self.v, self.sigma, self.rho))
        if self.kind == KIND_AHANP:
            return AHANP(AHANPParams(self.sigma))
        if self.kind == KIND_RAND:
            return RandDeadline(RandDeadlineParams(self.sigma))
        return {KIND_OD: ODOnly, KIND_MSU: MSU, KIND_UP: UP}[self.kind]()


def paper_pool(
    omegas: Sequence[int] = OMEGAS,
    sigmas: Sequence[float] = SIGMAS,
    fixed_v: Optional[int] = None,
    fixed_sigma: Optional[float] = None,
    include_ahanp: bool = True,
    rand_qs: Optional[Sequence[float]] = None,
) -> List[PolicySpec]:
    """105 AHAP + 7 AHANP by default; the fixed_* arguments reproduce the
    Fig. 9 hyperparameter-ablation pools (e.g. v=1 only, or sigma=0.9 only).
    ``rand_qs`` appends RAND_DEADLINE lanes (see rand_deadline_pool) —
    opt-in so the default composition stays the paper's 112."""
    pool: List[PolicySpec] = []
    for w in omegas:
        for v in range(1, w + 1):
            if fixed_v is not None and v != fixed_v:
                continue
            for s in sigmas:
                if fixed_sigma is not None and abs(s - fixed_sigma) > 1e-9:
                    continue
                pool.append(PolicySpec(KIND_AHAP, w, v, s))
    if include_ahanp:
        for s in sigmas:
            if fixed_sigma is not None and abs(s - fixed_sigma) > 1e-9:
                continue
            pool.append(PolicySpec(KIND_AHANP, 0, 0, s))
    if rand_qs is not None:
        pool.extend(rand_deadline_pool(rand_qs))
    return pool


def rand_deadline_pool(qs: Sequence[float] = RAND_QS) -> List[PolicySpec]:
    """BEYOND-PAPER: randomized commitment-threshold strategies
    (arXiv:2601.14612), one lane per quantile of the optimal commitment
    CDF. The quantile rides the ``sigma`` slot of the array encoding."""
    return [PolicySpec(KIND_RAND, 0, 0, q) for q in qs]


def baseline_specs() -> List[PolicySpec]:
    return [PolicySpec(KIND_OD), PolicySpec(KIND_MSU), PolicySpec(KIND_UP)]


def robust_pool(
    rhos: Sequence[float] = (0.5, 0.7, 0.85),
    omegas: Sequence[int] = (3, 5),
    sigmas: Sequence[float] = (0.3, 0.5, 0.7, 0.9),
) -> List[PolicySpec]:
    """BEYOND-PAPER: Robust-AHAP candidates (availability-pessimistic)."""
    return [
        PolicySpec(KIND_AHAP, w, 1, s, rho=r)
        for r in rhos for w in omegas for s in sigmas
    ]


def specs_to_arrays(pool: Sequence[PolicySpec]) -> dict:
    """Array encoding for the vmapped simulator. ``cfrac`` is the
    RAND_DEADLINE commitment fraction, precomputed in float64 here (and in
    RandDeadline.__init__) so both simulators floor identical f32 bits."""
    return {
        "kind": np.array([p.kind for p in pool], np.int32),
        "omega": np.array([p.omega for p in pool], np.int32),
        "v": np.array([max(p.v, 1) for p in pool], np.int32),
        "sigma": np.array([p.sigma for p in pool], np.float32),
        "rho": np.array([p.rho for p in pool], np.float32),
        "cfrac": np.array(
            [rand_commit_frac(p.sigma) if p.kind == KIND_RAND else 0.0
             for p in pool], np.float32,
        ),
    }
