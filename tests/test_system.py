"""End-to-end behaviour: the full paper pipeline — market -> forecasts ->
policy pool -> online selection across jobs -> the selected policy beats the
baselines (the paper's headline claim, small-scale)."""
import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core import fast_sim
from repro.core.job import normalize_utility
from repro.core.market import vast_like_trace
from repro.core.policy_pool import baseline_specs, paper_pool, specs_to_arrays
from repro.core.predictor import NoisyPredictor
from repro.core.selector import best_policy, init_selector, regret, regret_bound, update

TPUT = ThroughputConfig(mu1=0.9, mu2=0.95)


def _job(rng):
    return JobConfig(
        workload=float(rng.uniform(70, 120)),
        deadline=10,
        n_min=int(rng.integers(1, 4)),
        n_max=int(rng.integers(12, 17)),
        value=120.0,
    )


def test_online_selection_pipeline():
    pool = paper_pool(omegas=(1, 3, 5), sigmas=(0.3, 0.5, 0.7, 0.9))
    specs = pool + baseline_specs()
    arrs = specs_to_arrays(specs)
    K = 60
    rng = np.random.default_rng(0)
    st = init_selector(len(specs), K)
    # scarce, volatile market: spot alone cannot carry the job, so foresight
    # (AHAP) or adaptive reaction (AHANP) is required to beat the baselines
    trace = vast_like_trace(seed=42, days=30, mean_price=0.7, price_sigma=0.5,
                            avail_mean=5.0, avail_season_amp=3.0)
    base_utils = np.zeros(len(specs))
    for k in range(K):
        job = _job(rng)
        t0 = int(rng.integers(0, len(trace) - job.deadline - 1))
        tr = trace.window(t0, job.deadline + 1)
        pred = NoisyPredictor(tr, "fixed_uniform", 0.15, seed=k).matrix(
            fast_sim.W1MAX - 1
        )
        prices, avail, pm = fast_sim.prepare_inputs(tr, pred, job.deadline)
        out = fast_sim.simulate_pool(
            arrs, fast_sim.JobArrays.of(job), TPUT, prices, avail, pm
        )
        u_raw = np.asarray(out["utility"])
        base_utils += u_raw
        st = update(st, np.asarray(normalize_utility(job, u_raw)))

    # Theorem 2 bound holds on the real pipeline
    assert regret(st) <= regret_bound(len(specs), K)
    # the selected policy is one of ours, not a baseline, and beats them
    b = best_policy(st)
    assert specs[b].kind in (0, 1), specs[b].name
    mean_u = base_utils / K
    n_base = len(baseline_specs())
    assert mean_u[b] >= mean_u[-n_base:].max() - 1e-6, (
        specs[b].name, mean_u[b], mean_u[-n_base:]
    )
