"""Market trace calibration (paper Fig. 2) and predictor behavior (Fig. 3)."""
import numpy as np
import pytest

from repro.core.market import TraceStats, vast_like_trace
from repro.core.predictor import (
    ARIMAPredictor,
    NOISE_KINDS,
    NoisyPredictor,
    PerfectPredictor,
    forecast_errors,
    mape,
)


def test_trace_calibration():
    stats = [TraceStats.of(vast_like_trace(seed=s, days=10)) for s in range(5)]
    m_over_p90 = np.mean([s.median_over_p90 for s in stats])
    # paper Fig. 2(b): median ~= 60% of P90
    assert 0.5 < m_over_p90 < 0.75, m_over_p90
    for s in stats:
        assert 0 <= s.avail_mean <= 16
        # diurnal cycle: nights have less availability
        assert s.avail_day_night_ratio > 1.1


def test_trace_bounds():
    tr = vast_like_trace(seed=1, days=10)
    assert tr.avail.min() >= 0 and tr.avail.max() <= 16
    assert np.all(tr.prices > 0)
    assert len(tr) == 480


def test_perfect_predictor_exact():
    tr = vast_like_trace(seed=2, days=2)
    M = PerfectPredictor(tr).matrix(5)
    for j in range(6):
        t = 10
        assert M[t, j, 0] == pytest.approx(tr.prices[t + j])
        assert M[t, j, 1] == pytest.approx(tr.avail[t + j])


@pytest.mark.parametrize("kind", NOISE_KINDS)
def test_noise_grows_with_horizon(kind):
    tr = vast_like_trace(seed=3, days=4)
    pred = NoisyPredictor(tr, kind, level=0.3, seed=0)
    errs = forecast_errors(tr, pred, horizon=5)["price"]
    assert errs[-1] > errs[0] * 0.8  # roughly increasing
    # present is observed exactly
    M = pred.matrix(5)
    np.testing.assert_allclose(M[:, 0, 0], tr.prices, atol=1e-9)


def test_noise_level_ordering():
    tr = vast_like_trace(seed=4, days=4)
    e_small = np.mean(forecast_errors(tr, NoisyPredictor(tr, "fixed_uniform", 0.1, 0), 5)["price"])
    e_big = np.mean(forecast_errors(tr, NoisyPredictor(tr, "fixed_uniform", 0.5, 0), 5)["price"])
    assert e_big > e_small


def test_heavytail_has_outliers():
    tr = vast_like_trace(seed=5, days=4)
    u = NoisyPredictor(tr, "fixed_uniform", 0.3, 0).matrix(5)
    h = NoisyPredictor(tr, "fixed_heavytail", 0.3, 0).matrix(5)
    du = np.abs(u[:, 1:, 0] - PerfectPredictor(tr).matrix(5)[:, 1:, 0])
    dh = np.abs(h[:, 1:, 0] - PerfectPredictor(tr).matrix(5)[:, 1:, 0])
    assert np.percentile(dh, 99.5) > np.percentile(du, 99.5)


def test_arima_beats_persistence_on_seasonal_trace():
    tr = vast_like_trace(seed=6, days=6)
    horizon = 4
    arima_err = np.mean(forecast_errors(tr, ARIMAPredictor(tr), horizon)["price"][1:])
    # persistence: predict current value for all future steps
    T = len(tr)
    pers = []
    for j in range(2, horizon + 1):
        pred = tr.prices[: T - j]
        true = tr.prices[j:]
        pers.append(mape(pred, true))
    assert arima_err < np.mean(pers) * 1.15, (arima_err, np.mean(pers))


def test_arima_availability_integer_capped():
    tr = vast_like_trace(seed=7, days=4)
    M = ARIMAPredictor(tr).matrix(3)
    av = M[:, 1:, 1]
    assert np.all(av >= 0) and np.all(av <= 16)
    assert np.allclose(av, np.round(av))
