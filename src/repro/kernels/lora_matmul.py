"""Fused base+LoRA matmul Pallas TPU kernel.

Computes  y = x @ W + scale * (x @ A) @ B  in ONE pass over x and W:
the rank-r bottleneck (x @ A) is accumulated alongside the main MXU matmul
in an f32 VMEM scratch, and the (tiny) @B epilogue is fused into the final
k-step — the low-rank path never round-trips through HBM. This is the
TPU-native adaptation of the fused-adapter GEMMs used by LoRA serving
systems (DESIGN.md §4): A (bk x r) stays resident in VMEM per k-step and r
(= 8..64) rides in the MXU lane dimension.

Grid: (M/bm, N/bn, K/bk), k innermost (sequential) so the f32 accumulators
persist across k-steps of one (i, j) tile — the canonical Pallas matmul
pattern. Block shapes default to MXU-aligned 128 tiles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, xa_ref, *,
            scale: float, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(
        x, w_ref[...], preferred_element_type=jnp.float32
    )
    xa_ref[...] += jnp.dot(
        x, a_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        delta = jnp.dot(
            xa_ref[...], b_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        o_ref[...] = (acc_ref[...] + scale * delta).astype(o_ref.dtype)


def lora_matmul(
    x: jnp.ndarray,          # (M, K)
    w: jnp.ndarray,          # (K, N)
    a: jnp.ndarray,          # (K, r)
    b: jnp.ndarray,          # (r, N)
    scale: float,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    m, k = x.shape
    k2, n = w.shape
    r = a.shape[1]
    assert k == k2 and a.shape[0] == k and b.shape == (r, n)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),  # x
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),  # w
            pl.BlockSpec((bk, r), lambda i, j, kk: (kk, 0)),   # a
            pl.BlockSpec((r, bn), lambda i, j, kk: (0, j)),    # b
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            # f32 accumulators resident in VMEM across the k grid dim
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a, b)
