"""Multi-job scheduling extension (paper Sec. III-A: "readily extended")."""
import numpy as np
import pytest

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.market import constant_trace, from_arrays, vast_like_trace
from repro.core.multi_job import MultiJobScheduler
from repro.core.policies import AHAP, AHAPParams, UP
from repro.core.predictor import PerfectPredictor
from repro.core.simulator import simulate

TPUT = ThroughputConfig(mu1=0.9, mu2=0.95)
JOB = JobConfig(workload=40, deadline=8, n_min=1, n_max=10, value=80.0)


def test_single_job_matches_reference_simulator():
    """With one job, the multi-job scheduler == the single-job simulator."""
    tr = vast_like_trace(seed=1, days=1).window(0, 12)
    sched = MultiJobScheduler(TPUT, tr)
    sched.submit(0, JOB, UP())
    res = sched.run(10)[0]
    ref = simulate(UP(), JOB, TPUT, tr)
    assert res.utility == pytest.approx(ref.utility, abs=1e-6)
    assert res.cost == pytest.approx(ref.cost, abs=1e-6)
    assert res.completion_time == pytest.approx(ref.completion_time, abs=1e-6)


def test_capacity_is_shared_not_duplicated():
    """Two greedy jobs on a 6-unit pool can never take more than 6 spot."""
    tr = constant_trace(0.4, 6, 20)
    sched = MultiJobScheduler(TPUT, tr)
    sched.submit(0, JOB, UP())
    sched.submit(0, JOB, UP())
    spot_by_slot = np.zeros(20)
    for t in range(16):
        if not sched.active:
            break
        active_before = list(sched.active)
        sched.step(t)
        for aj in active_before:
            if aj.alloc_spot and len(aj.alloc_spot) - 1 == t - aj.arrival:
                spot_by_slot[t] += aj.alloc_spot[-1]
    assert np.all(spot_by_slot <= 6 + 1e-9)
    assert spot_by_slot[:3].sum() > 0  # the pool is actually used


def test_least_slack_gets_spot_first():
    """A nearly-late job outranks a fresh one for scarce cheap spot."""
    tr = constant_trace(0.3, 4, 30)
    sched = MultiJobScheduler(TPUT, tr)
    tight = JobConfig(workload=40, deadline=5, n_min=1, n_max=10, value=80.0)
    loose = JobConfig(workload=10, deadline=12, n_min=1, n_max=10, value=80.0)
    a = sched.submit(0, tight, UP())
    b = sched.submit(0, loose, UP())
    aj_tight = next(j for j in sched.active if j.job_id == a)
    aj_loose = next(j for j in sched.active if j.job_id == b)
    sched.step(0)
    assert aj_tight.alloc_spot[0] >= aj_loose.alloc_spot[0]
    results = {r.job_id: r for r in sched.run(25)}
    assert results[a].completed_by_deadline or results[a].completion_time < 7
    assert results[b].completed_by_deadline


def test_contention_costs_utility():
    """Sharing a scarce pool can only hurt (vs having it alone)."""
    tr = from_arrays(np.full(20, 0.4), np.full(20, 5))
    solo = simulate(UP(), JOB, TPUT, tr)
    sched = MultiJobScheduler(TPUT, tr)
    sched.submit(0, JOB, UP())
    sched.submit(0, JOB, UP())
    rs = sched.run(18)
    for r in rs:
        assert r.utility <= solo.utility + 1e-6
    assert min(r.utility for r in rs) < solo.utility  # someone paid for od


def test_ahap_jobs_with_forecasts():
    tr = vast_like_trace(seed=3, days=1)
    pred = PerfectPredictor(tr).matrix(5)
    sched = MultiJobScheduler(TPUT, tr)
    sched.submit(0, JOB, AHAP(AHAPParams(3, 1, 0.7)), pred=pred)
    sched.submit(2, JOB, AHAP(AHAPParams(3, 1, 0.7)), pred=pred)
    rs = sched.run(30)
    assert len(rs) == 2
    for r in rs:
        assert np.isfinite(r.utility)
        assert r.cost >= 0
