"""Slot-level execution of a policy on a market trace (reference simulator).

Semantics (Sec. III): instances are billed per whole slot; progress in a slot
is mu_t * H(n_t) (Eq. 1-2); the job stops renting once Z >= L; workload left
at the deadline is finished by the termination configuration (N^max
on-demand, fractionally billed) which is exactly the Ṽ(Z^ddl) - C^ddl
objective (Eq. 9). Completion time is fractional within the finishing slot so
V(T) is evaluated on continuous T (Eq. 4).

The vmapped JAX twin of this loop lives in fast_sim.py;
tests/test_selector_fastsim.py pins them against each other.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.job import tilde_value, value_fn
from repro.core.market import Trace
from repro.core.policies import BasePolicy, Obs


@dataclass
class SimResult:
    utility: float
    value: float
    cost: float
    completion_time: float      # slots (may exceed d via termination config)
    z_ddl: float
    completed_by_deadline: bool
    n_total: np.ndarray
    n_spot: np.ndarray
    n_od: np.ndarray

    @property
    def workload_done(self) -> float:
        return self.z_ddl


def exec_slot(job: JobConfig, tput: ThroughputConfig, z: float, n_prev: int,
              t: int, n_o: int, n_s: int, price: float, avail: int):
    """One slot of the paper's execution semantics, shared by this loop and
    the regional reference (region_market.simulate_regional): hard
    feasibility clip (5b)-(5d), mu reconfiguration ramp, whole-slot billing,
    fractional completion. Returns (n_o, n_s, work, cost_delta,
    t_complete-or-None)."""
    n_s = int(np.clip(n_s, 0, min(avail, job.n_max)))
    n_o = int(np.clip(n_o, 0, job.n_max - n_s))
    n = n_o + n_s
    if 0 < n < job.n_min:
        n_o += job.n_min - n
        n = n_o + n_s

    mu = 1.0 if n == n_prev else (tput.mu1 if n > n_prev else tput.mu2)
    if n == 0 and n_prev == 0:
        mu = 1.0
    work = mu * (tput.alpha * n + (tput.beta if n > 0 else 0.0))
    cost_delta = n_s * price + n_o * job.on_demand_price  # whole-slot billing

    t_complete = None
    if work > 0 and z + work >= job.workload:
        t_complete = t + (job.workload - z) / work
    return n_o, n_s, work, cost_delta, t_complete


def termination_config(job: JobConfig, tput: ThroughputConfig, z: float):
    """Finish the leftover workload with N^max on-demand past the deadline
    (fractionally billed, Eq. 9). Returns (extra_slots, extra_cost)."""
    h_max = tput.alpha * job.n_max + tput.beta
    dt = (job.workload - z) / h_max
    return dt, job.on_demand_price * job.n_max * dt


def simulate(
    policy: BasePolicy,
    job: JobConfig,
    tput: ThroughputConfig,
    trace: Trace,
    pred_matrix: Optional[np.ndarray] = None,  # (T, horizon+1, 2)
) -> SimResult:
    d = job.deadline
    assert len(trace) >= d, "trace shorter than deadline"
    policy.reset(job, tput)

    z, n_prev, cost = 0.0, 0, 0.0
    T_complete: Optional[float] = None
    ns_hist, no_hist = np.zeros(d, int), np.zeros(d, int)

    for t in range(d):
        price, avail = float(trace.prices[t]), int(trace.avail[t])
        pred = pred_matrix[t] if pred_matrix is not None else None
        obs = Obs(t=t, price=price, avail=avail, z_prev=z, n_prev=n_prev, pred=pred)
        n_o, n_s = policy.decide(obs)
        # hard feasibility (5b)-(5d): never trust a policy blindly
        n_o, n_s, work, dc, T_complete = exec_slot(
            job, tput, z, n_prev, t, n_o, n_s, price, avail
        )
        cost += dc
        ns_hist[t], no_hist[t] = n_s, n_o
        z = min(z + work, job.workload)
        n_prev = n_o + n_s
        if T_complete is not None:
            break

    if T_complete is not None:
        value = float(value_fn(job, T_complete))
    else:
        # termination configuration: N^max on-demand past the deadline
        dt, dc = termination_config(job, tput, z)
        T_complete = d + dt
        cost += dc
        value = float(value_fn(job, T_complete))

    return SimResult(
        utility=value - cost,
        value=value,
        cost=cost,
        completion_time=float(T_complete),
        z_ddl=float(z),
        completed_by_deadline=T_complete <= d,
        n_total=ns_hist + no_hist,
        n_spot=ns_hist,
        n_od=no_hist,
    )
