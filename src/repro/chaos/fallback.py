"""Online prediction-failure fallback: the static ``fallback=`` flag.

The paper's complementary prediction-free algorithm (AHANP, Alg. 3) as a
*runtime degradation path* for the prediction-consuming AHAP lanes: the
jitted scans carry a per-lane realized-forecast-error EWMA (computed from
values already flowing through the scan — last slot's 1-step-ahead
forecast vs this slot's observed price/availability), and while the EWMA
exceeds ``threshold`` the lane's decision is taken from the AHANP rule
instead of the AHAP window solve. Plans keep updating underneath, so when
the monitor recovers the lane resumes AHAP with a warm plan history.

``FallbackConfig`` is a frozen (hashable) dataclass so it can ride the
engines' static jit arguments and the ``lru_cache`` keys of the sharded
runners, exactly like the ``collect=`` flag: ``fallback=None`` (the
default everywhere) traces the bitwise-identical shipped program, pinned
single-device in tests/test_chaos.py and in both forced-4-device
subprocess parity tests. Each distinct config is a distinct compiled
program — sweep thresholds sparingly.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class FallbackConfig:
    """Knobs of the prediction-health monitor (all static constants).

    ``threshold``     EWMA level above which a lane runs AHANP instead of
                      AHAP (relative-error units; 0.5 means the blended
                      1-step forecast has been ~50% off lately)
    ``lam``           EWMA smoothing weight of the newest error sample
    ``price_weight``  blend between the price relative error (weight
                      ``price_weight``) and the availability relative
                      error (``1 - price_weight``)
    """
    threshold: float = 0.5
    lam: float = 0.25
    price_weight: float = 0.5

    def __post_init__(self):
        if not (self.threshold > 0):
            raise ValueError(f"threshold must be > 0, got {self.threshold}")
        if not (0 < self.lam <= 1):
            raise ValueError(f"lam must be in (0, 1], got {self.lam}")
        if not (0 <= self.price_weight <= 1):
            raise ValueError(
                f"price_weight must be in [0, 1], got {self.price_weight}")
