from repro.data.loader import ShardedLMLoader
from repro.data.synthetic import lm_batches, token_stream
