"""Spot market model: price/availability traces with Vast.ai-like statistics.

The paper (Fig. 2) collected 10 days of A100 spot data from Vast.ai at
30-minute slots and observed (a) a strong diurnal availability cycle,
(b) median price ~= 60% of the P90 price, (c) availability capped at a small
regional pool (normalized to [0, 16]). ``vast_like_trace`` reproduces those
statistics with a seasonal + AR(1) lognormal price process and a negatively
correlated availability process; ``TraceStats`` verifies the calibration
(tests + benchmarks/fig2).

A ``Trace`` describes ONE spot region. Multi-region markets (stacked per-
region traces with time-zone phase-shifted diurnal cycles and a migration
cost) live in repro.core.region_market; ``season_phase_slots`` below is the
knob that shifts a single region's diurnal cycle.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Trace:
    prices: np.ndarray          # (T,) spot price, on-demand normalized to 1.0
    avail: np.ndarray           # (T,) int, available spot instances
    slot_seconds: float = 1800.0
    slots_per_day: int = 48
    meta: dict = field(default_factory=dict)

    def __len__(self):
        return len(self.prices)

    def window(self, t0: int, length: int) -> "Trace":
        if t0 < 0 or length < 0 or t0 + length > len(self.prices):
            raise ValueError(
                f"window [{t0}, {t0 + length}) out of bounds for trace of "
                f"length {len(self.prices)}"
            )
        return Trace(
            self.prices[t0 : t0 + length],
            self.avail[t0 : t0 + length],
            self.slot_seconds,
            self.slots_per_day,
            dict(self.meta, t0=t0),
        )


def require_finite(name: str, arr) -> None:
    """Reject NaN/inf before they reach the jitted engines, where they
    would propagate silently through the scans as garbage utilities.
    The error names the offender and where it first appears."""
    arr = np.asarray(arr)
    if not np.issubdtype(arr.dtype, np.floating):
        return
    bad = ~np.isfinite(arr)
    if bad.any():
        first = np.unravel_index(int(np.argmax(bad)), arr.shape)
        raise ValueError(
            f"{name} contains {int(bad.sum())} non-finite value(s) "
            f"(NaN/inf), first at index {tuple(int(i) for i in first)}"
        )


def gather_windows(trace: Trace, t0s, length: int):
    """Batched :meth:`Trace.window`: gather K windows of ``length`` slots in
    one fancy-indexing pass — ``(prices (K, length), avail (K, length))``.
    Same bounds rule as ``window`` (every [t0, t0+length) must lie inside
    the trace). The row-k arrays equal ``trace.window(t0s[k], length)``'s;
    this is what core.engine's prep uses instead of a per-job window loop."""
    t0s = np.asarray(t0s, np.int64)
    if length < 0 or (t0s.size and (
            int(t0s.min()) < 0 or int(t0s.max()) + length > len(trace))):
        raise ValueError(
            f"windows of length {length} at t0 in [{t0s.min()}, {t0s.max()}] "
            f"out of bounds for trace of length {len(trace)}"
        )
    require_finite("trace.prices", trace.prices)
    require_finite("trace.avail", trace.avail)
    idx = t0s[:, None] + np.arange(length)[None, :]
    return trace.prices[idx], trace.avail[idx]


@dataclass
class TraceStats:
    price_median: float
    price_p90: float
    median_over_p90: float
    avail_mean: float
    avail_day_night_ratio: float

    @staticmethod
    def of(trace: Trace) -> "TraceStats":
        p = trace.prices
        spd = trace.slots_per_day
        t = np.arange(len(p)) % spd
        day = (t >= spd // 4) & (t < 3 * spd // 4)
        a = trace.avail.astype(float)
        night_mean = max(a[~day].mean(), 1e-9) if (~day).any() else 1.0
        return TraceStats(
            price_median=float(np.median(p)),
            price_p90=float(np.percentile(p, 90)),
            median_over_p90=float(np.median(p) / max(np.percentile(p, 90), 1e-9)),
            avail_mean=float(a.mean()),
            avail_day_night_ratio=float(a[day].mean() / night_mean) if day.any() else 1.0,
        )


def _ar1(rng, n, rho, sigma):
    x = np.zeros(n)
    e = rng.normal(0, sigma, n)
    for i in range(1, n):
        x[i] = rho * x[i - 1] + e[i]
    return x


def vast_like_trace(
    seed: int = 0,
    days: float = 10.0,
    slots_per_day: int = 48,
    *,
    mean_price: float = 0.45,
    price_sigma: float = 0.32,       # lognormal spread -> median/P90 ~ 0.6
    price_season_amp: float = 0.12,
    avail_mean: float = 8.0,
    avail_season_amp: float = 3.5,
    avail_sigma: float = 2.0,
    avail_max: int = 16,
    price_avail_corr: float = -0.5,
    rho: float = 0.85,
    season_phase_slots: float = 0.0,
) -> Trace:
    """Synthetic 30-min-slot A100 spot market calibrated to paper Fig. 2.

    ``season_phase_slots`` delays the diurnal cycle by that many slots —
    a region ``h`` hours west of the reference has its midday (availability
    peak) ``h * slots_per_day / 24`` slots later. 0.0 keeps the original
    trace bit-for-bit.
    """
    rng = np.random.default_rng(seed)
    n = int(days * slots_per_day)
    tod = (
        2 * np.pi
        * ((np.arange(n) - season_phase_slots) % slots_per_day)
        / slots_per_day
    )

    # shared diurnal demand driver: prices high / availability low at night
    # (paper Fig. 2: "higher availability during the daytime than at night")
    season = np.cos(tod)  # +1 midnight .. -1 midday
    z_price = _ar1(rng, n, rho, price_sigma * np.sqrt(1 - rho**2))
    prices = mean_price * np.exp(
        price_season_amp * season + z_price - 0.5 * price_sigma**2
    )
    prices = np.clip(prices, 0.02, 1.5)

    z_av = _ar1(rng, n, rho, avail_sigma * np.sqrt(1 - rho**2))
    corr_term = price_avail_corr * (z_price / max(price_sigma, 1e-9)) * avail_sigma
    avail = avail_mean - avail_season_amp * season + z_av * np.sqrt(1 - price_avail_corr**2) + corr_term
    avail = np.clip(np.round(avail), 0, avail_max).astype(np.int64)

    return Trace(
        prices=prices.astype(np.float64),
        avail=avail,
        slot_seconds=86400.0 / slots_per_day,
        slots_per_day=slots_per_day,
        meta={"seed": seed, "days": days, "kind": "vast_like",
              "season_phase_slots": season_phase_slots},
    )


def constant_trace(price: float, avail: int, length: int) -> Trace:
    return Trace(
        np.full(length, price), np.full(length, avail, np.int64),
        meta={"kind": "constant"},
    )


def from_arrays(prices, avail, **meta) -> Trace:
    return Trace(
        np.asarray(prices, np.float64),
        np.asarray(avail, np.int64),
        meta=dict(meta, kind="explicit"),
    )
