"""Fig. 9: convergence of online policy selection under the four prediction
noise regimes, plus the fixed-hyperparameter ablation pools (fixed v=1 /
fixed sigma=0.9).

1000 jobs per setting (paper's count), workloads U[70,120], deadline 10,
Nmin in [1,4], Nmax in [12,16]. The whole 112-policy x 1000-job workload is
ONE vmapped simulate_pool_jobs call per setting.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PAPER_TPUT, job_stream, paper_market, timed
from repro.core import fast_sim
from repro.core.job import normalize_utility
from repro.core.policy_pool import paper_pool, specs_to_arrays
from repro.core.predictor import NoisyPredictor
from repro.core.selector import best_policy, init_selector, regret, regret_bound, update

N_JOBS = 1000


def _utilities_matrix(pool_specs, kind: str, level: float, n_jobs: int, seed: int):
    """(K, M) raw utilities of every policy on every job."""
    rng = np.random.default_rng(seed)
    trace = paper_market(seed=21, days=40)
    jobs = list(job_stream(rng, n_jobs))
    d = jobs[0].deadline
    trs, preds = [], []
    for k in range(n_jobs):
        t0 = int(rng.integers(0, len(trace) - d - 1))
        w = trace.window(t0, d + 1)
        trs.append(w)
        preds.append(
            NoisyPredictor(w, kind, level, seed=seed * 100003 + k).matrix(
                fast_sim.W1MAX - 1
            )[:d]
        )
    arrs = specs_to_arrays(pool_specs)
    out = fast_sim.simulate_pool_jobs(
        arrs, fast_sim.stack_jobs(jobs), PAPER_TPUT,
        np.stack([t.prices[:d] for t in trs]).astype(np.float32),
        np.stack([t.avail[:d] for t in trs]),
        np.stack(preds).astype(np.float32),
    )
    u = np.asarray(out["utility"])  # (K, M)
    un = np.stack([
        np.asarray(normalize_utility(jobs[k], u[k])) for k in range(n_jobs)
    ])
    return u, un


def _converge(un: np.ndarray, M: int):
    """Run EG; return (best_idx, iterations till best weight > 0.5, regret_ratio)."""
    K = un.shape[0]
    st = init_selector(M, K)
    t_half = None
    for k in range(K):
        st = update(st, un[k])
        if t_half is None and st.weights.max() > 0.5:
            t_half = k + 1
    return best_policy(st), (t_half or K), regret(st) / regret_bound(M, K)


def run() -> list:
    rows = []
    settings = [
        ("magdep_uniform", 0.1),
        ("fixed_uniform", 0.1),
        ("magdep_heavytail", 0.3),
        ("fixed_heavytail", 0.3),
    ]
    pool = paper_pool()
    winners = {}
    for kind, level in settings:
        (u, un), us = timed(_utilities_matrix, pool, kind, level, N_JOBS, seed=7)
        best, t_half, rratio = _converge(un, len(pool))
        winners[(kind, level)] = best
        rows.append((f"fig9_{kind}_{level:g}_best_policy_idx", us, best))
        rows.append((f"fig9_{kind}_{level:g}_iters_to_half_weight", us, t_half))
        rows.append((f"fig9_{kind}_{level:g}_regret_over_bound", us, rratio))
        rows.append((f"fig9_{kind}_{level:g}_best_is_ahap", 0.0,
                     float(pool[best].kind == 0)))
    # noise regime changes the winning policy (the paper's point)
    rows.append(("fig9_distinct_winners", 0.0, float(len(set(winners.values())))))

    # hyperparameter ablations (fixed v=1 / fixed sigma=0.9), Fig. 9 bottom
    for name, pool_fn in [
        ("fixed_v1", lambda: paper_pool(fixed_v=1)),
        ("fixed_sigma09", lambda: paper_pool(fixed_sigma=0.9)),
    ]:
        sub = pool_fn()
        (u, un), us = timed(_utilities_matrix, sub, "fixed_uniform", 0.1, 400, seed=9)
        best, t_half, _ = _converge(un, len(sub))
        # restricting the pool lowers the achievable utility ceiling
        rows.append((f"fig9_{name}_pool_size", us, len(sub)))
        rows.append((f"fig9_{name}_best_mean_utility", us, u.mean(axis=0).max()))
    (u_full, _), _ = timed(_utilities_matrix, pool, "fixed_uniform", 0.1, 400, seed=9)
    rows.append(("fig9_full_pool_best_mean_utility", 0.0, u_full.mean(axis=0).max()))
    return rows
