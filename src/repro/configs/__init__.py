"""Architecture config registry.

``--arch <id>`` anywhere in the framework resolves through ``get_config``.
The 10 ASSIGNED architectures are the public-pool assignment for this paper;
``llama2-7b`` is the paper's own fine-tuning target and ``tiny-100m`` backs the
CPU end-to-end example.
"""
from repro.configs.base import (
    INPUT_SHAPES,
    JobConfig,
    LoRAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    ThroughputConfig,
    TrainConfig,
    shape_applicable,
)

from repro.configs import (
    command_r_plus_104b,
    granite_20b,
    hubert_xlarge,
    llama2_7b,
    mamba2_370m,
    mixtral_8x22b,
    mixtral_8x7b,
    olmo_1b,
    qwen1_5_110b,
    qwen2_vl_7b,
    tiny_100m,
    zamba2_2_7b,
)

_MODULES = {
    "qwen2-vl-7b": qwen2_vl_7b,
    "mamba2-370m": mamba2_370m,
    "olmo-1b": olmo_1b,
    "zamba2-2.7b": zamba2_2_7b,
    "qwen1.5-110b": qwen1_5_110b,
    "mixtral-8x7b": mixtral_8x7b,
    "mixtral-8x22b": mixtral_8x22b,
    "granite-20b": granite_20b,
    "command-r-plus-104b": command_r_plus_104b,
    "hubert-xlarge": hubert_xlarge,
    "llama2-7b": llama2_7b,
    "tiny-100m": tiny_100m,
}

ASSIGNED_ARCHS = (
    "qwen2-vl-7b",
    "mamba2-370m",
    "olmo-1b",
    "zamba2-2.7b",
    "qwen1.5-110b",
    "mixtral-8x7b",
    "mixtral-8x22b",
    "granite-20b",
    "command-r-plus-104b",
    "hubert-xlarge",
)


def list_archs():
    return list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    return _MODULES[name].config()


def get_smoke_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    return _MODULES[name].smoke_config()


__all__ = [
    "ASSIGNED_ARCHS",
    "INPUT_SHAPES",
    "JobConfig",
    "LoRAConfig",
    "ModelConfig",
    "MoEConfig",
    "ShapeConfig",
    "SSMConfig",
    "ThroughputConfig",
    "TrainConfig",
    "get_config",
    "get_smoke_config",
    "list_archs",
    "shape_applicable",
]
