"""Multi-region pool simulation benchmark (the SkyNomad scenario).

Three Vast.ai-like regions share one diurnal demand cycle phase-shifted by
8 hours each (it is always midday somewhere), in the paper's evaluation
regime (scarce availability, strong cycle). Jobs with 8-hour deadlines land
on random windows, so every job starts at a different point of the cycle.

Comparators:
  single-region   the compact scheduling slate (region_pool's base: AHAP
                  corners + AHANP + MSU + UP) pinned to each region
                  separately — best mean utility over (lane, region) is the
                  strongest thing a single-region scheduler could do.
  region lanes    the same slate crossed with region-selection strategies
                  (greedy-price / greedy-avail / predicted-horizon, plain
                  and sticky) via fast_sim.simulate_pool_regions, paying
                  ``delta_mig`` checkpoint-transfer slots per move.

The headline `region_sim_gain` row is (best region lane - best single
region) mean utility; the acceptance bar is gain > 0 — migration must beat
the best fixed region even after paying for its moves. Rows are also folded
into BENCH_pool_sim.json (region rows replaced in place, the rest of the
file untouched).

Env knobs: REGION_SIM_JOBS (default 16), REGION_SIM_REPEAT (default 3);
POOL_SIM_MESH picks the pool mesh for the sharded region entry point
(shared with pool_sim_bench; single device falls back bitwise to the
unsharded path).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import PAPER_TPUT, job_stream, merge_bench_rows
from benchmarks.pool_sim_bench import _JSON_PATH

N_JOBS = int(os.environ.get("REGION_SIM_JOBS", "16"))
REPEAT = int(os.environ.get("REGION_SIM_REPEAT", "3"))
N_REGIONS = 3
DEADLINE = 16          # 8 hours of 30-min slots: spans half a phase offset
DELTA_MIG = 1


def _market():
    from repro.core.region_market import vast_like_regions

    # paper_market's scarce regime (benchmarks/common.py), regionalized:
    # phases 8h apart so availability droughts never align across regions
    return vast_like_regions(
        N_REGIONS, seed=13, days=4,
        phase_hours=(0.0, 8.0, 16.0),
        mean_price=0.7, price_sigma=0.5,
        avail_mean=5.5, avail_season_amp=3.0,
        delta_mig=DELTA_MIG,
    )


def _workload(n_jobs: int):
    from repro.core import fast_sim
    from repro.core.predictor import NoisyPredictor

    rng = np.random.default_rng(23)
    jobs = list(job_stream(rng, n_jobs, deadline=DEADLINE))
    market = _market()
    t0s = [int(rng.integers(0, len(market) - DEADLINE - 1))
           for _ in range(n_jobs)]
    wins = [market.window(t0, DEADLINE + 1) for t0 in t0s]
    prices = np.stack([w.prices[:, :DEADLINE] for w in wins]).astype(np.float32)
    avail = np.stack([w.avail[:, :DEADLINE] for w in wins]).astype(np.int64)
    preds = np.stack([
        np.stack([
            NoisyPredictor(w.region(r), "fixed_uniform", 0.2,
                           seed=i * N_REGIONS + r).matrix(
                fast_sim.W1MAX - 1
            )[:DEADLINE]
            for r in range(N_REGIONS)
        ])
        for i, w in enumerate(wins)
    ]).astype(np.float32)
    return jobs, prices, avail, preds


def _bench(fn, repeat: int = REPEAT) -> float:
    jax.block_until_ready(fn()["utility"])
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(fn()["utility"])
    return (time.perf_counter() - t0) / repeat


def _update_bench_json(rows, extra):
    """Fold the region rows into BENCH_pool_sim.json without disturbing the
    other modules' rows (shared merge in benchmarks.common)."""
    merge_bench_rows(_JSON_PATH, "region_sim", "region", rows, extra)


def run():
    from repro.core import fast_sim
    from repro.core.policy_pool import region_pool, specs_to_arrays
    from repro.launch.mesh import make_pool_mesh, parse_pool_mesh_shape

    # same mesh knob as pool_sim_bench: the sharded region entry falls back
    # bitwise to simulate_pool_regions on one device, so the headline gain
    # numbers are identical either way — only the throughput row scales
    mesh = make_pool_mesh(
        shape=parse_pool_mesh_shape(os.environ.get("POOL_SIM_MESH", ""))
    )
    jobs, prices, avail, preds = _workload(N_JOBS)
    stacked = fast_sim.stack_jobs(jobs)

    region_specs = region_pool()               # base slate x strategies
    base_specs = region_pool(strategies=(0,), margins=(0.0,))  # slate, fixed
    r_arrs = specs_to_arrays(region_specs)
    b_arrs = specs_to_arrays(base_specs)

    # best single-region lane: the base slate pinned to each region
    best_single, single_util = -np.inf, {}
    for r in range(N_REGIONS):
        out = fast_sim.simulate_pool_jobs(
            b_arrs, stacked, PAPER_TPUT,
            prices[:, r], avail[:, r], preds[:, r],
        )
        u = np.asarray(out["utility"]).mean(axis=0)
        single_util[r] = float(u.max())
        best_single = max(best_single, single_util[r])

    region_fn = lambda: fast_sim.simulate_pool_regions_sharded(
        r_arrs, stacked, PAPER_TPUT, prices, avail, preds,
        delta_mig=DELTA_MIG, mesh=mesh,
    )
    secs = _bench(region_fn)
    out = region_fn()
    u_region = np.asarray(out["utility"]).mean(axis=0)
    best_region = float(u_region.max())
    best_lane = region_specs[int(u_region.argmax())].name
    mean_migs = float(np.asarray(out["migrations"]).mean())

    work_units = DEADLINE * len(region_specs) * N_JOBS * N_REGIONS
    rows = [
        ("region_sim_regions", secs * 1e6, work_units / secs),
        ("region_sim_best_single", 0.0, best_single),
        ("region_sim_best_region_lane", 0.0, best_region),
        ("region_sim_gain", 0.0, best_region - best_single),
        ("region_sim_mean_migrations", 0.0, mean_migs),
    ]
    _update_bench_json(rows, {
        "workload": {
            "regions": N_REGIONS, "jobs": N_JOBS, "slots": DEADLINE,
            "delta_mig": DELTA_MIG, "lanes": len(region_specs),
        },
        "best_region_lane": best_lane,
        "single_region_best_utilities": single_util,
        "gain": best_region - best_single,
    })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
