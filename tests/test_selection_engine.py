"""Device-resident selection engine (core.engine + the batched selector /
predictor / normalization paths it chains).

Pins, per the engine's contracts:
  * ``run_eg_scan`` parity with the numpy ``update`` loop (weights,
    cum_expected, cum_utils, regret, first-max argmax ties) to f32
    tolerance, on random AND adversarial utility streams;
  * chunked-vs-unchunked ``simulate_and_select`` equality (trajectories
    bitwise, the mean-utility accumulator to f32 tolerance);
  * ``noisy_matrix_batch`` bitwise parity with per-job
    ``NoisyPredictor.matrix`` across all four noise regimes;
  * ``normalize_utility_batch`` parity with the per-job loop;
  * ``gather_windows`` / ``job_stream_arrays`` parity with their per-job
    twins;
  * the numpy selector's ``history_stride`` memory cap.
"""
import numpy as np
import pytest

from benchmarks.common import PAPER_TPUT, job_stream, job_stream_arrays, paper_market
from repro.core import engine, fast_sim
from repro.core import selector as sel
from repro.core.job import normalize_utility, normalize_utility_batch
from repro.core.market import gather_windows, vast_like_trace
from repro.core.policy_pool import (
    baseline_specs,
    paper_pool,
    rand_deadline_pool,
    specs_to_arrays,
)
from repro.core.predictor import NOISE_KINDS, NoisyPredictor, noisy_matrix_batch


def _numpy_reference(u, eta=None):
    """Run the numpy update loop over (K, M) utilities; return the state and
    the per-update max-weight trajectory."""
    K, M = u.shape
    st = sel.init_selector(M, K, eta=eta)
    max_w = []
    for k in range(K):
        st = sel.update(st, u[k])
        max_w.append(st.weights.max())
    return st, np.asarray(max_w)


def _assert_scan_matches(u, eta=None):
    K, M = u.shape
    st_np, max_w_np = _numpy_reference(u, eta=eta)
    st, traj = sel.run_eg_scan(sel.eg_init(M, K, eta=eta), u)
    np.testing.assert_allclose(
        np.asarray(st.weights), st_np.weights, atol=1e-5
    )
    np.testing.assert_allclose(
        float(st.cum_expected), st_np.cum_expected, rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(st.cum_utils), st_np.cum_utils, rtol=1e-4, atol=1e-3
    )
    np.testing.assert_allclose(
        sel.regret(st), sel.regret(st_np), rtol=1e-3, atol=1e-3
    )
    # a unique f64 winner must be the f32 winner too; analytically-tied
    # weights can land on either member of the tie in f32 (the exact-tie
    # case, where the columns are bitwise identical, is pinned separately)
    gap = st_np.weights.max() - np.partition(st_np.weights, -2)[-2]
    if gap > 1e-6:
        assert sel.best_policy(st) == sel.best_policy(st_np)
    else:
        assert np.isclose(
            st_np.weights[sel.best_policy(st)], st_np.weights.max(), atol=1e-6
        )
    assert int(st.k) == st_np.k == K
    # the convergence metric reads off the max-weight trajectory
    assert sel.iters_to_half(np.asarray(traj["max_weight"])) == \
        sel.iters_to_half(max_w_np)


def test_run_eg_scan_matches_numpy_random():
    rng = np.random.default_rng(0)
    means = rng.uniform(0.2, 0.8, 24)
    u = np.clip(rng.normal(means, 0.15, size=(400, 24)), 0, 1)
    _assert_scan_matches(u)


def test_run_eg_scan_matches_numpy_adversarial():
    """Alternating one-hot adversary + out-of-range utilities (the scan must
    clip to [0, 1] exactly like the numpy loop)."""
    M, K = 8, 300
    u = np.zeros((K, M))
    u[np.arange(K), np.arange(K) % M] = 1.7   # clipped to 1
    u[:, -1] = -0.3                           # clipped to 0
    _assert_scan_matches(u)


def test_run_eg_scan_argmax_ties_first_max():
    """Identical utility columns leave the weights tied — both
    implementations must pick the FIRST max."""
    u = np.full((50, 6), 0.5)
    u[:, 2:4] = 0.9  # columns 2 and 3 tie for best
    st_np, _ = _numpy_reference(u)
    st, _ = sel.run_eg_scan(sel.eg_init(6, 50), u)
    assert sel.best_policy(st) == sel.best_policy(st_np) == 2
    np.testing.assert_array_equal(
        np.asarray(st.weights)[2], np.asarray(st.weights)[3]
    )


def test_run_eg_scan_chained_chunks_bitwise():
    """Feeding the stream in chunks with the state threaded through equals
    one scan over the concatenation — the engine's streaming contract."""
    rng = np.random.default_rng(3)
    u = rng.uniform(0, 1, size=(120, 10)).astype(np.float32)
    whole, traj = sel.run_eg_scan(sel.eg_init(10, 120), u)
    st = sel.eg_init(10, 120)
    parts = []
    for lo in (0, 50, 100):
        st, t = sel.run_eg_scan(st, u[lo:lo + 50])
        parts.append(np.asarray(t["max_weight"]))
    np.testing.assert_array_equal(np.asarray(whole.weights), np.asarray(st.weights))
    np.testing.assert_array_equal(
        np.asarray(traj["max_weight"]), np.concatenate(parts)
    )


def test_selector_history_stride():
    """history_stride caps the host-side weight_history: every s-th update
    is recorded (plus the initial weights); stride 1 is the old behavior."""
    rng = np.random.default_rng(1)
    u = rng.uniform(0, 1, size=(20, 5))
    full = sel.init_selector(5, 20, track_history=True)
    strided = sel.init_selector(5, 20, track_history=True, history_stride=4)
    for k in range(20):
        full = sel.update(full, u[k], track_history=True)
        strided = sel.update(strided, u[k], track_history=True)
    assert len(full.weight_history) == 21
    assert len(strided.weight_history) == 1 + 20 // 4
    for i, h in enumerate(strided.weight_history[1:]):
        np.testing.assert_array_equal(h, full.weight_history[(i + 1) * 4])
    with pytest.raises(ValueError):
        sel.init_selector(5, 20, history_stride=0)


# ---------------------------------------------------------------------------
# batched prep: windows, predictors, job draws, normalization
# ---------------------------------------------------------------------------

def test_gather_windows_matches_window_loop():
    tr = vast_like_trace(seed=5, days=2)
    t0s = np.random.default_rng(0).integers(0, len(tr) - 11, 16)
    pw, aw = gather_windows(tr, t0s, 11)
    for k, t0 in enumerate(t0s):
        w = tr.window(int(t0), 11)
        np.testing.assert_array_equal(pw[k], w.prices)
        np.testing.assert_array_equal(aw[k], w.avail)
    with pytest.raises(ValueError):
        gather_windows(tr, [len(tr) - 5], 11)
    with pytest.raises(ValueError):
        gather_windows(tr, [-1], 11)


@pytest.mark.parametrize("kind", NOISE_KINDS)
def test_noisy_matrix_batch_matches_per_job(kind):
    """The whole (K, T, h+1, 2) forecast stack in one call, bitwise equal to
    K per-job NoisyPredictor constructions (same seeds, same windows)."""
    tr = vast_like_trace(seed=9, days=2)
    t0s = np.random.default_rng(2).integers(0, len(tr) - 12, 12)
    seeds = 7 * 100003 + np.arange(12)
    pw, aw = gather_windows(tr, t0s, 11)
    batch = noisy_matrix_batch(pw, aw, kind, 0.3, seeds, fast_sim.W1MAX - 1)
    ref = np.stack([
        NoisyPredictor(tr.window(int(t0), 11), kind, 0.3,
                       seed=int(s)).matrix(fast_sim.W1MAX - 1)
        for t0, s in zip(t0s, seeds)
    ])
    np.testing.assert_array_equal(batch, ref)


def test_job_stream_delegates_to_arrays():
    """job_stream and job_stream_arrays draw identical jobs from equal rng
    states (the delegation contract), and the arrays match stack_jobs."""
    arrs = job_stream_arrays(np.random.default_rng(11), 32)
    stacked = fast_sim.stack_jobs(list(job_stream(np.random.default_rng(11), 32)))
    for a, b, f in zip(arrs, stacked, fast_sim.JobArrays._fields):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=f)
    assert arrs.workload.shape == (32,)
    assert np.all((arrs.n_min >= 1) & (arrs.n_min < 4))
    assert np.all((arrs.n_max >= 12) & (arrs.n_max < 17))


def test_normalize_utility_batch_matches_per_job():
    rng = np.random.default_rng(4)
    jobs = job_stream_arrays(rng, 10)
    jobs_cfg = fast_sim.unstack_jobs(jobs)
    u = rng.uniform(-400, 130, size=(10, 7)).astype(np.float32)
    batch = np.asarray(normalize_utility_batch(jobs, u))
    ref = np.stack([
        np.asarray(normalize_utility(jobs_cfg[k], u[k])) for k in range(10)
    ])
    # Fig. 9 job params are all f32-exact, so the bounds agree bitwise
    np.testing.assert_array_equal(batch, ref)
    assert np.all((batch >= 0) & (batch <= 1))


# ---------------------------------------------------------------------------
# the engine end to end
# ---------------------------------------------------------------------------

def _small_workload(n_jobs=18, seed=7):
    pool = (paper_pool(omegas=(1, 3), sigmas=(0.3, 0.7))
            + rand_deadline_pool((0.3, 0.7)) + baseline_specs())
    arrs = specs_to_arrays(pool)
    rng = np.random.default_rng(seed)
    trace = paper_market(seed=21, days=4)
    jobs = job_stream_arrays(rng, n_jobs)
    d = int(np.asarray(jobs.deadline)[0])
    t0s = rng.integers(0, len(trace) - d - 1, size=n_jobs)
    seeds = seed * 100003 + np.arange(n_jobs)
    prices, avail, preds = engine.prepare_noisy_inputs(
        trace, t0s, d, "fixed_uniform", 0.2, seeds
    )
    return pool, arrs, jobs, prices, avail, preds


def test_engine_matches_host_loop_pipeline():
    """simulate_and_select lands on the pre-engine pipeline's decision: same
    simulated utilities (bitwise), f32-close weights, same winner."""
    pool, arrs, jobs, prices, avail, preds = _small_workload()
    n = int(jobs.workload.shape[0])
    res = engine.simulate_and_select(
        arrs, jobs, PAPER_TPUT, prices, avail, preds, return_utilities=True
    )
    out = fast_sim.simulate_pool_jobs(arrs, jobs, PAPER_TPUT, prices, avail, preds)
    u = np.asarray(out["utility"])
    np.testing.assert_array_equal(res.utilities, u)
    jobs_cfg = fast_sim.unstack_jobs(jobs)
    st = sel.init_selector(len(pool), n)
    for k in range(n):
        st = sel.update(st, np.asarray(normalize_utility(jobs_cfg[k], u[k])))
    assert res.best_policy() == sel.best_policy(st)
    np.testing.assert_allclose(
        np.asarray(res.state.weights), st.weights, atol=1e-5
    )
    np.testing.assert_allclose(
        sel.regret(res.state), sel.regret(st), atol=1e-3
    )
    np.testing.assert_allclose(
        res.mean_utility, u.mean(axis=0), rtol=1e-5, atol=1e-4
    )


def test_engine_chunked_equals_unchunked():
    """Job-chunked streaming (K >> memory mode): trajectories and final
    weights bitwise, the mean-utility accumulator to f32 tolerance —
    across the edge cases too: chunk == 1 (K single-job calls),
    interior sizes that do and don't divide K (5, 6 with K = 18),
    chunk == K (one full chunk) and chunk > K (clamped to one chunk)."""
    _, arrs, jobs, prices, avail, preds = _small_workload()
    n = int(np.shape(jobs.workload)[0])
    whole = engine.simulate_and_select(
        arrs, jobs, PAPER_TPUT, prices, avail, preds,
        track_history=True, return_utilities=True,
    )
    for chunk in (1, 5, 6, n, n + 7):
        part = engine.simulate_and_select(
            arrs, jobs, PAPER_TPUT, prices, avail, preds, job_chunk=chunk,
            track_history=True, return_utilities=True,
        )
        np.testing.assert_array_equal(whole.utilities, part.utilities)
        np.testing.assert_array_equal(whole.max_weight, part.max_weight)
        np.testing.assert_array_equal(whole.regret, part.regret)
        np.testing.assert_array_equal(whole.weight_history, part.weight_history)
        np.testing.assert_array_equal(
            np.asarray(whole.state.weights), np.asarray(part.state.weights)
        )
        np.testing.assert_allclose(
            whole.mean_utility, part.mean_utility, rtol=1e-5, atol=1e-4
        )
    with pytest.raises(ValueError):
        engine.simulate_and_select(
            arrs, jobs, PAPER_TPUT, prices, avail, preds, job_chunk=-1
        )


def test_engine_state_threads_across_calls():
    """Passing the returned state back in continues the stream (Fig. 10's
    phase schedule): two calls over halves == one call over the whole."""
    _, arrs, jobs, prices, avail, preds = _small_workload()
    n = int(jobs.workload.shape[0])
    whole = engine.simulate_and_select(
        arrs, jobs, PAPER_TPUT, prices, avail, preds
    )
    half = n // 2
    first = engine.simulate_and_select(
        arrs, fast_sim.slice_jobs(jobs, 0, half), PAPER_TPUT,
        prices[:half], avail[:half], preds[:half],
        eta=float(whole.state.eta),
    )
    second = engine.simulate_and_select(
        arrs, fast_sim.slice_jobs(jobs, half, n), PAPER_TPUT,
        prices[half:], avail[half:], preds[half:], state=first.state,
    )
    np.testing.assert_array_equal(
        np.asarray(whole.state.weights), np.asarray(second.state.weights)
    )
    np.testing.assert_array_equal(
        whole.max_weight, np.concatenate([first.max_weight, second.max_weight])
    )


def test_engine_sharded_flag_single_device_identical():
    """sharded=True rides simulate_pool_jobs_sharded, which falls back
    bitwise to the single-device path on one device."""
    _, arrs, jobs, prices, avail, preds = _small_workload(n_jobs=9)
    a = engine.simulate_and_select(
        arrs, jobs, PAPER_TPUT, prices, avail, preds, sharded=True,
        return_utilities=True,
    )
    b = engine.simulate_and_select(
        arrs, jobs, PAPER_TPUT, prices, avail, preds, sharded=False,
        return_utilities=True,
    )
    np.testing.assert_array_equal(a.utilities, b.utilities)
    np.testing.assert_array_equal(
        np.asarray(a.state.weights), np.asarray(b.state.weights)
    )
