"""Fig. 3: ARIMA forecast quality on price and availability (30-min slots)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.core.market import vast_like_trace
from repro.core.predictor import ARIMAPredictor, forecast_errors, mape


def run() -> list:
    tr = vast_like_trace(seed=6, days=8)
    errs, us = timed(lambda: forecast_errors(tr, ARIMAPredictor(tr), 5))
    T = len(tr)
    persist_price = np.mean(
        [mape(tr.prices[: T - j], tr.prices[j:]) for j in range(1, 6)]
    )
    persist_avail = np.mean(
        [mape(tr.avail[: T - j].astype(float),
              np.maximum(tr.avail[j:], 1).astype(float)) for j in range(1, 6)]
    )
    return [
        ("fig3_arima_price_mape_h1", us, errs["price"][0]),
        ("fig3_arima_price_mape_h5", us, errs["price"][-1]),
        ("fig3_arima_avail_mape_h1", us, errs["avail"][0]),
        ("fig3_arima_vs_persist_price", us, np.mean(errs["price"]) / persist_price),
        ("fig3_arima_vs_persist_avail", us, np.mean(errs["avail"]) / persist_avail),
    ]
