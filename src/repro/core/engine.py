"""Device-resident online selection engine: the paper's Algorithm 2 loop
(simulate every pool policy on the incoming job -> normalize utilities ->
EG update) end to end, with the (K, M) utility matrix never round-tripping
through host numpy.

Before this module the selection stage was the last fully host-bound part
of the pipeline: Fig. 9/10 built ``NoisyPredictor`` matrices one job at a
time, called ``normalize_utility`` in a per-job loop and ran
``selector.update`` as a numpy loop over 1000 jobs — while the simulator
underneath was jitted, kind-partitioned and 2-D sharded. The engine chains

  prep      batched trace-window gather (market.gather_windows) + ONE
            vectorized forecast stack (predictor.noisy_matrix_batch) —
            host numpy, but array code instead of K python constructions
  simulate  fast_sim.simulate_pool_jobs[_sharded] (jobs x lanes over the
            pool mesh)
  select    job.normalize_utility_batch + selector.run_eg_scan, fused into
            one jitted call — the (K, M) matrix stays a device array from
            the simulator's output to the selector's weight trajectories

and streams the job axis in chunks (``job_chunk``) when K is too large for
one resident (K, M, ...) simulation — the EG scan's state threads through
the chunks, so chunked and unchunked runs agree (the scan trajectories
bitwise, the mean-utility accumulator to f32 tolerance;
tests/test_selection_engine.py pins both).

Benchmarks: benchmarks/selection_e2e.py records the prep/simulate/select
split and pins the engine against the pre-engine host-loop pipeline
(``SEL_E2E_JOBS`` knob, rows in BENCH_pool_sim.json).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ThroughputConfig
from repro.core import fast_sim, selector
from repro.core.job import normalize_utility_batch
from repro.core.market import gather_windows, require_finite
from repro.core.predictor import (
    noisy_matrix_batch,
    noisy_matrix_batch_jax,
    regional_noisy_matrix_jax,
)


def prepare_noisy_inputs(trace, t0s, deadline: int, kind: str, level,
                         seeds, horizon: Optional[int] = None,
                         avail_max: int = 16, prep_backend: str = "numpy"):
    """Batched Fig. 9-style prep: gather the K job windows in one indexing
    pass and emit the whole noisy forecast stack in one vectorized call.
    Returns ``(prices (K, d) f32, avail (K, d) i64, preds (K, d, W1MAX, 2)
    f32)`` ready for ``simulate_pool_jobs[_sharded]``. Row k equals the
    per-job ``NoisyPredictor(trace.window(t0s[k], d+1), ..., seed=seeds[k])``
    construction it replaces. ``level`` may be a scalar or a per-row (K,)
    array (``noisy_matrix_batch``'s contract) — the scenario grid passes
    per-regime noise levels through one call this way.

    ``prep_backend="jax"`` swaps the forecast construction for the jitted
    batched-PRNG ``noisy_matrix_batch_jax`` — ``preds`` comes back as a
    device array (born where the simulator consumes it; no host round-trip
    and no per-seed generator loop). The draws use JAX's counter-based
    PRNG, so the stacks are distribution- but not bitwise-equal to the
    numpy oracle (winner/regret parity pinned in
    tests/test_region_engine.py)."""
    horizon = fast_sim.W1MAX - 1 if horizon is None else horizon
    pw, aw = gather_windows(trace, t0s, deadline + 1)
    if prep_backend == "jax":
        preds = noisy_matrix_batch_jax(pw, aw, kind, level, seeds, horizon,
                                       avail_max)[:, :deadline]
    else:
        preds = noisy_matrix_batch(pw, aw, kind, level, seeds, horizon,
                                   avail_max)[:, :deadline]
        require_finite("forecast stack", preds)
        preds = preds.astype(np.float32)
    return (pw[:, :deadline].astype(np.float32),
            aw[:, :deadline].astype(np.int64),
            preds)


def prepare_noisy_inputs_regions(market, t0s, deadline: int, kind: str,
                                 level, seeds,
                                 horizon: Optional[int] = None,
                                 avail_max: int = 16,
                                 prep_backend: str = "numpy"):
    """Regional :func:`prepare_noisy_inputs`: gather every (job, region)
    market window of a :class:`RegionalMarket` and emit the full
    (K, R, d, W1MAX, 2) forecast stack in ONE batched pass over the
    flattened (K*R,) row axis. Returns ``(prices (K, R, d) f32, avail
    (K, R, d) i64, preds (K, R, d, W1MAX, 2) f32)`` ready for
    ``simulate_pool_regions[_sharded]`` / the regional
    :func:`simulate_and_select` path.

    Row (k, r) is seeded ``seeds[k] * 1009 + r`` — the same
    decorrelate-regions-by-1009 convention as ``vast_like_regions`` — so
    the numpy path is bitwise-equal to stacking per-job
    ``RegionalPredictor(market.window(t0s[k], d+1), lambda tr, r:
    NoisyPredictor(tr, kind, level, seed=seeds[k] * 1009 + r))``
    constructions (the replaced Fig. 9/10 host loop).
    ``prep_backend="jax"`` builds the stack on device via
    ``regional_noisy_matrix_jax`` (different PRNG; winner/regret parity
    pinned, as for the single-region path)."""
    horizon = fast_sim.W1MAX - 1 if horizon is None else horizon
    n_regions = market.n_regions
    pws, aws = zip(*(gather_windows(market.region(r), t0s, deadline + 1)
                     for r in range(n_regions)))
    pw = np.stack(pws, axis=1)                    # (K, R, d+1)
    aw = np.stack(aws, axis=1)
    n_jobs = pw.shape[0]
    seeds = np.asarray(seeds)
    rseeds = seeds[:, None] * np.int64(1009) + np.arange(n_regions)[None, :]
    if prep_backend == "jax":
        preds = regional_noisy_matrix_jax(
            pw, aw, kind, level, rseeds, horizon, avail_max
        )[:, :, :deadline]
    else:
        level_arr = np.asarray(level, float)
        lv = np.repeat(level_arr, n_regions) if level_arr.ndim else level_arr
        preds = noisy_matrix_batch(
            pw.reshape(n_jobs * n_regions, -1),
            aw.reshape(n_jobs * n_regions, -1),
            kind, lv, rseeds.reshape(-1), horizon, avail_max,
        ).reshape(n_jobs, n_regions, deadline + 1, horizon + 1, 2)
        preds = preds[:, :, :deadline]
        require_finite("forecast stack", preds)
        preds = preds.astype(np.float32)
    return (pw[:, :, :deadline].astype(np.float32),
            aw[:, :, :deadline].astype(np.int64),
            preds)


@functools.partial(jax.jit, static_argnames=("track_history", "collect"))
def _normalize_and_scan(jobs: fast_sim.JobArrays, u, state: selector.EGState,
                        track_history: bool, collect: bool = False):
    """The fused select stage: per-job [0,1] normalization of the (K, M)
    raw-utility matrix + the EG lax.scan, one device call."""
    un = normalize_utility_batch(jobs, u)
    return selector.run_eg_scan(state, un, track_history=track_history,
                                collect=collect)


def select_from_utilities(jobs: fast_sim.JobArrays, utilities,
                          state: selector.EGState,
                          track_history: bool = False,
                          collect: bool = False):
    """Public wrapper over the fused normalize+scan stage (the engine's
    'select' leg, also what benchmarks/selection_e2e.py times)."""
    return _normalize_and_scan(jobs, utilities, state, track_history, collect)


@dataclass
class SelectionResult:
    """Output of :func:`simulate_and_select`.

    ``state`` is the final EG selector state (pass it back in to continue
    the stream, e.g. Fig. 10's phase schedule); the trajectories are host
    numpy — (K,) scalars per job, plus the (K, M) post-update weight
    history when requested."""
    state: selector.EGState
    mean_utility: np.ndarray              # (M,) raw mean utility per policy
    max_weight: np.ndarray                # (K,) leader weight after each job
    regret: np.ndarray                    # (K,) cumulative regret after each job
    n_jobs: int
    weight_history: Optional[np.ndarray] = None   # (K, M), track_history only
    utilities: Optional[np.ndarray] = None        # (K, M), return_utilities only
    entropy: Optional[np.ndarray] = None          # (K,), collect only
    top_policy: Optional[np.ndarray] = None       # (K,) i32, collect only
    sim_out: Optional[dict] = None                # full sim dict, collect only

    def best_policy(self) -> int:
        return selector.best_policy(self.state)

    def iters_to_half(self) -> int:
        return selector.iters_to_half(self.max_weight)

    def regret_ratio(self) -> float:
        """Final regret over the Theorem 2 bound sqrt(2 K ln M)."""
        m = int(np.shape(self.state.weights)[0])
        return selector.regret(self.state) / selector.regret_bound(
            m, int(self.state.k)
        )

    def admission_rows(self, pool_arrays: dict, n: int, rng=None,
                       greedy: bool = False):
        """Per-job policy rows for fleet admission, drawn from the final
        EG weights — the select -> admit loop: ``core.fleet`` consumes the
        returned rows as each arriving job's policy. Returns ``(rows,
        idx)`` like :func:`fleet.policy_rows_from_weights`."""
        from repro.core import fleet  # deferred: fleet must not import engine

        return fleet.policy_rows_from_weights(
            pool_arrays, np.asarray(self.state.weights), n,
            rng=rng, greedy=greedy,
        )


def simulate_and_select(
    pool_arrays: dict,
    jobs: fast_sim.JobArrays,
    tput: ThroughputConfig,
    prices, avail, preds,
    *,
    backend: str = "xla",
    sharded: bool = True,
    mesh=None,
    eta: Optional[float] = None,
    state: Optional[selector.EGState] = None,
    job_chunk: int = 0,
    track_history: bool = False,
    return_utilities: bool = False,
    collect: bool = False,
    fallback=None,
    delta_mig: Optional[int] = None,
    p_od=None,
    prep=None,
) -> SelectionResult:
    """Run the whole online-selection workload in one call: sharded pool
    simulation of every (job, policy) cell, batched utility normalization,
    and the EG scan — Fig. 9's four-regime sweep is one call per regime.

    ``jobs`` are stacked (K,) JobArrays (benchmarks.common.job_stream_arrays
    or fast_sim.stack_jobs); ``prices``/``avail`` are (K, d) and ``preds``
    (K, d, W1MAX, 2) (see :func:`prepare_noisy_inputs`). ``sharded`` lays
    the (jobs x lanes) grid over ``mesh`` (default pool mesh; bitwise
    fallback to the single-device path on one device). ``state`` continues
    an earlier stream (defaults to a fresh uniform selector with Thm. 2's
    eta for K jobs); ``job_chunk`` > 0 streams the job axis in chunks of
    that size so K >> device memory works — equal-size chunks reuse the
    jitted partition runners' compilation cache.

    ``collect=True`` turns on the flight recorder end to end: the
    simulator emits its per-slot ``tel_*`` series (kept whole in
    ``sim_out``, chunk-concatenated along the job axis), and the EG scan
    adds per-job weight ``entropy`` and the ``top_policy`` leader trace.
    The flag is static and only ADDS scan outputs, so ``collect=False``
    runs the identical compiled program (pinned in
    tests/test_telemetry.py).

    ``fallback`` takes a ``repro.chaos.FallbackConfig`` to arm the
    prediction-failure monitor in the AHAP lanes (see
    ``repro.chaos.fallback``); ``None`` — the default — is the same
    static-flag discipline and compiles the identical shipped program
    (pinned in tests/test_chaos.py).

    **Regional mode** — pass ``delta_mig`` (the market's checkpoint-
    transfer cost) to select among region-aware lanes instead: the inputs
    become (K, R, d) ``prices``/``avail`` and (K, R, d, W1MAX, 2) ``preds``
    (:func:`prepare_noisy_inputs_regions`), the simulate leg becomes
    ``simulate_pool_regions[_sharded]``, and the (K, M) utility matrix,
    region paths and migration counts stay device-resident between the
    sim, normalize and EG stages exactly as in the single-region path.
    ``p_od`` forwards the market's optional per-region on-demand
    multipliers. With R == 1 (and ``p_od=None``) the result is
    BITWISE-identical to the single-region engine on the squeezed inputs
    (pinned in tests/test_region_engine.py) — the per-cell programs agree
    bitwise and the select leg is shared code.

    ``prep`` optionally streams input construction: a callable
    ``prep(lo, hi) -> (prices, avail, preds)`` producing each chunk's
    inputs on demand (e.g. a :func:`prepare_noisy_inputs_regions` closure
    over the job windows), in which case the array arguments may be
    ``None``. The chunk loop DOUBLE-BUFFERS: chunk k's simulate/select
    work is dispatched asynchronously, then chunk k+1's prep runs on the
    host while the device chews — the prep leg hides behind the simulate
    leg instead of serializing with it (benchmarks/region_e2e.py measures
    the split via StageTimer). ``prep=None`` slices the passed arrays,
    which is the same values in the same order — results are unchanged."""
    n_jobs = int(np.shape(jobs.workload)[0])
    n_pol = int(np.asarray(pool_arrays["kind"]).shape[0])
    if state is None:
        state = selector.eg_init(n_pol, n_jobs, eta=eta)
    chunk = int(job_chunk) if job_chunk else n_jobs
    if chunk < 1:
        raise ValueError(f"job_chunk must be >= 1, got {job_chunk}")
    regional = delta_mig is not None
    if prep is None and preds is None:
        raise ValueError("pass (prices, avail, preds) arrays or prep=")

    def _stage(lo, hi):
        if prep is not None:
            p, a, m = prep(lo, hi)
        else:
            p, a, m = prices[lo:hi], avail[lo:hi], preds[lo:hi]
        # jnp.asarray starts the host->device transfer right away, so a
        # staged chunk is already in flight when its sim dispatches
        return jnp.asarray(p), jnp.asarray(a), jnp.asarray(m)

    u_sum = jnp.zeros((n_pol,), jnp.float32)
    max_w, regrets, hist, raw = [], [], [], []
    ent, top, sim_chunks = [], [], []
    spans = [(lo, min(lo + chunk, n_jobs))
             for lo in range(0, n_jobs, chunk)]
    staged = _stage(*spans[0])
    for i, (lo, hi) in enumerate(spans):
        pr_c, av_c, pm_c = staged
        jb = fast_sim.slice_jobs(jobs, lo, hi)
        if regional:
            if sharded:
                out = fast_sim.simulate_pool_regions_sharded(
                    pool_arrays, jb, tput, pr_c, av_c, pm_c,
                    backend=backend, delta_mig=delta_mig, mesh=mesh,
                    collect=collect, fallback=fallback, p_od=p_od,
                )
            else:
                out = fast_sim.simulate_pool_regions(
                    pool_arrays, jb, tput, pr_c, av_c, pm_c,
                    backend=backend, delta_mig=delta_mig, collect=collect,
                    fallback=fallback, p_od=p_od,
                )
        elif sharded:
            out = fast_sim.simulate_pool_jobs_sharded(
                pool_arrays, jb, tput, pr_c, av_c, pm_c, backend=backend,
                mesh=mesh, collect=collect, fallback=fallback,
            )
        else:
            out = fast_sim.simulate_pool_jobs(
                pool_arrays, jb, tput, pr_c, av_c, pm_c, backend=backend,
                collect=collect, fallback=fallback,
            )
        u = out["utility"]                       # (k, M), device-resident
        u_sum = u_sum + jnp.sum(u, axis=0)
        state, traj = _normalize_and_scan(jb, u, state, track_history,
                                          collect)
        # everything above is async-dispatched device work; prep the NEXT
        # chunk now so host prep overlaps the in-flight simulation
        if i + 1 < len(spans):
            staged = _stage(*spans[i + 1])
        max_w.append(traj["max_weight"])
        regrets.append(traj["regret"])
        if track_history:
            hist.append(traj["weights"])
        if return_utilities:
            raw.append(u)
        if collect:
            ent.append(traj["entropy"])
            top.append(traj["top_policy"])
            sim_chunks.append(out)

    cat = (lambda parts: np.asarray(parts[0]) if len(parts) == 1
           else np.concatenate([np.asarray(p) for p in parts]))
    sim_out = None
    if collect:
        sim_out = {k: cat([c[k] for c in sim_chunks])
                   for k in sim_chunks[0]}
    return SelectionResult(
        state=state,
        mean_utility=np.asarray(u_sum) / n_jobs,
        max_weight=cat(max_w),
        regret=cat(regrets),
        n_jobs=n_jobs,
        weight_history=cat(hist) if track_history else None,
        utilities=cat(raw) if return_utilities else None,
        entropy=cat(ent) if collect else None,
        top_policy=cat(top) if collect else None,
        sim_out=sim_out,
    )
