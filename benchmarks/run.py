"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig5,fig9] \
        [--json out.json] [--profile-dir traces/]

``--json <path>`` additionally captures every module's rows as a
machine-readable payload ``{schema_version, backend, devices, elapsed_s,
provenance, rows: [{module, name, us_per_call, derived}, ...]}`` — the
mechanism behind the repo's ``BENCH_*.json`` perf-trajectory files and the
opt-in CI regression guard (tests/test_bench_regression.py reads the
pool_sim speedup rows from it). ``provenance`` pins what produced the
numbers: git sha, jax/python versions, platform, device count, UTC
timestamp.

``--profile-dir <dir>`` wraps the whole module loop in a
``jax.profiler.trace`` capture (viewable in TensorBoard / Perfetto) —
opt-in because tracing adds overhead and trace files are large.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

# bump when the --json payload layout changes shape
JSON_SCHEMA_VERSION = 2


def provenance() -> dict:
    """Best-effort environment fingerprint for the --json payload. Every
    field degrades to None rather than failing the benchmark run."""
    import platform as _platform
    import subprocess

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10,
        ).stdout.strip() or None
    except Exception:
        sha = None
    try:
        import jax
        jax_version = jax.__version__
        device_count = jax.device_count()
    except Exception:
        jax_version = device_count = None
    return {
        "git_sha": sha,
        "jax_version": jax_version,
        "python_version": _platform.python_version(),
        "platform": _platform.platform(),
        "device_count": device_count,
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }

MODULES = [
    "fig1_throughput",
    "fig2_market",
    "fig3_arima",
    "fig4_toy",
    "fig5_deadline",
    "fig6_reconfig",
    "fig7_availability",
    "fig8_price",
    "fig9_convergence",
    "fig10_adaptation",
    "theorem1",
    "beyond_robust",
    "predictor_value",
    "theorem2",
    "kernels_bench",
    "pool_sim_bench",
    "region_sim",
    "region_e2e",
    "selection_e2e",
    "fleet_sim",
    "scenario_grid",
    "chaos_sweep",
]


def select_modules(only: str):
    """Resolve a comma-separated ``--only`` prefix list against MODULES.
    Returns ``(selected, unknown)`` — ``unknown`` holds every prefix that
    matched nothing, so a typo (``--only pool_sim,felt_sim``) is an error
    callers can surface instead of a silently skipped benchmark."""
    sel = [s for s in only.split(",") if s]
    selected = [m for m in MODULES
                if not sel or any(m.startswith(s) for s in sel)]
    unknown = [s for s in sel if not any(m.startswith(s) for m in MODULES)]
    return selected, unknown


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated module prefixes")
    ap.add_argument("--json", default="",
                    help="also write all rows to this path as JSON")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the run here")
    args = ap.parse_args()
    selected, unknown = select_modules(args.only)
    if unknown:
        raise SystemExit(
            f"unknown benchmark name(s): {', '.join(unknown)}\n"
            f"known modules: {', '.join(MODULES)}"
        )

    profile_ctx = None
    if args.profile_dir:
        import jax

        profile_ctx = jax.profiler.trace(args.profile_dir)
        profile_ctx.__enter__()
        print(f"# profiling to {args.profile_dir}", flush=True)

    print("name,us_per_call,derived")
    failures = 0
    json_rows = []
    t_start = time.time()
    for mod_name in selected:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run()
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived:.6g}")
                json_rows.append({
                    "module": mod_name, "name": name,
                    "us_per_call": float(us), "derived": float(derived),
                })
        except Exception as e:
            failures += 1
            print(f"{mod_name},0.0,nan  # FAILED", flush=True)
            json_rows.append({
                "module": mod_name, "name": f"{mod_name}__FAILED",
                "us_per_call": 0.0, "derived": None,  # null: strict-JSON safe
                "error": f"{type(e).__name__}: {e}",
            })
            traceback.print_exc(file=sys.stderr)
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", flush=True)
    if profile_ctx is not None:
        profile_ctx.__exit__(None, None, None)
    if args.json:
        import jax  # benchmark modules have long since initialized it

        payload = {
            "schema_version": JSON_SCHEMA_VERSION,
            "backend": jax.default_backend(),
            "devices": jax.device_count(),
            "elapsed_s": time.time() - t_start,
            "provenance": provenance(),
            "rows": json_rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(json_rows)} rows to {args.json}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
