"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the real kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.ref import flash_attention_ref, lora_matmul_ref, ssd_scan_ref
from repro.kernels.ssd_scan import ssd_scan


# ---------------------------------------------------------------------------
# lora_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,r", [(128, 128, 128, 16), (256, 384, 128, 8), (128, 256, 256, 64)])
def test_lora_matmul_sweep(rng, m, k, n, r, dtype):
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (m, k), dtype)
    w = jax.random.normal(ks[1], (k, n), dtype) * 0.05
    a = jax.random.normal(ks[2], (k, r), dtype) * 0.05
    b = jax.random.normal(ks[3], (r, n), dtype) * 0.05
    y = lora_matmul(x, w, a, b, 2.0, interpret=True)
    yr = lora_matmul_ref(x, w, a, b, 2.0)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=tol, rtol=tol
    )


@settings(max_examples=8, deadline=None)
@given(
    mi=st.integers(1, 3), ki=st.integers(1, 3), ni=st.integers(1, 3),
    r=st.sampled_from([8, 16, 32]), scale=st.floats(0.1, 4.0),
)
def test_lora_matmul_property(mi, ki, ni, r, scale):
    m, k, n = mi * 128, ki * 128, ni * 128
    keys = jax.random.split(jax.random.PRNGKey(m * 7 + k * 3 + n), 4)
    x = jax.random.normal(keys[0], (m, k))
    w = jax.random.normal(keys[1], (k, n)) * 0.05
    a = jax.random.normal(keys[2], (k, r)) * 0.05
    b = jax.random.normal(keys[3], (r, n)) * 0.05
    y = lora_matmul(x, w, a, b, scale, interpret=True)
    yr = lora_matmul_ref(x, w, a, b, scale)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4, rtol=2e-4)


def test_lora_matmul_zero_b_equals_base(rng):
    ks = jax.random.split(rng, 3)
    x = jax.random.normal(ks[0], (128, 128))
    w = jax.random.normal(ks[1], (128, 128)) * 0.05
    a = jax.random.normal(ks[2], (128, 16)) * 0.05
    b = jnp.zeros((16, 128))
    y = lora_matmul(x, w, a, b, 2.0, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 100)])
@pytest.mark.parametrize("bh,sq,sk,d", [(4, 256, 256, 64), (2, 128, 512, 128)])
def test_flash_attention_sweep(rng, bh, sq, sk, d, causal, window):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (bh, sq, d))
    k = jax.random.normal(ks[1], (bh, sk, d))
    v = jax.random.normal(ks[2], (bh, sk, d))
    y = flash_attention(q, k, v, causal=causal, window=window, interpret=True)
    yr = flash_attention_ref(
        q[:, None].swapaxes(0, 1), k[:, None].swapaxes(0, 1), v[:, None].swapaxes(0, 1),
        causal=causal, window=window,
    )[0]
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16(rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 128, 64), jnp.bfloat16)
    y = flash_attention(q, k, v, interpret=True)
    yr = flash_attention_ref(q[None].swapaxes(0, 1), k[None].swapaxes(0, 1),
                             v[None].swapaxes(0, 1))[:, 0]
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=3e-2, rtol=3e-2
    )


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,s,p,n,cs", [(4, 256, 64, 32, 64), (2, 256, 32, 128, 128)])
def test_ssd_scan_sweep(rng, bh, s, p, n, cs):
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (bh, s, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (bh,))) * 0.5
    B = jax.random.normal(ks[3], (bh, s, n)) * 0.3
    C = jax.random.normal(ks[4], (bh, s, n)) * 0.3
    y, hf = ssd_scan(x, dt, A, B, C, chunk=cs, interpret=True)
    yr, hr = ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), atol=3e-4, rtol=3e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), cs=st.sampled_from([32, 64, 128]))
def test_ssd_scan_property(seed, cs):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    bh, s, p, n = 2, 128, 32, 16
    x = jax.random.normal(ks[0], (bh, s, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (bh,))) * 0.5
    B = jax.random.normal(ks[3], (bh, s, n)) * 0.3
    C = jax.random.normal(ks[4], (bh, s, n)) * 0.3
    y, hf = ssd_scan(x, dt, A, B, C, chunk=cs, interpret=True)
    yr, hr = ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4, rtol=5e-4)


# ---------------------------------------------------------------------------
# ops wrappers vs model XLA paths (kernel == oracle == model triangle)
# ---------------------------------------------------------------------------

def test_ops_attention_gqa_matches_model_path(rng):
    from repro.models.attention import _plain_attn

    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    o1 = ops.attention(q, k, v, causal=True)
    o2 = _plain_attn(q, k, v, jnp.arange(128), jnp.arange(128), True, None)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5, rtol=2e-5)


def test_ops_ssd_matches_model_path(rng):
    from repro.models.ssm import ssd_chunked

    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (2, 128, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 128, 4))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (4,))) * 0.5
    B = jax.random.normal(ks[3], (2, 128, 2, 16)) * 0.3
    C = jax.random.normal(ks[4], (2, 128, 2, 16)) * 0.3
    y1, h1 = ops.ssd(x, dt, A, B, C, chunk=64)
    y2, h2 = ssd_chunked(x, dt, A, B, C, 64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(
        np.asarray(h1.transpose(0, 1, 3, 2)), np.asarray(h2), atol=3e-4, rtol=3e-4
    )
