"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness-scale
timings; the real perf numbers are the TPU dry-run rooflines)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.ref import flash_attention_ref, lora_matmul_ref, ssd_scan_ref
from repro.kernels.ssd_scan import ssd_scan


def run() -> list:
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 8)
    rows = []

    m = k = n = 256
    x = jax.random.normal(ks[0], (m, k))
    w = jax.random.normal(ks[1], (k, n)) * 0.05
    a = jax.random.normal(ks[2], (k, 16)) * 0.05
    b = jax.random.normal(ks[3], (16, n)) * 0.05
    f = jax.jit(lambda *t: lora_matmul(*t, 2.0, interpret=True))
    jax.block_until_ready(f(x, w, a, b))
    _, us = timed(lambda: jax.block_until_ready(f(x, w, a, b)), repeat=3)
    fr = jax.jit(lambda *t: lora_matmul_ref(*t, 2.0))
    jax.block_until_ready(fr(x, w, a, b))
    _, us_r = timed(lambda: jax.block_until_ready(fr(x, w, a, b)), repeat=3)
    rows += [("kernel_lora_matmul_256_interp", us, 2.0 * m * k * n / (us / 1e6)),
             ("kernel_lora_matmul_256_xla_ref", us_r, us / max(us_r, 1e-9))]

    q = jax.random.normal(ks[4], (4, 256, 64))
    kk = jax.random.normal(ks[5], (4, 256, 64))
    v = jax.random.normal(ks[6], (4, 256, 64))
    f = jax.jit(lambda *t: flash_attention(*t, interpret=True))
    jax.block_until_ready(f(q, kk, v))
    _, us = timed(lambda: jax.block_until_ready(f(q, kk, v)), repeat=3)
    rows.append(("kernel_flash_attention_256_interp", us, 0.0))

    xx = jax.random.normal(ks[7], (4, 256, 64))
    dt = jax.nn.softplus(jax.random.normal(ks[0], (4, 256))) * 0.5
    A = -jnp.ones((4,)) * 0.5
    B = jax.random.normal(ks[1], (4, 256, 32)) * 0.3
    C = jax.random.normal(ks[2], (4, 256, 32)) * 0.3
    f = jax.jit(lambda *t: ssd_scan(*t, chunk=64, interpret=True))
    jax.block_until_ready(f(xx, dt, A, B, C))
    _, us = timed(lambda: jax.block_until_ready(f(xx, dt, A, B, C)), repeat=3)
    rows.append(("kernel_ssd_scan_256_interp", us, 0.0))
    return rows
