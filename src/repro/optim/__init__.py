from repro.optim import adamw
from repro.optim.schedule import constant, warmup_cosine
