"""Mixture-of-Experts layer (Mixtral-style: top-k softmax router, SwiGLU experts).

Dispatch is *sort-based with capacity buckets* (MegaBlocks/MaxText style), not
one-hot-einsum (GShard dispatch tensors): tokens are argsorted by expert id
and scattered into an (E, C, d) buffer, each expert runs one dense GEMM, and
outputs are combined back with the router weights. Compiled FLOPs are
``capacity_factor x active`` rather than the ~E/k x blow-up of dense routing.

Routing is GROUPED per sequence (vmap over the batch dim): groups align with
the batch sharding, so dispatch stays local to a data shard and the compiler
never materializes a global token permutation — routing a global flat token
list produced 222 GiB/device temps in the dry-run (EXPERIMENTS.md §Perf).

Overflowing tokens (beyond expert capacity) are dropped for that expert —
standard capacity semantics; the Switch-style aux loss discourages overflow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import act_fn, normal_param
from repro.sharding import shard


def init_moe(key, cfg, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": normal_param(ks[0], (d, e), ("fsdp", None), jnp.float32, stddev=0.02),
        "w1": normal_param(ks[1], (e, d, f), ("experts", "fsdp", "tensor"), dtype),
        "w3": normal_param(ks[2], (e, d, f), ("experts", "fsdp", "tensor"), dtype),
        "w2": normal_param(ks[3], (e, f, d), ("experts", "tensor", "fsdp"), dtype),
    }


def expert_capacity(cfg, group_tokens: int) -> int:
    m = cfg.moe
    cap = int(group_tokens * m.top_k * m.capacity_factor / m.num_experts)
    cap = max(m.top_k, cap)
    if cap >= 128:  # MXU-align large buckets
        cap = (cap + 127) // 128 * 128
    return cap


def route(cfg, router_w, x_flat):
    """x_flat:(T,d) -> (idx:(T,k), weights:(T,k), aux scalar)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), router_w)
    gates = jax.nn.softmax(logits, axis=-1)  # (T, E)
    weights, idx = jax.lax.top_k(gates, m.top_k)  # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss (per group; averaged by caller)
    me = gates.mean(axis=0)
    ce = jax.nn.one_hot(idx[:, 0], m.num_experts).mean(axis=0)
    aux = m.num_experts * jnp.sum(me * ce) * m.aux_loss_coef
    return idx, weights.astype(x_flat.dtype), aux


def _dispatch_one(cfg, x, idx, wts, cap):
    """One group. x:(T,d), idx/wts:(T,k) -> (buf:(E,C,d), combine info)."""
    t, d = x.shape
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    flat_expert = idx.reshape(-1)  # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    counts = jnp.bincount(flat_expert, length=e)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_expert].astype(jnp.int32)
    keep = pos < cap
    dest = jnp.where(keep, sorted_expert * cap + pos, e * cap)
    src_tok = flat_token[order]
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(x[src_tok], mode="drop")
    return buf[: e * cap].reshape(e, cap, d), (order, src_tok, dest, keep)


def _combine_one(cfg, out_ecd, info, wts, t):
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    order, src_tok, dest, keep = info
    cap = out_ecd.shape[1]
    d = out_ecd.shape[2]
    flat = out_ecd.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], flat[jnp.clip(dest, 0, e * cap - 1)], 0.0)
    w_sorted = wts.reshape(-1)[order]
    y = jnp.zeros((t, d), jnp.float32)
    y = y.at[src_tok].add(gathered.astype(jnp.float32) * w_sorted[:, None].astype(jnp.float32))
    return y


def apply_moe(cfg, p, x):
    """x:(B,S,d) -> (y:(B,S,d), aux_loss). Routing grouped per batch row."""
    b, s, d = x.shape
    cap = expert_capacity(cfg, s)
    act = act_fn(cfg.mlp_act)

    def one_group(xs):
        idx, wts, aux = route(cfg, p["router"], xs)
        buf, info = _dispatch_one(cfg, xs, idx, wts, cap)  # (E,C,d)
        return buf, info, wts, aux

    buf, info, wts, aux = jax.vmap(one_group)(x)
    # keep the dispatch buffer batch-sharded: scatter output sharding is
    # undecidable for XLA and silently replicates otherwise (dry-run showed
    # 17.9 GiB/layer all-reduces; EXPERIMENTS.md §Perf)
    buf = shard(buf, "batch", "experts", None, "embed")
    h = act(jnp.einsum("becd,edf->becf", buf, p["w1"]))
    if cfg.mlp_act == "silu":
        h = h * jnp.einsum("becd,edf->becf", buf, p["w3"])
    h = shard(h, "batch", "experts", None, "tensor")
    out = jnp.einsum("becf,efd->becd", h, p["w2"])
    out = shard(out, "batch", "experts", None, "embed")
    y = jax.vmap(lambda o, i, w: _combine_one(cfg, o, i, w, s))(out, info, wts)
    y = shard(y.astype(x.dtype), "batch", "seq", "embed")
    return y, aux.mean()
