"""Multi-region spot markets (BEYOND-PAPER, SkyNomad arXiv:2601.06520).

The paper's market model (Fig. 2) is single-region. Real spot markets span
regions whose (price, availability) processes are phase-shifted copies of
the same diurnal demand cycle — when it is night (scarce, pricey spot) in
one region it is midday (plentiful, cheap spot) eight time zones away.
SkyNomad shows that for deadline-bound batch jobs this makes cross-region
migration the dominant cost lever, PROVIDED the mover pays the checkpoint
transfer: here ``delta_mig`` slots during which the job holds zero
instances.

This module provides:

  RegionalMarket       stacked (R, T) price/availability traces + the
                       migration cost, with per-region ``Trace`` views
  vast_like_regions    R phase-shifted ``vast_like_trace`` regions with
                       per-region price levels/volatility
  simulate_regional    the python reference simulator: region selection
                       (policies.RegionSelector) layered over the paper's
                       slot execution — the oracle the vectorized
                       fast_sim.simulate_pool_regions lanes are pinned to

The JAX hot path lives in fast_sim.simulate_pool_regions; the pool lanes
that pair a scheduling policy with a region strategy come from
policy_pool.region_pool.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import JobConfig, ThroughputConfig
from repro.core.job import value_fn
from repro.core.market import Trace, TraceStats, vast_like_trace
from repro.core.policies import BasePolicy, Obs, RegionSelector
from repro.core.simulator import SimResult, exec_slot, termination_config


@dataclass
class RegionalMarket:
    prices: np.ndarray          # (R, T) spot price per region
    avail: np.ndarray           # (R, T) int, available spot instances
    slot_seconds: float = 1800.0
    slots_per_day: int = 48
    delta_mig: int = 1          # checkpoint-transfer cost: slots lost per move
    region_names: Sequence[str] = ()
    meta: dict = field(default_factory=dict)
    # per-region on-demand price MULTIPLIERS of a job's flat
    # on_demand_price (regions price reserved capacity differently too).
    # None (the default) or a scalar keeps the flat-od behavior — a scalar
    # broadcasts, and 1.0 multipliers are IEEE-exact no-ops, so old
    # behavior is preserved bitwise; an (R,) vector makes the od leg of
    # billing (and the AHAP thresholds/window solves) region-dependent.
    p_od: Optional[np.ndarray] = None

    def __post_init__(self):
        assert self.prices.shape == self.avail.shape, (
            self.prices.shape, self.avail.shape)
        assert self.prices.ndim == 2, self.prices.shape
        if not self.region_names:
            self.region_names = tuple(
                f"r{i}" for i in range(self.prices.shape[0]))
        if self.p_od is not None:
            self.p_od = np.broadcast_to(
                np.asarray(self.p_od, np.float64).reshape(-1),
                (self.prices.shape[0],),
            )

    def __len__(self):  # number of slots, matching Trace
        return self.prices.shape[1]

    @property
    def n_regions(self) -> int:
        return self.prices.shape[0]

    def region(self, r: int) -> Trace:
        """Single-region Trace view (shares the underlying arrays)."""
        return Trace(
            self.prices[r], self.avail[r], self.slot_seconds,
            self.slots_per_day,
            dict(self.meta, region=self.region_names[r]),
        )

    def window(self, t0: int, length: int) -> "RegionalMarket":
        if t0 < 0 or length < 0 or t0 + length > len(self):
            raise ValueError(
                f"window [{t0}, {t0 + length}) out of bounds for market of "
                f"length {len(self)}"
            )
        return RegionalMarket(
            self.prices[:, t0 : t0 + length], self.avail[:, t0 : t0 + length],
            self.slot_seconds, self.slots_per_day, self.delta_mig,
            self.region_names, dict(self.meta, t0=t0), p_od=self.p_od,
        )

    def stats(self) -> List[TraceStats]:
        return [TraceStats.of(self.region(r)) for r in range(self.n_regions)]

    @staticmethod
    def from_traces(traces: Sequence[Trace], delta_mig: int = 1,
                    region_names: Sequence[str] = (),
                    p_od=None) -> "RegionalMarket":
        t0 = traces[0]
        for i, t in enumerate(traces[1:], 1):  # no silent misalignment:
            if len(t) != len(t0):              # regions share one time base
                raise ValueError(
                    f"trace {i} has {len(t)} slots, trace 0 has {len(t0)}"
                )
            if (t.slot_seconds, t.slots_per_day) != (
                    t0.slot_seconds, t0.slots_per_day):
                raise ValueError(
                    f"trace {i} slot base ({t.slot_seconds}s, "
                    f"{t.slots_per_day}/day) differs from trace 0"
                )
        return RegionalMarket(
            prices=np.stack([np.asarray(t.prices, np.float64)
                             for t in traces]),
            avail=np.stack([np.asarray(t.avail, np.int64)
                            for t in traces]),
            slot_seconds=t0.slot_seconds,
            slots_per_day=t0.slots_per_day,
            delta_mig=delta_mig,
            region_names=tuple(region_names),
            meta={"kind": "from_traces"},
            p_od=p_od,
        )


def vast_like_regions(
    n_regions: int,
    seed: int = 0,
    days: float = 10.0,
    slots_per_day: int = 48,
    *,
    phase_hours: Optional[Sequence[float]] = None,
    mean_prices: Optional[Sequence[float]] = None,
    price_sigmas: Optional[Sequence[float]] = None,
    avail_means: Optional[Sequence[float]] = None,
    delta_mig: int = 1,
    **trace_kwargs,
) -> RegionalMarket:
    """R Vast.ai-like regions sharing one diurnal demand cycle, phase-shifted
    per region's time zone.

    Defaults: phases spread evenly over 24h (region r is ``r * 24/R`` hours
    behind region 0), identical price levels/volatility/availability unless
    overridden per region. Each region gets an independent noise seed;
    remaining ``trace_kwargs`` pass through to ``vast_like_trace``.
    """
    if phase_hours is None:
        phase_hours = [24.0 * r / n_regions for r in range(n_regions)]
    assert len(phase_hours) == n_regions, (phase_hours, n_regions)
    per_region = lambda v, r, default: (
        default if v is None else v[r] if not np.isscalar(v) else v)
    traces = []
    for r in range(n_regions):
        kw = dict(trace_kwargs)
        if mean_prices is not None:
            kw["mean_price"] = per_region(mean_prices, r, None)
        if price_sigmas is not None:
            kw["price_sigma"] = per_region(price_sigmas, r, None)
        if avail_means is not None:
            kw["avail_mean"] = per_region(avail_means, r, None)
        traces.append(vast_like_trace(
            seed=seed * 1009 + r,
            days=days,
            slots_per_day=slots_per_day,
            season_phase_slots=phase_hours[r] * slots_per_day / 24.0,
            **kw,
        ))
    market = RegionalMarket.from_traces(
        traces, delta_mig=delta_mig,
        region_names=[f"r{r}(+{phase_hours[r]:g}h)" for r in range(n_regions)],
    )
    market.meta = {"kind": "vast_like_regions", "seed": seed, "days": days,
                   "phase_hours": tuple(phase_hours)}
    return market


@dataclass
class RegionalSimResult(SimResult):
    region_hist: np.ndarray = None   # (d,) region occupied each slot
    migrations: int = 0              # completed switch decisions


def simulate_regional(
    policy: BasePolicy,
    selector: RegionSelector,
    job: JobConfig,
    tput: ThroughputConfig,
    market: RegionalMarket,
    pred_matrix: Optional[np.ndarray] = None,  # (R, T, horizon+1, 2)
) -> RegionalSimResult:
    """Reference regional simulator: simulator.simulate with a region layer.

    Each slot: score regions (selector), pick/hold a region with hysteresis,
    observe the selected region's (price, avail, forecast), let the
    scheduling policy decide as usual, then — if a checkpoint transfer is in
    flight — override the allocation to zero for that slot (no progress, no
    billing). Everything else (feasibility clip, mu, whole-slot billing,
    fractional completion, termination configuration) is byte-for-byte the
    single-region reference loop, which this reduces to when R == 1 (the
    selector never leaves region 0 and no migration is ever charged).

    Input convention (same as the single-region parity pins): for exact
    agreement with the fast AHAP lanes, ``pred_matrix`` must cover the
    policy's window — pass a predictor horizon >= the largest omega (i.e.
    fast_sim.W1MAX - 1), or the edge-padded matrix from
    ``prepare_inputs_regions``. Region *scores* are horizon-robust either
    way (RegionSelector pads to RSEL_PRED_WINDOW itself); a too-short
    forecast only starves the python AHAP's plan window relative to the
    padded one the fast lanes see.

    When the market carries per-region on-demand multipliers
    (``market.p_od``), each slot runs against an *effective* job whose
    ``on_demand_price`` is scaled by the occupied region's multiplier —
    the policy's decision, the slot billing, and (via the final region)
    the termination configuration all see the regional od price. ``None``
    leaves the loop byte-for-byte as before.
    """
    d = job.deadline
    assert len(market) >= d, "market shorter than deadline"
    policy.reset(job, tput)
    selector.reset(job, market.delta_mig)
    pod = market.p_od
    eff_job = (lambda r: job) if pod is None else (
        lambda r: replace(job, on_demand_price=job.on_demand_price
                          * float(pod[r])))

    z, n_prev, cost = 0.0, 0, 0.0
    T_complete: Optional[float] = None
    ns_hist, no_hist = np.zeros(d, int), np.zeros(d, int)
    region_hist = np.zeros(d, int)
    migrations = 0
    cur = 0

    for t in range(d):
        pred_t = pred_matrix[:, t] if pred_matrix is not None else None
        sc = selector.scores(market.prices[:, t], market.avail[:, t], pred_t)
        cur, migrating, switched = selector.step(sc)
        migrations += int(switched)
        region_hist[t] = cur

        price, avail = float(market.prices[cur, t]), int(market.avail[cur, t])
        pred = pred_t[cur] if pred_t is not None else None
        job_t = eff_job(cur)
        policy.job = job_t  # policies read self.job fresh every decide
        obs = Obs(t=t, price=price, avail=avail, z_prev=z, n_prev=n_prev,
                  pred=pred)
        n_o, n_s = policy.decide(obs)
        if migrating:   # checkpoint in transit: hold nothing this slot
            n_o = n_s = 0
        # slot execution is shared with simulator.simulate — the single-
        # region loop and this one cannot drift apart
        n_o, n_s, work, dc, T_complete = exec_slot(
            job_t, tput, z, n_prev, t, n_o, n_s, price, avail
        )
        cost += dc
        ns_hist[t], no_hist[t] = n_s, n_o
        z = min(z + work, job.workload)
        n_prev = n_o + n_s
        if T_complete is not None:
            break

    if T_complete is not None:
        value = float(value_fn(job, T_complete))
    else:
        # termination configuration: N^max on-demand past the deadline,
        # billed at the final occupied region's od rate
        dt, dc = termination_config(eff_job(cur), tput, z)
        T_complete = d + dt
        cost += dc
        value = float(value_fn(job, T_complete))

    return RegionalSimResult(
        utility=value - cost,
        value=value,
        cost=cost,
        completion_time=float(T_complete),
        z_ddl=float(z),
        completed_by_deadline=T_complete <= d,
        n_total=ns_hist + no_hist,
        n_spot=ns_hist,
        n_od=no_hist,
        region_hist=region_hist,
        migrations=migrations,
    )
