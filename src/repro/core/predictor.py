"""Spot price/availability forecasting (paper Sec. II-C, Fig. 3).

Every predictor produces a *prediction matrix* P[t, j, c]: the forecast made
at slot t for slot t+j (j=0 is the observed present, always exact), with
channels c=0 price, c=1 availability. The matrix form is what the vmapped
policy simulator consumes.

Predictors:
  PerfectPredictor  — oracle (paper's 'Perfect-Predictor' strategy)
  NoisyPredictor    — the paper's four noise regimes: {magnitude-dependent,
                      fixed-magnitude} x {uniform, heavy-tail}, with error
                      growing in the prediction step j (multi-step error
                      accumulation, Definition 1)
  ARIMAPredictor    — seasonally-differenced AR(p) fit by least squares on a
                      rolling history window (the paper's ARIMA with 30-min
                      slots), forecast recursively
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.market import Trace, require_finite

NOISE_KINDS = (
    "magdep_uniform",
    "fixed_uniform",
    "magdep_heavytail",
    "fixed_heavytail",
)


def _true_future(trace: Trace, horizon: int) -> np.ndarray:
    """(T, horizon+1, 2) true values, edge-padded past the end."""
    T = len(trace)
    prices = np.concatenate([trace.prices, np.full(horizon, trace.prices[-1])])
    avail = np.concatenate([trace.avail, np.full(horizon, trace.avail[-1])])
    out = np.empty((T, horizon + 1, 2))
    for j in range(horizon + 1):
        out[:, j, 0] = prices[j : j + T]
        out[:, j, 1] = avail[j : j + T]
    return out


def true_future_batch(prices: np.ndarray, avail: np.ndarray,
                      horizon: int) -> np.ndarray:
    """Batched :func:`_true_future`: (K, T) price/avail windows ->
    (K, T, horizon+1, 2) true values, each row edge-padded past its end."""
    prices = np.asarray(prices, float)
    avail = np.asarray(avail, float)
    T = prices.shape[1]
    p = np.concatenate([prices, np.repeat(prices[:, -1:], horizon, axis=1)], 1)
    a = np.concatenate([avail, np.repeat(avail[:, -1:], horizon, axis=1)], 1)
    idx = np.arange(T)[:, None] + np.arange(horizon + 1)[None, :]
    return np.stack([p[:, idx], a[:, idx]], axis=-1)


def noisy_matrix_batch(prices: np.ndarray, avail: np.ndarray, kind: str,
                       level, seeds, horizon: int,
                       avail_max: int = 16) -> np.ndarray:
    """Batched :class:`NoisyPredictor`: the whole (K, T, horizon+1, 2)
    forecast stack in one vectorized pass over (K, T) market windows.

    Bitwise-equal to stacking
    ``NoisyPredictor(window_k, kind, level, seed=seeds[k]).matrix(horizon)``
    over k (pinned in tests/test_selection_engine.py): every arithmetic op
    is elementwise over the batch axis, and each row's noise is drawn from
    ``np.random.default_rng(seeds[k])`` exactly as the per-job constructor
    would — the per-seed draw is the one per-row op left (independent
    streams have no batch API); everything around it is vectorized, which
    is what collapses Fig. 9's per-job predictor loop into array code.

    ``level`` may be a scalar (one noise level for every row) or a (K,)
    array of per-row levels — how the scenario grid realizes its
    prediction-noise axis inside one batched call; row k then matches the
    per-job construction at ``level[k]`` (level 0 rows reduce to the
    perfect forecast)."""
    assert kind in NOISE_KINDS, kind
    prices = np.asarray(prices, float)
    avail = np.asarray(avail, float)
    require_finite("prices", prices)
    require_finite("avail", avail)
    require_finite("level", np.asarray(level, float))
    seeds = np.asarray(seeds)
    out = true_future_batch(prices, avail, horizon)
    K = out.shape[0]
    assert seeds.shape == (K,), (seeds.shape, K)
    level = np.asarray(level, float)
    if level.ndim == 0:
        scale = level * np.sqrt(np.arange(horizon + 1))          # 0 at j=0
    else:
        assert level.shape == (K,), (level.shape, K)
        scale = level[:, None] * np.sqrt(np.arange(horizon + 1))  # (K, h+1)
    ref = np.stack([
        np.broadcast_to(prices.mean(axis=1)[:, None], prices.shape),
        np.broadcast_to(avail.mean(axis=1)[:, None], avail.shape),
    ], axis=-1)  # (K, T, 2) per-row reference magnitudes
    shape = out.shape[1:]
    if kind.endswith("uniform"):
        eps = np.stack([
            np.random.default_rng(int(s)).uniform(-1, 1, shape) for s in seeds
        ])
    else:  # heavy-tail: Student-t(3), clipped for sanity
        eps = np.stack([
            np.clip(np.random.default_rng(int(s)).standard_t(3, shape), -8, 8)
            for s in seeds
        ]) / np.sqrt(3)
    if scale.ndim == 1:
        eps = eps * scale[None, None, :, None]
    else:
        eps = eps * scale[:, None, :, None]
    if kind.startswith("magdep"):
        noisy = out * (1.0 + eps)
    else:
        noisy = out + eps * ref[:, :, None, :]
    noisy[..., 0] = np.clip(noisy[..., 0], 0.01, 10.0)
    noisy[..., 1] = np.clip(np.round(noisy[..., 1]), 0, avail_max)
    noisy[:, :, 0, :] = out[:, :, 0, :]  # the present is observed
    return noisy


def _true_future_batch_jax(prices, avail, horizon: int):
    """Device twin of :func:`true_future_batch`: (K, T) jnp windows ->
    (K, T, horizon+1, 2) edge-padded true values, all on device."""
    T = prices.shape[1]
    p = jnp.concatenate(
        [prices, jnp.repeat(prices[:, -1:], horizon, axis=1)], axis=1)
    a = jnp.concatenate(
        [avail, jnp.repeat(avail[:, -1:], horizon, axis=1)], axis=1)
    idx = jnp.arange(T)[:, None] + jnp.arange(horizon + 1)[None, :]
    return jnp.stack([p[:, idx], a[:, idx]], axis=-1)


@functools.partial(jax.jit, static_argnames=("kind", "horizon", "avail_max"))
def _noisy_matrix_batch_jax(prices, avail, level, seeds, kind: str,
                            horizon: int, avail_max: int):
    out = _true_future_batch_jax(prices, avail, horizon)
    steps = jnp.sqrt(jnp.arange(horizon + 1, dtype=jnp.float32))
    scale = level * steps if level.ndim == 0 else level[:, None] * steps
    ref = jnp.stack([
        jnp.broadcast_to(jnp.mean(prices, axis=1)[:, None], prices.shape),
        jnp.broadcast_to(jnp.mean(avail, axis=1)[:, None], avail.shape),
    ], axis=-1)                                     # (K, T, 2)
    shape = out.shape[1:]

    def draw(seed):
        key = jax.random.PRNGKey(seed)
        if kind.endswith("uniform"):
            return jax.random.uniform(key, shape, jnp.float32, -1.0, 1.0)
        return (jnp.clip(jax.random.t(key, 3.0, shape, jnp.float32),
                         -8.0, 8.0)
                / np.sqrt(3.0).astype(np.float32))

    eps = jax.vmap(draw)(seeds)                     # (K, T, h+1, 2)
    eps = eps * (scale[None, None, :, None] if scale.ndim == 1
                 else scale[:, None, :, None])
    if kind.startswith("magdep"):
        noisy = out * (1.0 + eps)
    else:
        noisy = out + eps * ref[:, :, None, :]
    noisy = jnp.stack([
        jnp.clip(noisy[..., 0], 0.01, 10.0),
        jnp.clip(jnp.round(noisy[..., 1]), 0.0, float(avail_max)),
    ], axis=-1)
    return noisy.at[:, :, 0, :].set(out[:, :, 0, :])  # present is observed


def noisy_matrix_batch_jax(prices, avail, kind: str, level, seeds,
                           horizon: int, avail_max: int = 16):
    """Jitted device twin of :func:`noisy_matrix_batch`: the whole
    (K, T, horizon+1, 2) noisy forecast stack built by one batched-PRNG
    XLA program — no host loop over per-seed generator objects, and the
    result is born on device where the pool simulator consumes it
    (core.engine's ``prep_backend="jax"``).

    Same math (sqrt(j) error growth, per-row reference magnitudes, clips,
    observed-present restore) in float32, but the draws come from JAX's
    counter-based PRNG keyed per row on ``seeds`` — NOT bitwise-equal to
    the numpy Philox streams. The numpy path stays the parity oracle:
    tests pin that both backends agree on the selected winner and keep
    EG regret within the Theorem 2 bound (tests/test_region_engine.py).
    """
    assert kind in NOISE_KINDS, kind
    prices = jnp.asarray(prices, jnp.float32)
    avail = jnp.asarray(avail, jnp.float32)
    seeds = jnp.asarray(seeds, jnp.uint32)
    assert seeds.shape == (prices.shape[0],), (seeds.shape, prices.shape)
    return _noisy_matrix_batch_jax(
        prices, avail, jnp.asarray(level, jnp.float32), seeds,
        kind, int(horizon), int(avail_max),
    )


def regional_noisy_matrix_jax(prices, avail, kind: str, level, seeds,
                              horizon: int, avail_max: int = 16):
    """:class:`RegionalPredictor` lift of :func:`noisy_matrix_batch_jax`:
    (K, R, T) per-(job, region) market windows and (K, R) seeds ->
    (K, R, T, horizon+1, 2) forecast stacks, built by ONE jitted call over
    the flattened (K*R,) row axis — the region axis never leaves the
    device. ``level`` is a scalar or (K,) per-job array (broadcast across
    that job's regions)."""
    prices = jnp.asarray(prices, jnp.float32)
    K, R, T = prices.shape
    avail = jnp.asarray(avail, jnp.float32)
    seeds = jnp.asarray(seeds, jnp.uint32)
    assert seeds.shape == (K, R), (seeds.shape, (K, R))
    level = jnp.asarray(level, jnp.float32)
    if level.ndim:
        level = jnp.repeat(level, R)                # (K*R,) per-row levels
    out = noisy_matrix_batch_jax(
        prices.reshape(K * R, T), avail.reshape(K * R, T), kind, level,
        seeds.reshape(-1), horizon, avail_max,
    )
    return out.reshape(K, R, T, horizon + 1, 2)


class PerfectPredictor:
    def __init__(self, trace: Trace):
        self.trace = trace

    def matrix(self, horizon: int) -> np.ndarray:
        return _true_future(self.trace, horizon)


class NoisyPredictor:
    """Perfect forecast corrupted by one of the four paper noise regimes.

    ``level`` is the relative error scale (e.g. 0.1 = 10%); the j-step error
    scales with sqrt(j) (error accumulation in multi-step forecasts).
    """

    def __init__(self, trace: Trace, kind: str, level: float, seed: int = 0,
                 avail_max: int = 16):
        assert kind in NOISE_KINDS, kind
        self.trace, self.kind, self.level, self.seed = trace, kind, level, seed
        self.avail_max = avail_max

    def matrix(self, horizon: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        out = _true_future(self.trace, horizon)
        T = out.shape[0]
        scale = self.level * np.sqrt(np.arange(horizon + 1))  # 0 at j=0
        ref = np.stack([
            np.full(T, np.mean(self.trace.prices)),
            np.full(T, np.mean(self.trace.avail)),
        ], axis=-1)  # (T,2) reference magnitudes for fixed-magnitude noise
        if self.kind.endswith("uniform"):
            eps = rng.uniform(-1, 1, out.shape)
        else:  # heavy-tail: Student-t(3), clipped for sanity
            eps = np.clip(rng.standard_t(3, out.shape), -8, 8) / np.sqrt(3)
        eps = eps * scale[None, :, None]
        if self.kind.startswith("magdep"):
            noisy = out * (1.0 + eps)
        else:
            noisy = out + eps * ref[:, None, :]
        noisy[..., 0] = np.clip(noisy[..., 0], 0.01, 10.0)
        noisy[..., 1] = np.clip(np.round(noisy[..., 1]), 0, self.avail_max)
        noisy[:, 0, :] = out[:, 0, :]  # the present is observed, not predicted
        return noisy


@dataclass
class ARIMAConfig:
    p: int = 2                 # AR order on deseasonalized residuals
    seasonal_lag: int = 48     # one day of 30-min slots
    history: int = 10 * 48     # fit window
    ridge: float = 1e-3


class ARIMAPredictor:
    """Seasonal AR: y_t = m_{t mod s} + r_t with AR(p) residuals.

    The seasonal profile m (per time-of-day mean over the history window)
    captures the diurnal cycle; the residual AR(p) (numpy lstsq with ridge)
    captures the persistent noise — a SARIMA-family decomposition that beats
    both pure persistence and naive seasonal differencing on AR-dominated
    diurnal traces (test_market_predictor.py pins this).
    """

    def __init__(self, trace: Trace, cfg: Optional[ARIMAConfig] = None,
                 avail_max: int = 16):
        self.trace = trace
        self.cfg = cfg or ARIMAConfig(seasonal_lag=trace.slots_per_day)
        self.avail_max = avail_max

    def _fit_forecast(self, series: np.ndarray, t: int, horizon: int) -> np.ndarray:
        c = self.cfg
        s, p = c.seasonal_lag, c.p
        start = max(0, t + 1 - c.history)
        hist = series[start : t + 1]
        if len(hist) < s + p + 8:  # not enough data: persistence forecast
            return np.full(horizon, series[t])
        logspace = bool(np.all(hist > 0))  # prices: multiplicative dynamics
        h = np.log(hist) if logspace else hist.astype(float)
        # smoothed seasonal profile over the history window
        idx = (np.arange(start, t + 1)) % s
        prof = np.full(s, h.mean())
        for k in range(s):
            sel = h[idx == k]
            if len(sel):
                prof[k] = sel.mean()
        w = 5  # circular smoothing kills per-slot profile noise
        ker = np.ones(w) / w
        prof = np.convolve(np.concatenate([prof[-w:], prof, prof[:w]]), ker, "same")[w:-w]
        r = h - prof[idx]
        # AR(p) on deseasonalized residuals
        X = np.stack([r[p - i - 1 : len(r) - i - 1] for i in range(p)], axis=1)
        y = r[p:]
        A = X.T @ X + c.ridge * len(y) * np.eye(p)
        coef = np.linalg.solve(A, X.T @ y)
        rbuf = list(r[-p:])  # oldest..newest
        out = np.empty(horizon)
        for j in range(1, horizon + 1):
            rn = float(np.dot(coef, rbuf[::-1][:p]))
            v = prof[(t + j) % s] + rn
            out[j - 1] = np.exp(v) if logspace else v
            rbuf.append(rn)
        return out

    def matrix(self, horizon: int) -> np.ndarray:
        T = len(self.trace)
        out = _true_future(self.trace, horizon)  # j=0 column = observed
        for t in range(T):
            fp = self._fit_forecast(self.trace.prices, t, horizon)
            fa = self._fit_forecast(self.trace.avail.astype(float), t, horizon)
            out[t, 1:, 0] = np.clip(fp, 0.01, 10.0)
            out[t, 1:, 1] = np.clip(np.round(fa), 0, self.avail_max)
        return out


class RegionalPredictor:
    """Per-region predictor lifted to a multi-region market: ``matrix``
    returns (R, T, horizon+1, 2) — one prediction matrix per region, each
    produced by an independent base predictor.

    ``factory(trace, region_index) -> predictor`` builds the per-region base
    (default: PerfectPredictor). The region index lets noisy/ARIMA factories
    decorrelate seeds across regions, e.g.::

        RegionalPredictor(market,
                          lambda tr, r: NoisyPredictor(tr, "fixed_uniform",
                                                       0.2, seed=r))
    """

    def __init__(self, market, factory=None):
        self.market = market
        self.factory = factory or (lambda tr, r: PerfectPredictor(tr))
        self.predictors = [
            self.factory(market.region(r), r) for r in range(market.n_regions)
        ]

    def matrix(self, horizon: int) -> np.ndarray:
        return np.stack([p.matrix(horizon) for p in self.predictors])


def mape(pred: np.ndarray, true: np.ndarray) -> float:
    return float(np.mean(np.abs(pred - true) / np.maximum(np.abs(true), 1e-6)))


def forecast_errors(trace: Trace, predictor, horizon: int) -> dict:
    """Per-step MAPE for price and availability (benchmarks/fig3)."""
    M = predictor.matrix(horizon)
    truth = _true_future(trace, horizon)
    out = {"price": [], "avail": []}
    T = len(trace)
    for j in range(1, horizon + 1):
        valid = np.arange(T - j)
        out["price"].append(mape(M[valid, j, 0], truth[valid, j, 0]))
        out["avail"].append(mape(M[valid, j, 1], np.maximum(truth[valid, j, 1], 1)))
    return out
