"""Mixtral-8x7B [arXiv:2401.04088] — MoE, 8 experts top-2, sliding-window attention."""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        arch_type="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        rope_theta=1_000_000.0,
        sliding_window=4096,
        norm_type="rmsnorm",
        mlp_act="silu",
        moe=MoEConfig(num_experts=8, top_k=2),
        source="arXiv:2401.04088",
    )


def smoke_config() -> ModelConfig:
    return config().reduced()
