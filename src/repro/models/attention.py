"""GQA attention: full / causal / sliding-window, train+prefill+decode paths.

Long sequences (prefill_32k) never materialize the full score matrix: the
XLA path switches to a blockwise online-softmax formulation (lax.scan over KV
blocks inside a lax.map over Q blocks) — the same tiling the Pallas TPU
kernel (`repro/kernels/flash_attention.py`) uses, which keeps the dry-run
memory analysis honest.

Sliding-window decode uses a ring-buffer KV cache of size ``window`` so that
`long_500k` decode is O(window), not O(seq) (DESIGN.md §3).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import lora as lora_lib
from repro.models.common import normal_param, zeros_param
from repro.models.rope import apply_m_rope, apply_rope
from repro.sharding import shard

_NEG_INF = -2.0e38  # f32-safe mask value

# switch to blockwise attention above this many score elements per (b,h)
_BLOCKWISE_THRESHOLD = 4096 * 4096
_Q_BLOCK = 512
_KV_BLOCK = 1024


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": normal_param(ks[0], (d, h, hd), ("fsdp", "heads", None), dtype),
        "wk": normal_param(ks[1], (d, kv, hd), ("fsdp", "kv_heads", None), dtype),
        "wv": normal_param(ks[2], (d, kv, hd), ("fsdp", "kv_heads", None), dtype),
        "wo": normal_param(
            ks[3], (h, hd, d), ("heads", None, "fsdp"), dtype, stddev=1.0 / math.sqrt(h * hd)
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_param((h, hd), ("heads", None), dtype)
        p["bk"] = zeros_param((kv, hd), ("kv_heads", None), dtype)
        p["bv"] = zeros_param((kv, hd), ("kv_heads", None), dtype)
    if cfg.o_bias:
        p["bo"] = zeros_param((d,), (None,), dtype)
    lora_tree = {}
    r = cfg.lora.rank
    lk = jax.random.split(ks[4], 4)
    if "q" in cfg.lora.targets:
        lora_tree["q"] = lora_lib.init_lora_pair(lk[0], d, (h, hd), r)
    if "k" in cfg.lora.targets:
        lora_tree["k"] = lora_lib.init_lora_pair(lk[1], d, (kv, hd), r)
    if "v" in cfg.lora.targets:
        lora_tree["v"] = lora_lib.init_lora_pair(lk[2], d, (kv, hd), r)
    if "o" in cfg.lora.targets:
        lora_tree["o"] = lora_lib.init_lora_pair(lk[3], h * hd, (d,), r)
    if lora_tree:
        p["lora"] = lora_tree
    return p


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def qkv_project(cfg, p, x, positions):
    """x:(B,S,d) -> q:(B,S,h,hd), k,v:(B,S,kv,hd), with RoPE applied."""
    scale = cfg.lora.alpha / cfg.lora.rank
    lt = p.get("lora", {})
    q = lora_lib.proj(x, p["wq"], p.get("bq"), lt.get("q"), scale)
    k = lora_lib.proj(x, p["wk"], p.get("bk"), lt.get("k"), scale)
    v = lora_lib.proj(x, p["wv"], p.get("bv"), lt.get("v"), scale)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    if cfg.m_rope:
        q = apply_m_rope(q, positions, cfg.rope_theta, cfg.m_rope_sections)
        k = apply_m_rope(k, positions, cfg.rope_theta, cfg.m_rope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_project(cfg, p, attn_out):
    """attn_out:(B,S,h,hd) -> (B,S,d)."""
    scale = cfg.lora.alpha / cfg.lora.rank
    y = jnp.einsum("bsnh,nhd->bsd", attn_out, p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    lt = p.get("lora", {})
    if "o" in lt:
        b, s, n, hd = attn_out.shape
        y = y + lora_lib.lora_delta(
            attn_out.reshape(b, s, n * hd), lt["o"], scale
        )
    return y


# ---------------------------------------------------------------------------
# Core attention (plain + blockwise)
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(Sq, Sk) additive mask bias in f32."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, _NEG_INF)


def _plain_attn(q, k, v, q_pos, k_pos, causal, window):
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qf = q.astype(jnp.float32) * (1.0 / math.sqrt(hd))
    qf = qf.reshape(b, sq, kvh, rep, hd)
    scores = jnp.einsum("bqkrh,bskh->bkrqs", qf, k.astype(jnp.float32))
    scores = scores + _mask_bias(q_pos, k_pos, causal, window)[None, None, None]
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrqs,bskh->bqkrh", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _blockwise_attn(q, k, v, q_pos, k_pos, causal, window):
    """Online-softmax attention; never materializes more than a block pair."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    rep = h // kvh
    qb, kb = min(_Q_BLOCK, sq), min(_KV_BLOCK, sk)
    assert sq % qb == 0 and sk % kb == 0, (sq, qb, sk, kb)
    nq, nk = sq // qb, sk // kb
    sm = 1.0 / math.sqrt(hd)

    kc = k.astype(jnp.float32).reshape(b, nk, kb, kvh, hd)
    vc = v.astype(jnp.float32).reshape(b, nk, kb, kvh, hd)
    k_pos_c = k_pos.reshape(nk, kb)

    def per_q_block(args):
        qi, q_blk, qp = args  # q_blk: (b, qb, h, hd)
        qf = (q_blk.astype(jnp.float32) * sm).reshape(b, qb, kvh, rep, hd)

        def kv_step(carry, inp):
            m, l, acc = carry
            k_blk, v_blk, kp = inp  # (b, kb, kvh, hd), (kb,)
            s = jnp.einsum("bqkrh,bskh->bkrqs", qf, k_blk)
            s = s + _mask_bias(qp, kp, causal, window)[None, None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bkrqs,bskh->bkrqh", p, v_blk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, rep, qb), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, rep, qb), jnp.float32)
        a0 = jnp.zeros((b, kvh, rep, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kc.swapaxes(0, 1), vc.swapaxes(0, 1), k_pos_c)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, qb, h, hd)

    q_blocks = q.reshape(b, nq, qb, h, hd).swapaxes(0, 1)
    q_pos_c = q_pos.reshape(nq, qb)
    outs = jax.lax.map(per_q_block, (jnp.arange(nq), q_blocks, q_pos_c))
    return outs.swapaxes(0, 1).reshape(b, sq, h, hd).astype(q.dtype)


def attend(q, k, v, q_pos, k_pos, causal: bool, window: Optional[int]):
    """Dispatch: plain einsum for small S, blockwise for long sequences."""
    if q.shape[1] * k.shape[1] <= _BLOCKWISE_THRESHOLD:
        return _plain_attn(q, k, v, q_pos, k_pos, causal, window)
    return _blockwise_attn(q, k, v, q_pos, k_pos, causal, window)


# ---------------------------------------------------------------------------
# KV cache (full + sliding-window ring buffer)
# ---------------------------------------------------------------------------

def cache_width(cfg, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_kv_cache(cfg, batch: int, max_len: int, dtype, n_layers: int):
    w = cache_width(cfg, max_len)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (n_layers, batch, w, kv, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def kv_cache_specs(cfg, batch: int, max_len: int, n_layers: int):
    """Logical axes for the cache pytree (for pjit shardings)."""
    axes = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {"k": axes, "v": axes}


def write_prefill(cfg, cache_k, cache_v, k, v):
    """Write a full prefix (B,S,kv,hd) into one layer's cache (B,W,kv,hd)."""
    w = cache_k.shape[1]
    s = k.shape[1]
    if s >= w:
        kw, vw = k[:, -w:], v[:, -w:]
        shift = s % w
        return jnp.roll(kw, shift, axis=1), jnp.roll(vw, shift, axis=1)
    return (
        jax.lax.dynamic_update_slice_in_dim(cache_k, k, 0, axis=1),
        jax.lax.dynamic_update_slice_in_dim(cache_v, v, 0, axis=1),
    )


def write_decode(cache_k, cache_v, k1, v1, index):
    """Write one token (B,1,kv,hd) at ring slot index % W."""
    w = cache_k.shape[1]
    slot = index % w
    return (
        jax.lax.dynamic_update_slice_in_dim(cache_k, k1, slot, axis=1),
        jax.lax.dynamic_update_slice_in_dim(cache_v, v1, slot, axis=1),
    )


def ring_positions(width: int, index):
    """Position held by each ring slot after `index` tokens written; -1 = empty.

    Slot j holds the largest position p < index with p % width == j.
    """
    j = jnp.arange(width, dtype=jnp.int32)
    last = index - 1
    p = last - ((last - j) % width)
    return jnp.where((index > 0) & (p >= 0), p, -1)


def decode_attend(cfg, q1, cache_k, cache_v, index):
    """q1:(B,1,h,hd) against one layer's ring cache; returns (B,1,h,hd)."""
    b, _, h, hd = q1.shape
    w = cache_k.shape[1]
    k_pos = ring_positions(w, index)
    q_pos = jnp.full((1,), index, jnp.int32)
    kvh = cache_k.shape[2]
    rep = h // kvh
    qf = q1.astype(jnp.float32).reshape(b, 1, kvh, rep, hd) * (1.0 / math.sqrt(hd))
    s = jnp.einsum("bqkrh,bskh->bkrqs", qf, cache_k.astype(jnp.float32))
    ok = k_pos >= 0
    if cfg.sliding_window is not None:
        # query position is index-1 (index = tokens written incl. current):
        # valid keys satisfy k_pos > q_pos - window
        ok &= k_pos > index - 1 - cfg.sliding_window
    # causal w.r.t. current index is implied: all cached positions < index
    s = jnp.where(ok[None, None, None, None, :], s, _NEG_INF)
    wgt = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrqs,bskh->bqkrh", wgt, cache_v.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q1.dtype)
