"""Elastic slot-driven fine-tuning: the paper's scheduler driving a real
LoRA training loop.

Each market slot the policy picks (n_o, n_s); the trainer then executes
``round(mu_t * H(n_t) * steps_per_unit)`` optimizer steps of the slot. The
GLOBAL batch is held fixed (paper Sec. III-B: "to avoid affecting the
model's convergence ... we fix the global batch size"), so the update
sequence is identical to what an n_t-wide data-parallel cluster would
produce — elasticity changes wall-clock time and cost, never the math. On
every instance-count change the trainer performs a REAL checkpoint
save/restore roundtrip (repro.checkpoint), measuring serialized bytes and
deriving the switching cost the same way the paper's mu does (Eq. 2).

Spot preemption: if the market's availability drops below the policy's
spot allocation, the allocation is trimmed (the simulator semantics) and
the state restored from the last checkpoint — data-stream determinism
(ShardedLMLoader.batch_at) guarantees no sample is lost or duplicated.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import numpy as np

from repro.checkpoint import restore, save, transfer_seconds
from repro.configs.base import JobConfig, ModelConfig, ThroughputConfig, TrainConfig
from repro.core.job import value_fn
from repro.core.market import Trace
from repro.core.policies import BasePolicy, Obs
from repro.data.loader import ShardedLMLoader
from repro.models import transformer as tf
from repro.sharding import split_params
from repro.train.step import init_opt_state, make_train_step


@dataclass
class SlotLog:
    t: int
    n_od: int
    n_spot: int
    price: float
    mu: float
    steps: int
    mean_loss: float
    cost: float
    reconfig_s: float = 0.0
    ckpt_bytes: int = 0


@dataclass
class ElasticReport:
    utility: float
    value: float
    cost: float
    completion_time: float
    z_final: float
    completed: bool
    total_steps: int
    losses: List[float] = field(default_factory=list)
    slots: List[SlotLog] = field(default_factory=list)


class ElasticTrainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        job: JobConfig,
        tput: ThroughputConfig,
        policy: BasePolicy,
        trace: Trace,
        pred_matrix: Optional[np.ndarray] = None,
        steps_per_unit: float = 4.0,
        ckpt_dir: str = "/tmp/repro_elastic",
        bandwidth_bps: float = 800e6,
        seed: int = 0,
        ckpt_retries: int = 2,
    ):
        self.cfg, self.tcfg, self.job, self.tput = cfg, tcfg, job, tput
        self.policy, self.trace, self.pred = policy, trace, pred_matrix
        self.steps_per_unit = steps_per_unit
        self.ckpt_dir = ckpt_dir
        self.bandwidth_bps = bandwidth_bps
        self.ckpt_retries = ckpt_retries

        rng = jax.random.PRNGKey(tcfg.seed)
        self.params, _ = tf.init_model(rng, cfg)
        self.opt = init_opt_state(self.params)
        self._step = jax.jit(make_train_step(cfg, tcfg))
        self.loader = ShardedLMLoader(
            cfg.vocab_size, tcfg.global_batch, tcfg.seq_len, seed=seed
        )
        self.global_step = 0

    # ------------------------------------------------------------------
    def _reconfigure(self, t: int) -> tuple:
        """Checkpoint roundtrip on an instance-count change; returns
        (seconds_estimate_on_cluster, bytes)."""
        path = os.path.join(self.ckpt_dir, "elastic.ckpt")
        from repro.utils.partition import is_lora_path, partition_by_path

        lora, merge = partition_by_path(self.params, is_lora_path)
        state = {"lora": lora, "opt": self.opt, "step": self.global_step}
        nbytes = save(path, state, meta={"arch": self.cfg.name},
                      retries=self.ckpt_retries)
        restored, meta = restore(path, state, retries=self.ckpt_retries)
        # re-adopt the restored state (exercises the real path)
        self.params = merge(restored["lora"])
        self.opt = restored["opt"]
        secs = nbytes * 8.0 / self.bandwidth_bps
        return secs, nbytes

    # ------------------------------------------------------------------
    def run(self) -> ElasticReport:
        job, tput = self.job, self.tput
        policy = self.policy
        policy.reset(job, tput)
        z, n_prev, cost = 0.0, 0, 0.0
        T_complete: Optional[float] = None
        losses: List[float] = []
        slots: List[SlotLog] = []

        for t in range(job.deadline):
            price = float(self.trace.prices[t])
            avail = int(self.trace.avail[t])
            obs = Obs(t=t, price=price, avail=avail, z_prev=z, n_prev=n_prev,
                      pred=self.pred[t] if self.pred is not None else None)
            n_o, n_s = policy.decide(obs)
            n_s = int(np.clip(n_s, 0, min(avail, job.n_max)))
            n_o = int(np.clip(n_o, 0, job.n_max - n_s))
            n = n_o + n_s
            if 0 < n < job.n_min:
                n_o += job.n_min - n
                n = n_o + n_s

            reconfig_s, nbytes = (0.0, 0)
            if n != n_prev and n > 0:
                reconfig_s, nbytes = self._reconfigure(t)
            mu = 1.0 if n == n_prev else (tput.mu1 if n > n_prev else tput.mu2)
            if n == 0 and n_prev == 0:
                mu = 1.0

            work = mu * (tput.alpha * n + (tput.beta if n > 0 else 0.0))
            work = min(work, job.workload - z) if z + work >= job.workload else work
            steps = int(round(work * self.steps_per_unit))
            slot_losses = []
            for _ in range(steps):
                batch = self.loader.batch_at(self.global_step)
                self.params, self.opt, m = self._step(self.params, self.opt, batch)
                slot_losses.append(float(m.loss))
                self.global_step += 1
            losses.extend(slot_losses)

            cost += n_s * price + n_o * job.on_demand_price
            full_work = mu * (tput.alpha * n + (tput.beta if n > 0 else 0.0))
            if full_work > 0 and z + full_work >= job.workload and T_complete is None:
                T_complete = t + (job.workload - z) / full_work
            z = min(z + full_work, job.workload)
            slots.append(SlotLog(
                t=t, n_od=n_o, n_spot=n_s, price=price, mu=mu, steps=steps,
                mean_loss=float(np.mean(slot_losses)) if slot_losses else float("nan"),
                cost=n_s * price + n_o * job.on_demand_price,
                reconfig_s=reconfig_s, ckpt_bytes=nbytes,
            ))
            n_prev = n
            if T_complete is not None:
                break

        if T_complete is None:
            h_max = tput.alpha * job.n_max + tput.beta
            dt_ = (job.workload - z) / h_max
            T_complete = job.deadline + dt_
            cost += job.on_demand_price * job.n_max * dt_
            # termination config: run the remaining steps on-demand
            steps = int(round((job.workload - z) * self.steps_per_unit))
            for _ in range(steps):
                batch = self.loader.batch_at(self.global_step)
                self.params, self.opt, m = self._step(self.params, self.opt, batch)
                losses.append(float(m.loss))
                self.global_step += 1
            z = job.workload

        value = float(value_fn(job, T_complete))
        return ElasticReport(
            utility=value - cost, value=value, cost=cost,
            completion_time=float(T_complete), z_final=float(z),
            completed=T_complete <= job.deadline,
            total_steps=self.global_step, losses=losses, slots=slots,
        )
