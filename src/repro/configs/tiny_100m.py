"""~100M-param dense model for the end-to-end CPU example driver."""
from repro.configs.base import LoRAConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="tiny-100m",
        arch_type="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=32000,
        rope_theta=10000.0,
        norm_type="rmsnorm",
        mlp_act="silu",
        tie_embeddings=True,
        lora=LoRAConfig(rank=16, alpha=32.0, targets=("q", "v")),
        source="example driver",
    )


def smoke_config() -> ModelConfig:
    return config().reduced()
