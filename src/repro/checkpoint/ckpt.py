"""Checkpointing: msgpack + zstd over flattened pytrees.

This is the substrate behind the paper's *switching cost* (Sec. II-A): when
the spot scheduler changes the instance count or a preemption hits, the
fine-tuning state (LoRA params + optimizer state + data-stream position) is
written, shipped over the (possibly slow) network, and restored. The paper
measures 0.58 s at 200 Gbps vs 1152 s at 100 Mbps for a full LLaMA2-7B
checkpoint; ``checkpoint_bytes``/``transfer_seconds`` reproduce that model
from the actual serialized sizes.

Elastic resharding: checkpoints are *instance-count independent* (full
logical arrays), so restoring onto a different data-parallel width is a
no-op — the loader re-shards on the next step.
"""
from __future__ import annotations

import io
import json
import os
import tempfile
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional: zstd is the preferred codec but not a hard dependency
    import zstandard
except ModuleNotFoundError:  # pragma: no cover - env-dependent
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _pack_leaf(x) -> dict:
    arr = np.asarray(x)
    if arr.dtype == jnp.bfloat16:
        return {
            "dtype": "bfloat16",
            "shape": list(arr.shape),
            "data": arr.view(np.uint16).tobytes(),
        }
    return {"dtype": str(arr.dtype), "shape": list(arr.shape), "data": arr.tobytes()}


def _unpack_leaf(d: dict):
    if d["dtype"] == "bfloat16":
        arr = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    arr = np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])
    return jnp.asarray(arr)


def serialize(tree, meta: Optional[Dict[str, Any]] = None) -> bytes:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "meta": json.dumps(meta or {}),
        "leaves": [_pack_leaf(l) for l in leaves],
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def deserialize(blob: bytes, tree_like) -> Tuple[Any, Dict[str, Any]]:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ModuleNotFoundError(
                "checkpoint was written with zstd but the 'zstandard' package "
                "is not installed (zlib-written checkpoints need no extra deps)"
            )
        raw = zstandard.ZstdDecompressor().decompress(blob)
    else:
        raw = zlib.decompress(blob)
    payload = msgpack.unpackb(raw, raw=False)
    leaves = [_unpack_leaf(d) for d in payload["leaves"]]
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves), json.loads(payload["meta"])


def save(path: str, tree, meta: Optional[Dict[str, Any]] = None) -> int:
    """Atomic write; returns byte size (feeds the switching-cost model)."""
    blob = serialize(tree, meta)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return len(blob)


def restore(path: str, tree_like) -> Tuple[Any, Dict[str, Any]]:
    with open(path, "rb") as f:
        return deserialize(f.read(), tree_like)


# ---------------------------------------------------------------------------
# Switching-cost model (paper Sec. II-A / VI-A)
# ---------------------------------------------------------------------------

def checkpoint_bytes(cfg) -> int:
    """Base model + LoRA + Adam moments, bf16 base / f32 adapters."""
    base = cfg.param_count() * 2
    lora = cfg.lora_param_count() * 4
    adam = cfg.lora_param_count() * 8  # m and v in f32
    return base + lora + adam


def transfer_seconds(cfg, bandwidth_bps: float) -> float:
    return checkpoint_bytes(cfg) * 8.0 / bandwidth_bps


def reconfiguration_mu(cfg, bandwidth_bps: float, slot_seconds: float,
                       startup_seconds: float = 180.0) -> float:
    """Effective-compute fraction of a slot after a scale-up event (Eq. 2):
    checkpoint transfer + container/startup time, clipped to [0, 1]."""
    dead = transfer_seconds(cfg, bandwidth_bps) + startup_seconds
    return float(np.clip(1.0 - dead / slot_seconds, 0.0, 1.0))
